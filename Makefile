PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-quick ci

test:            ## tier-1 test suite
	python -m pytest -x -q

bench:           ## full benchmark harness (all paper figures)
	python -m benchmarks.run

bench-quick:     ## smoke subset: conv layers + dispatch, 3 iters
	python -m benchmarks.run --quick

ci: test bench-quick  ## what scripts/ci.sh runs
