PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test lint-contracts bench bench-quick bench-conv serve-smoke serve-smoke-paged obs-smoke train-smoke chaos-smoke train-chaos-smoke ci

test:            ## tier-1 test suite
	python -m pytest -x -q

lint-contracts:  ## cross-layer contract checker (docs/static-analysis.md)
	python -m repro.analysis src

bench:           ## full benchmark harness (all paper figures)
	python -m benchmarks.run

bench-quick:     ## smoke subset: conv layers + dispatch, 3 iters
	python -m benchmarks.run --quick

bench-conv:      ## conv megakernel race, quick; writes BENCH_conv.json
	python -m benchmarks.bench_conv_fused --quick --json

serve-smoke:     ## continuous-batching scheduler CLI smoke
	python -m repro.launch.serve --arch smollm-360m --smoke --continuous \
	    --requests 6 --slots 3 --prompt-len 12 --new-tokens 8 --prefill-chunk 8

serve-smoke-paged: ## paged-KV scheduler smoke: --trace validated + page gauges
	@t=$$(mktemp -t repro_paged_XXXXXX.json); \
	python -m repro.launch.serve --arch smollm-360m --smoke --continuous \
	    --paged --page-size 8 --requests 6 --slots 3 --prompt-len 12 \
	    --new-tokens 8 --prefill-chunk 8 --trace $$t \
	&& python -m repro.obs.validate $$t; \
	rc=$$?; rm -f $$t; exit $$rc

obs-smoke:       ## serve --trace writes a Chrome trace; validate its schema
	@t=$$(mktemp -t repro_obs_XXXXXX.json); \
	python -m repro.launch.serve --arch smollm-360m --smoke --continuous \
	    --requests 6 --slots 3 --prompt-len 12 --new-tokens 8 \
	    --prefill-chunk 8 --trace $$t \
	&& python -m repro.obs.validate $$t; \
	rc=$$?; rm -f $$t; exit $$rc

train-smoke:     ## 2-step resnet-tiny sparse finetune (conv VJP backward path)
	python -c "from repro.models.vision import train_smoke; train_smoke(steps=2)"

train-chaos-smoke: ## kill a finetune subprocess mid-run, restart, demand bitwise-identical final params
	python scripts/train_chaos_smoke.py

chaos-smoke:     ## seeded fault-injected paged serve: quarantine-degradation + lifecycle, trace validated
	@t=$$(mktemp -t repro_chaos_XXXXXX.json); \
	python scripts/chaos_smoke.py --trace $$t \
	&& python -m repro.obs.validate $$t; \
	rc=$$?; rm -f $$t; exit $$rc

ci: lint-contracts test serve-smoke serve-smoke-paged obs-smoke chaos-smoke train-smoke train-chaos-smoke bench-quick bench-conv  ## what scripts/ci.sh runs
