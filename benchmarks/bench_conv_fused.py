"""Fused conv megakernel vs its decomposed plans (perf trajectory artifact).

Races, per ResNet-shaped conv layer at 50% column-wise sparsity:

  fused       — the im2col+pack+sparse-GEMM megakernel (strips live in VMEM,
                zero intermediate HBM round-trips)
  two_kernel  — pack kernel + strip-major sparse GEMM (strips written/read
                once, no transpose relayout)
  transposed  — the pre-megakernel two-kernel path: pack kernel, then
                ``transpose(0,2,1).reshape`` relayout feeding the row-major
                GEMM (three patch-matrix HBM round-trips)
  xla         — pack kernel + gather-einsum reference GEMM

Also reports the analytic bytes moved around the packing stage
(``im2col_pack.ops.bytes_moved_*``) so the measured ordering can be checked
against the data-movement model.  ``--json`` writes ``BENCH_conv.json`` —
the repo's conv perf-trajectory artifact — with every timing and the
fused/two-kernel speedup per layer.  ``--quick`` runs the two deepest layers
with 3 iters (CI smoke; interpret-mode Pallas on CPU is the slow part).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.timing import row, time_fn
from repro.core import SparsityConfig
from repro.kernels.conv_gemm.ops import (
    compress_conv_weights,
    conv2d_fused,
    conv2d_two_kernel,
    conv2d_xla_ref,
)
from repro.kernels.colwise_nm.ops import colwise_nm_matmul
from repro.kernels.im2col_pack.ops import (
    bytes_moved_fused,
    bytes_moved_unfused,
    im2col_pack,
)
from repro.kernels.im2col_pack.ref import out_size

SPARSITY = 0.5
V = 128

# ResNet-50 stages (batch 1); H capped so CPU interpret-mode Pallas stays
# affordable — the deeper layers are the exact paper shapes.
LAYERS = [
    ("s2.c2", 128, 28, 128, 3, 1),
    ("s3.c2", 256, 14, 256, 3, 1),
    ("s4.c2", 512, 7, 512, 3, 1),
]
QUICK_LAYERS = ("s3.c2", "s4.c2")


def _transposed(x, values, idx, *, kh, kw, stride, pad, v):
    """The pre-megakernel plan: pack, relayout through HBM, row-major GEMM."""
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    o = values.shape[0] * values.shape[2]
    strips = im2col_pack(x, kh=kh, kw=kw, stride=stride, pad=pad, v=v)
    xt = strips.transpose(0, 2, 1).reshape(-1, kh * kw * c)
    y = colwise_nm_matmul(xt, values, idx)[: b * ho * wo]
    return y.T.reshape(o, b, ho, wo)


PLANS = [
    ("fused", conv2d_fused),
    ("two_kernel", conv2d_two_kernel),
    ("transposed", _transposed),
    ("xla", conv2d_xla_ref),
]


def _problem(c, h, o, k, stride):
    x = jax.random.normal(jax.random.PRNGKey(0), (c, 1, h, h))
    wt = jax.random.normal(jax.random.PRNGKey(1), (o, k, k, c)) / jnp.sqrt(
        float(k * k * c))
    cfg = SparsityConfig(SPARSITY, m=None, tile=None, format="compressed_pallas")
    values, idx, meta = compress_conv_weights(wt, cfg)
    return x, values, idx, meta


def measure(iters: int = 5, quick: bool = False):
    """Time every plan per layer; returns {layer: {plan: us, ...}}."""
    layers = [l for l in LAYERS if not quick or l[0] in QUICK_LAYERS]
    results = {}
    for name, c, h, o, k, stride in layers:
        pad = k // 2 if k > 1 else 0
        x, values, idx, meta = _problem(c, h, o, k, stride)
        ho = out_size(h, k, stride, pad)
        entry = {"shape": {"c": c, "h": h, "o": o, "k": k, "stride": stride,
                           "tile": meta.tile, "k_kept": meta.k_kept}}
        for plan, fn in PLANS:
            f = jax.jit(lambda x, fn=fn: fn(
                x, values, idx, kh=k, kw=k, stride=stride, pad=pad, v=V))
            entry[plan] = time_fn(f, x, iters=iters, warmup=1)
        entry["fused_speedup_vs_two_kernel"] = entry["two_kernel"] / entry["fused"]
        entry["fused_speedup_vs_transposed"] = entry["transposed"] / entry["fused"]
        entry["bytes_moved_fused"] = bytes_moved_fused(
            c, 1, h, h, k, k, ho, ho, V, 4)
        entry["bytes_moved_unfused"] = bytes_moved_unfused(
            c, 1, h, h, k, k, ho, ho, V, 4)
        results[name] = entry
    return results


def run(iters: int = 5, quick: bool = False):
    out = []
    for name, entry in measure(iters=iters, quick=quick).items():
        sh = entry["shape"]
        for plan, _ in PLANS:
            out.append(row(f"conv_fused.{name}.{plan}", entry[plan],
                           f"C={sh['c']} H={sh['h']} O={sh['o']} k={sh['k']}"))
        out.append(row(
            f"conv_fused.{name}.speedup", 0.0,
            f"fused_vs_two_kernel={entry['fused_speedup_vs_two_kernel']:.2f}x "
            f"fused_vs_transposed={entry['fused_speedup_vs_transposed']:.2f}x "
            f"bytes_fused/unfused="
            f"{entry['bytes_moved_fused'] / entry['bytes_moved_unfused']:.2f}"))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_conv.json (perf trajectory artifact)")
    ap.add_argument("--quick", action="store_true",
                    help="two deepest layers, 3 iters (CI smoke)")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args(argv)

    iters = args.iters if args.iters is not None else (3 if args.quick else 5)
    results = measure(iters=iters, quick=args.quick)
    for name, entry in results.items():
        for plan, _ in PLANS:
            print(row(f"conv_fused.{name}.{plan}", entry[plan]))
        print(row(f"conv_fused.{name}.speedup", 0.0,
                  f"fused_vs_two_kernel="
                  f"{entry['fused_speedup_vs_two_kernel']:.2f}x"))
    if args.json:
        payload = {
            "backend": jax.default_backend(),
            "sparsity": SPARSITY,
            "v": V,
            "iters": iters,
            "layers": results,
        }
        path = Path(__file__).resolve().parent.parent / "BENCH_conv.json"
        path.write_text(json.dumps(payload, indent=1))
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
