"""Conv execution-plan ladder race (perf trajectory artifact).

Races, per ResNet-shaped conv layer at 50% column-wise sparsity:

  fused       — the im2col+pack+sparse-GEMM megakernel (strips live in VMEM,
                zero intermediate HBM round-trips); skipped where its
                whole-map-resident VMEM predicate fails (stem-scale, batch>1)
  banded      — the H-tiled megakernel: double-buffered DMA row bands keep
                only ``stride*V rows + kh-1 halo`` resident; the rung that
                covers the shapes fused cannot
  two_kernel  — pack kernel + strip-major sparse GEMM (strips written/read
                once, no transpose relayout)
  pipelined   — two-kernel with the overlapped strip pipeline: strip chunk
                s+1 is async-copied while the GEMM consumes chunk s
  transposed  — the pre-megakernel two-kernel path: pack kernel, then
                ``transpose(0,2,1).reshape`` relayout feeding the row-major
                GEMM (three patch-matrix HBM round-trips)
  xla         — pack kernel + gather-einsum reference GEMM

Also reports the analytic bytes moved around the packing stage
(``im2col_pack.ops.bytes_moved_*``) and — for the banded plan — the analytic
band-DMA traffic per band depth (``conv_gemm.ops.banded_bytes_moved`` over
hb in {1, 2, 4}: shallow bands re-read more halo rows, deep bands amortize
it), so measured orderings can be checked against the data-movement model.
``--json`` appends to ``BENCH_conv.json`` — the repo's conv perf-trajectory
artifact keeps prior runs under ``history`` so the trajectory across PRs is
recorded, not overwritten.  ``--quick`` runs the two deepest layers with 3
iters (CI smoke; interpret-mode Pallas on CPU is the slow part).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.timing import row, time_fn
from repro import dispatch
from repro.core import SparsityConfig
from repro.dispatch import REGISTRY, env_fingerprint
from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.kernels.conv_gemm.ops import (
    banded_bytes_moved,
    compress_conv_weights,
    conv2d_fused,
    conv2d_fused_banded,
    conv2d_two_kernel,
    conv2d_two_kernel_pipelined,
    conv2d_xla_ref,
)
from repro.kernels.colwise_nm.ops import colwise_nm_matmul
from repro.kernels.im2col_pack.ops import (
    bytes_moved_fused,
    bytes_moved_unfused,
    im2col_pack,
)
from repro.kernels.im2col_pack.ref import out_size
from repro.kernels.pltpu_compat import HAS_ASYNC_COPY

SPARSITY = 0.5
V = 128
BAND_HB = 2  # band depth the banded/pipelined plans run at (default geometry)

# ResNet-50 stages; the deeper layers are the exact paper shapes (H capped so
# CPU interpret-mode Pallas stays affordable).  ``stem.b8`` and ``s2.c2.b4``
# are the banded tier's reason to exist: stem-scale spatial extent and
# batch > 1 blow the whole-map-resident megakernel's VMEM predicate, so
# before this tier those shapes always fell back to the two-kernel plan.
#          name       c    h    o    k  stride batch
LAYERS = [
    ("s2.c2", 128, 28, 128, 3, 1, 1),
    ("s3.c2", 256, 14, 256, 3, 1, 1),
    ("s4.c2", 512, 7, 512, 3, 1, 1),
    ("s2.c2.b4", 128, 28, 128, 3, 1, 4),
    ("stem.b8", 64, 112, 64, 3, 2, 8),
]
QUICK_LAYERS = ("s3.c2", "s4.c2")


def _transposed(x, values, idx, *, kh, kw, stride, pad, v):
    """The pre-megakernel plan: pack, relayout through HBM, row-major GEMM."""
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    o = values.shape[0] * values.shape[2]
    strips = im2col_pack(x, kh=kh, kw=kw, stride=stride, pad=pad, v=v)
    xt = strips.transpose(0, 2, 1).reshape(-1, kh * kw * c)
    y = colwise_nm_matmul(xt, values, idx)[: b * ho * wo]
    return y.T.reshape(o, b, ho, wo)


def _banded(x, values, idx, *, kh, kw, stride, pad, v):
    return conv2d_fused_banded(x, values, idx, kh=kh, kw=kw, stride=stride,
                               pad=pad, v=v, hb=BAND_HB)


def _pipelined(x, values, idx, *, kh, kw, stride, pad, v):
    return conv2d_two_kernel_pipelined(x, values, idx, kh=kh, kw=kw,
                                       stride=stride, pad=pad, v=v, hb=BAND_HB)


# (name, fn, needs_fused_feasible): plans gated on the VMEM-resident
# predicate only run where a real TPU could run them; the manual-DMA plans
# only exist on async-copy-capable pallas builds (same gate as their
# dispatch predicates — the bench degrades to the PR-3 plan set, not a crash)
PLANS = [
    ("fused", conv2d_fused, True),
    *([("banded", _banded, False)] if HAS_ASYNC_COPY else []),
    ("two_kernel", conv2d_two_kernel, False),
    *([("pipelined", _pipelined, False)] if HAS_ASYNC_COPY else []),
    ("transposed", _transposed, False),
    ("xla", conv2d_xla_ref, False),
]


def _problem(c, h, o, k, stride, batch):
    x = jax.random.normal(jax.random.PRNGKey(0), (c, batch, h, h))
    wt = jax.random.normal(jax.random.PRNGKey(1), (o, k, k, c)) / jnp.sqrt(
        float(k * k * c))
    cfg = SparsityConfig(SPARSITY, m=None, tile=None, format="compressed_pallas")
    values, idx, meta = compress_conv_weights(wt, cfg)
    return x, values, idx, meta


def measure(iters: int = 5, quick: bool = False):
    """Time every plan per layer; returns {layer: {plan: us, ...}}."""
    layers = [l for l in LAYERS if not quick or l[0] in QUICK_LAYERS]
    results = {}
    for name, c, h, o, k, stride, batch in layers:
        pad = k // 2 if k > 1 else 0
        x, values, idx, meta = _problem(c, h, o, k, stride, batch)
        ho = out_size(h, k, stride, pad)
        key = dispatch.conv_key(c, h, h, o, k, k, stride, pad,
                                meta.k_kept, meta.tile, v=V, batch=batch)
        fused_ok, fused_why = REGISTRY.get(
            "conv", "fused_sparse_pallas").feasible(key)
        entry = {
            "shape": {"c": c, "h": h, "o": o, "k": k, "stride": stride,
                      "batch": batch, "tile": meta.tile,
                      "k_kept": meta.k_kept},
            "fused_feasible": bool(fused_ok),
            "fused_feasible_reason": fused_why,
        }
        for plan, fn, needs_fused in PLANS:
            if needs_fused and not fused_ok:
                continue  # a real TPU could not run this plan on this shape
            f = jax.jit(lambda x, fn=fn: fn(
                x, values, idx, kh=k, kw=k, stride=stride, pad=pad, v=V))
            entry[plan] = time_fn(f, x, iters=iters, warmup=1,
                                  name=f"conv_fused.{name}.{plan}")
        if "fused" in entry:
            entry["fused_speedup_vs_two_kernel"] = (
                entry["two_kernel"] / entry["fused"])
            entry["fused_speedup_vs_transposed"] = (
                entry["transposed"] / entry["fused"])
        for plan in ("banded", "pipelined"):
            if plan in entry:
                entry[f"{plan}_speedup_vs_two_kernel"] = (
                    entry["two_kernel"] / entry[plan])
        entry["bytes_moved_fused"] = bytes_moved_fused(
            c, batch, h, h, k, k, ho, ho, V, 4)
        entry["bytes_moved_unfused"] = bytes_moved_unfused(
            c, batch, h, h, k, k, ho, ho, V, 4)
        # band-DMA traffic vs band depth: the data-movement model behind the
        # hb tunable (shallow bands re-read halo rows; deep bands cost VMEM)
        entry["bytes_moved_banded"] = {
            str(hb): banded_bytes_moved(c, batch, h, h, k, stride, pad,
                                        ho, ho, V, hb, o, 4)
            for hb in (1, 2, 4)
        }
        # analytic data-movement counters on the obs registry (no-ops while
        # REPRO_OBS is off): a trace of a bench run carries the model-side
        # bytes next to the measured wall times
        _om.counter("bench.conv.bytes_moved_fused").inc(
            entry["bytes_moved_fused"])
        _om.counter("bench.conv.bytes_moved_unfused").inc(
            entry["bytes_moved_unfused"])
        _ot.instant("bench.conv.bytes_moved", layer=name,
                    fused=entry["bytes_moved_fused"],
                    unfused=entry["bytes_moved_unfused"],
                    banded_hb2=entry["bytes_moved_banded"]["2"])
        results[name] = entry
    return results


def run(iters: int = 5, quick: bool = False):
    out = []
    for name, entry in measure(iters=iters, quick=quick).items():
        sh = entry["shape"]
        for plan, _fn, _nf in PLANS:
            if plan not in entry:
                continue
            out.append(row(f"conv_fused.{name}.{plan}", entry[plan],
                           f"C={sh['c']} H={sh['h']} O={sh['o']} "
                           f"k={sh['k']} B={sh['batch']}"))
        speed = " ".join(
            f"{p}_vs_two_kernel={entry[f'{p}_speedup_vs_two_kernel']:.2f}x"
            for p in ("fused", "banded")
            if f"{p}_speedup_vs_two_kernel" in entry)
        out.append(row(
            f"conv_fused.{name}.speedup", 0.0,
            speed + " bytes_fused/unfused="
            f"{entry['bytes_moved_fused'] / entry['bytes_moved_unfused']:.2f}"
        ))
    return out


HISTORY_CAP = 20  # trajectory points kept; beyond this, oldest runs drop


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=5, check=True).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — not a git checkout / git missing
        return "unknown"


def _write_json(results, iters, quick=False):
    """Append this run to BENCH_conv.json.  A FULL run becomes the new
    top-level payload (back-compat with readers of the PR-3 schema) and the
    previous top-level run is pushed onto ``history`` — the perf trajectory
    across PRs, capped at :data:`HISTORY_CAP` entries so the artifact cannot
    grow without bound.  Every run is stamped with the dispatch-layer
    environment fingerprint and the git revision, so trajectory points from
    different machines/commits are distinguishable instead of silently
    comparable.  A ``--quick`` run (the CI smoke) only refreshes the
    ``smoke`` section of the existing payload: it proves the plans still run
    without replacing a real trajectory point with 2-layer/3-iter noise or
    growing ``history`` on every CI invocation."""
    path = Path(__file__).resolve().parent.parent / "BENCH_conv.json"
    old = None
    if path.exists():
        try:
            old = json.loads(path.read_text())
        except json.JSONDecodeError:
            old = None
        if not isinstance(old, dict):
            old = None
    run = {
        "backend": jax.default_backend(),
        "sparsity": SPARSITY,
        "v": V,
        "band_hb": BAND_HB,
        "iters": iters,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_rev": _git_rev(),
        "fingerprint": env_fingerprint(),
        "layers": results,
    }
    if quick and old is not None and "layers" in old:
        old["smoke"] = run
        payload = old
        note = "refreshed smoke section"
    else:
        history = []
        if old is not None:
            history = old.pop("history", [])
            old.pop("smoke", None)
            history.append(old)
        history = history[-HISTORY_CAP:]
        payload = dict(run, history=history)
        note = f"{len(history)} prior run(s) kept in history"
    path.write_text(json.dumps(payload, indent=1))
    print(f"wrote {path} ({note})")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="append to BENCH_conv.json (perf trajectory artifact)")
    ap.add_argument("--quick", action="store_true",
                    help="two deepest layers, 3 iters (CI smoke)")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args(argv)

    iters = args.iters if args.iters is not None else (3 if args.quick else 5)
    results = measure(iters=iters, quick=args.quick)
    for name, entry in results.items():
        for plan, _fn, _nf in PLANS:
            if plan in entry:
                print(row(f"conv_fused.{name}.{plan}", entry[plan]))
        print(row(f"conv_fused.{name}.speedup", 0.0, " ".join(
            f"{p}_vs_two_kernel={entry[f'{p}_speedup_vs_two_kernel']:.2f}x"
            for p in ("banded", "pipelined")
            if f"{p}_speedup_vs_two_kernel" in entry)))
    if args.json:
        _write_json(results, iters, quick=args.quick)


if __name__ == "__main__":
    main()
