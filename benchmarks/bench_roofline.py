"""Roofline summary (assignment deliverable g): per (arch × shape × mesh)
terms from the dry-run artifacts as CSV rows."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.timing import row


def run(art_dir: str = "artifacts/dryrun"):
    try:
        from benchmarks.report import load
    except Exception:
        return [row("roofline.unavailable", 0.0, "run repro.launch.dryrun first")]
    out = []
    for mesh, sp in [("pod16x16", 50), ("pod16x16", 0), ("pod2x16x16", 50)]:
        for (arch, shape), rec in sorted(load(mesh, sp).items()):
            if "roofline" not in rec:
                continue
            r = rec["roofline"]
            out.append(
                row(
                    f"roofline.{mesh}.s{sp}.{arch}.{shape}",
                    1e6 * max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]),
                    f"bottleneck={r['bottleneck']} frac={r['roofline_fraction']:.4f} "
                    f"tc={r['t_compute_s']:.4g} tm={r['t_memory_s']:.4g} "
                    f"tcoll={r['t_collective_s']:.4g}",
                )
            )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
