"""Paper Fig. 9: the LMUL sweep, adapted to TPU block geometry.

RVV's LMUL multiplies the effective vector width; the TPU analog is the
kernel's block/tile widths.  Two sweeps:
  (a) strip width V of the fused im2col+pack (data-movement efficiency vs
      boundary handling — exactly the paper's trade-off), and
  (b) pruning-tile width T of the column-wise sparse GEMM (accumulator
      footprint vs gather amortization).
Host wall-clock; the analytic VMEM footprint of the Pallas kernel per
(block_b, block_k, T) is reported alongside (the register-pressure analog).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.timing import row, time_fn
from repro.core import SparsityConfig, colwise_nm_mask, meta_for, pack_colwise
from repro.kernels.colwise_nm.kernel import vmem_bytes
from repro.kernels.im2col_pack.ref import im2col_pack_ref


def run(iters: int = 10):
    out = []
    # (a) strip width sweep on a ResNet stage-2 3x3 layer
    c, h, k = 128, 28, 3
    x = jax.random.normal(jax.random.PRNGKey(0), (c, 1, h, h))
    for v in [64, 128, 256, 512, 1024]:
        f = jax.jit(lambda x, v=v: im2col_pack_ref(x, k, k, 1, 1, v))
        t = time_fn(f, x, iters=iters)
        out.append(row(f"fig9.pack.V{v}", t, "strip-width (LMUL analog)"))

    # (b) tile width sweep on a transformer FFN GEMM (4096 tokens)
    d_in, d_out, tokens, s = 2048, 2048, 4096, 0.5
    xt = jax.random.normal(jax.random.PRNGKey(1), (tokens, d_in))
    w = jax.random.normal(jax.random.PRNGKey(2), (d_in, d_out)) / 45.0
    for tile in [32, 128, 512, 2048]:
        cfg = SparsityConfig(s, m=None, tile=tile, format="compressed_xla")
        meta = meta_for(d_in, d_out, cfg)
        mask = colwise_nm_mask(w, s, tile=meta.tile)
        values, idx = pack_colwise(w, mask, meta)

        def f(x, values=values, idx=idx):
            xg = jnp.take(x, idx, axis=-1)
            return jnp.einsum("ptk,tkf->ptf", xg, values)

        t = time_fn(jax.jit(f), xt, iters=iters)
        vm = vmem_bytes(block_b=128, block_k=128, d_in=d_in, tile=min(tile, 512))
        out.append(row(f"fig9.gemm.T{tile}", t, f"pallas_vmem_per_step={vm}B"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
