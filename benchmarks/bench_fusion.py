"""Paper Fig. 6/7/8: fused im2col+packing vs the two-pass baseline.

Wall time (Fig. 6 analog), analytic bytes moved (Fig. 7's L1-loads analog;
no hardware counters in a dry-run container), and the Fig. 8 breakdown
(im2col only / unfused / fused).
"""
from __future__ import annotations

import jax

from benchmarks.timing import row, time_fn
from repro.kernels.im2col_pack.ops import (
    bytes_moved_fused,
    bytes_moved_unfused,
    im2col_only,
    im2col_then_pack,
)
from repro.kernels.im2col_pack.ref import im2col_pack_ref, out_size

# 7x7-stem + the 3x3 layers of each ResNet-50 stage (the layers the paper
# evaluates — largest im2col overhead).  Batch 4 keeps the working set out of
# the LLC so the data-movement difference is visible in wall time (the bytes
# model — the L1-loads analog — is reported regardless).
LAYERS = [
    ("stem7x7", 3, 224, 7, 2),
    ("s1.3x3", 64, 56, 3, 1),
    ("s2.3x3", 128, 28, 3, 1),
    ("s3.3x3", 256, 14, 3, 1),
    ("s4.3x3", 512, 7, 3, 1),
]
BATCH = 4


def run(iters: int = 10, v: int = 128):
    out = []
    for name, c, h, k, stride in LAYERS:
        pad = k // 2 if k > 1 else 0
        x = jax.random.normal(jax.random.PRNGKey(0), (c, BATCH, h, h))
        ho = out_size(h, k, stride, pad)

        fused = jax.jit(
            lambda x, k=k, stride=stride, pad=pad: im2col_pack_ref(x, k, k, stride, pad, v)
        )
        t_fused = time_fn(fused, x, iters=iters)
        t_unfused = time_fn(
            lambda x: im2col_then_pack(x, kh=k, kw=k, stride=stride, pad=pad, v=v),
            x, iters=iters,
        )
        t_im2col = time_fn(
            lambda x: im2col_only(x, kh=k, kw=k, stride=stride, pad=pad), x, iters=iters
        )
        bf = bytes_moved_fused(c, BATCH, h, h, k, k, ho, ho, v, 4)
        bu = bytes_moved_unfused(c, BATCH, h, h, k, k, ho, ho, v, 4)
        out.append(row(f"fig6.{name}.fused", t_fused, f"speedup={t_unfused/t_fused:.2f}x"))
        out.append(row(f"fig6.{name}.unfused", t_unfused, ""))
        out.append(row(f"fig8.{name}.im2col_only", t_im2col, ""))
        out.append(
            row(f"fig7.{name}.bytes", 0.0,
                f"fused={bf} unfused={bu} reduction={100*(1-bf/bu):.0f}%")
        )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
