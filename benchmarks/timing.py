"""Wall-clock timing helper for the benchmark harness.

Every bench that times through :func:`time_fn` with a ``name`` emits the
same obs trace schema — a ``bench.<name>`` span whose closing event carries
the median ``wall_us`` plus a ``bench.<name>.us`` gauge — so a single
``--trace`` run of the harness produces one uniformly-shaped Perfetto
timeline across all benchmark modules (no-ops while REPRO_OBS is off).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.obs import metrics as _om
from repro.obs import trace as _ot


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3,
            name: Optional[str] = None) -> float:
    """Median wall time per call in microseconds (blocks on device results).

    With ``name``, the measurement loop runs inside a ``bench.<name>`` obs
    span and the median is recorded on a ``bench.<name>.us`` gauge.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    with _ot.span(f"bench.{name}" if name else "bench.time_fn",
                  iters=iters) as sp:
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            times.append(time.perf_counter() - t0)
        times.sort()
        med_us = times[len(times) // 2] * 1e6
        sp.set(wall_us=round(med_us, 1))
    if name:
        _om.gauge(f"bench.{name}.us").set(med_us)
    return med_us


def row(name: str, us: float, derived: str = "") -> str:
    """One CSV result line; also mirrored onto a ``bench.<name>.us`` gauge
    and a ``bench.row`` instant so trace files carry the table contents."""
    _om.gauge(f"bench.{name}.us").set(us)
    _ot.instant("bench.row", bench=name, us=round(us, 1), derived=derived)
    return f"{name},{us:.1f},{derived}"
