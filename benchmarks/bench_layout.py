"""Paper Fig. 12 / §5: CNHW vs NHWC layout for the im2col data path.

CNHW keeps W contiguous so strips move with long contiguous reads (the
paper's layout choice); NHWC interleaves channels, so forming the same
(k,c)-major patch matrix strides across memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.timing import row, time_fn
from repro.kernels.im2col_pack.ref import im2col_pack_ref


def im2col_pack_nhwc(x_nhwc, kh, kw, stride, pad, v):
    """Same output as the CNHW path, starting from an NHWC feature map."""
    x = jnp.transpose(x_nhwc, (3, 0, 1, 2))  # materialized transpose = the cost
    return im2col_pack_ref(x, kh, kw, stride, pad, v)


def run(iters: int = 10):
    out = []
    for name, c, h, k, stride, bsz in [
        ("s1.3x3.b1", 64, 56, 3, 1, 1),
        ("s2.3x3.b1", 128, 28, 3, 1, 1),
        ("s2.3x3.b4", 128, 28, 3, 1, 4),
    ]:
        pad = 1
        x_cnhw = jax.random.normal(jax.random.PRNGKey(0), (c, bsz, h, h))
        x_nhwc = jnp.transpose(x_cnhw, (1, 2, 3, 0))
        f_c = jax.jit(lambda x, k=k, s=stride, p=pad: im2col_pack_ref(x, k, k, s, p, 128))
        f_n = jax.jit(lambda x, k=k, s=stride, p=pad: im2col_pack_nhwc(x, k, k, s, p, 128))
        t_c = time_fn(f_c, x_cnhw, iters=iters)
        t_n = time_fn(f_n, x_nhwc, iters=iters)
        out.append(row(f"fig12.{name}.cnhw", t_c, f"speedup={t_n/t_c:.2f}x"))
        out.append(row(f"fig12.{name}.nhwc", t_n, ""))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
