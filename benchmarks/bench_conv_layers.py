"""Paper Fig. 5 + Fig. 10: per-conv-layer inference time — dense vs
conventional (row-wise, outer-product) N:M vs column-wise N:M.

ResNet-50's representative layer shapes (ImageNet, batch 1).  All three
configurations share the fused im2col+packing front (as in the paper); only
the GEMM differs:
  dense        — full [O, K] x [K, P] matmul
  conventional — row-wise N:M: every output row gathers its own kept columns
                 (the redundant-load pattern of paper §3.1)
  column-wise  — tile-shared kept columns: one gather per tile, dense MXU
                 matmul (the paper's method; XLA path of our kernel)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.timing import row, time_fn
from repro.core import SparsityConfig, colwise_nm_mask, meta_for, pack_colwise, rowwise_nm_mask
from repro.kernels.im2col_pack.ref import im2col_pack_ref, out_size

# (name, C_in, H, C_out, kh, stride)  — ResNet-50 stages, batch 1
LAYERS = [
    ("stem", 3, 224, 64, 7, 2),
    ("s1.c1", 64, 56, 64, 1, 1),
    ("s1.c2", 64, 56, 64, 3, 1),
    ("s1.c3", 64, 56, 256, 1, 1),
    ("s2.c2", 128, 28, 128, 3, 1),
    ("s3.c2", 256, 14, 256, 3, 1),
    ("s4.c2", 512, 7, 512, 3, 1),
]

SPARSITY = 0.5
V = 128


def _packed(c, h, k, stride):
    """Packed data matrix in the PAPER's layout: rows = reduction dim K,
    columns = output positions (strips flattened back to P)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (c, 1, h, h))
    pad = k // 2 if k > 1 else 0
    strips = im2col_pack_ref(x, k, k, stride, pad, V)  # [S, K, V]
    return strips.transpose(1, 0, 2).reshape(k * k * c, -1)  # [K, P]


def run(iters: int = 10):
    out = []
    for name, c, h, o, k, stride in LAYERS:
        key = jax.random.PRNGKey(1)
        kdim = k * k * c
        xT = _packed(c, h, k, stride)  # [K, P] — rows are contiguous vectors
        w = jax.random.normal(key, (kdim, o)) / jnp.sqrt(kdim)

        dense = jax.jit(lambda xT, w: jnp.einsum("kp,kf->pf", xT, w))
        t_dense = time_fn(dense, xT, w, iters=iters)

        # column-wise N:M (paper Alg. 1): the kept-column indices are shared
        # across the output tile, so the kernel gathers each kept *row* of the
        # packed matrix once (a contiguous vector load) and reuses it for all
        # T accumulators — here realized as one row-gather + dense GEMM.
        cfg = SparsityConfig(SPARSITY, m=None, tile=None, format="compressed_xla")
        meta = meta_for(kdim, o, cfg)
        mask = colwise_nm_mask(w, SPARSITY, tile=meta.tile)
        values, idx = pack_colwise(w, mask, meta)

        def colwise(xT, values=values, idx=idx):
            xg = jnp.take(xT, idx[0], axis=0)  # contiguous row gather, once
            return jnp.einsum("kp,kf->pf", xg, values[0])

        t_col = time_fn(jax.jit(colwise), xT, iters=iters)

        # conventional row-wise N:M, outer-product execution: every output
        # row has its own kept indices -> per-output gather (the redundant
        # loads of paper §3.1; the paper measures up to 5.4x slowdown)
        rmask = rowwise_nm_mask(w, SPARSITY, m=4)
        kk = int(kdim * (1 - SPARSITY))
        ridx = jnp.argsort(~rmask, axis=0, stable=True)[:kk].T  # [O, kk]
        rvals = jnp.take_along_axis(w.T, ridx, axis=1)  # [O, kk]

        def rowwise(xT, ridx=ridx, rvals=rvals):
            xg = jnp.take(xT, ridx, axis=0)  # [O, kk, P] — the redundant loads
            return jnp.einsum("okp,ok->po", xg, rvals)

        t_row = time_fn(jax.jit(rowwise), xT, iters=iters)

        out.append(row(f"fig5.{name}.dense", t_dense, f"P={xT.shape[1]} K={kdim} O={o}"))
        out.append(row(f"fig5.{name}.rownm", t_row, f"slowdown={t_row/t_dense:.2f}x"))
        out.append(row(f"fig5.{name}.colwise", t_col, f"speedup={t_dense/t_col:.2f}x"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
