"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSON artifacts.

MODEL_FLOPS is recomputed live from the configs (the stored value predates an
active-param accounting fix), and the derived ratios are refreshed from the
stored per-chip terms.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import PEAK_FLOPS, model_flops_for

ART = Path("artifacts/dryrun")


def refresh_roofline(rec: Dict) -> Dict:
    """Recompute model_flops-derived fields from the live config."""
    r = rec.get("roofline")
    if not r:
        return rec
    cfg = get_config(rec["arch"])
    cell = SHAPES[rec["shape"]]
    mf = model_flops_for(cfg, cell, rec.get("sparsity", 0.0))
    r["model_flops"] = mf
    total = r["flops_per_chip"] * r["chips"]
    r["useful_flops_ratio"] = mf / total if total else 0.0
    t_bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
    ideal = mf / r["chips"] / PEAK_FLOPS
    r["roofline_fraction"] = ideal / t_bound if t_bound else 0.0
    return rec

ARCH_ORDER = [
    "olmoe-1b-7b", "moonshot-v1-16b-a3b", "smollm-360m", "qwen2-0.5b",
    "qwen2-7b", "nemotron-4-15b", "xlstm-350m", "qwen2-vl-72b",
    "whisper-small", "zamba2-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, sparsity: int, tag: str = "") -> Dict[str, Dict]:
    out = {}
    for p in ART.glob(f"*__{mesh}__s{sparsity}{tag}.json"):
        if ".err" in p.name:
            continue
        rec = json.loads(p.read_text())
        out[(rec["arch"], rec["shape"])] = refresh_roofline(rec)
    return out


def fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def fmt_b(x: Optional[float]) -> str:
    if x is None:
        return "—"
    for unit, div in [("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)]:
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def roofline_table(recs: Dict, caption: str) -> List[str]:
    lines = [
        f"\n### {caption}\n",
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | "
        "MODEL_FLOPS/HLO | roofline frac | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        "compute": "shrink HLO FLOPs: higher sparsity realization, drop remat recompute",
        "memory": "cut HBM traffic: fuse gathers into matmuls, wider fusion, bf16 master",
        "collective": "reshard: shard-local gathers for reduce-dim sparse layers, overlap",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                continue
            if "skipped" in rec:
                lines.append(f"| {arch} | {shape} | — | — | — | skipped | — | — | {rec['skipped'][:60]} |")
                continue
            if "roofline" not in rec:
                lines.append(f"| {arch} | {shape} | ERR | | | | | | {rec.get('error','')[:60]} |")
                continue
            r = rec["roofline"]
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
                f"| {fmt_s(r['t_collective_s'])} | **{r['bottleneck']}** "
                f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.3f} "
                f"| {fixes[r['bottleneck']]} |"
            )
    return lines


def dryrun_table(recs: Dict, caption: str) -> List[str]:
    lines = [
        f"\n### {caption}\n",
        "| arch | shape | HLO FLOPs/chip | HBM bytes/chip | collective bytes/chip | "
        "top collectives | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None or "skipped" in rec or "roofline" not in rec:
                continue
            r = rec["roofline"]
            coll = rec.get("collectives", {}).get("bytes", {})
            top = sorted(coll.items(), key=lambda kv: -kv[1])[:2]
            tops = ", ".join(f"{k}:{fmt_b(v)}" for k, v in top) or "none"
            lines.append(
                f"| {arch} | {shape} | {r['flops_per_chip']:.2e} | "
                f"{fmt_b(r['hlo_bytes_per_chip'])} | {fmt_b(r['collective_bytes_per_chip'])} "
                f"| {tops} | {rec.get('compile_seconds', 0):.0f}s |"
            )
    return lines


def compare_table(base: Dict, opt: Dict, caption: str) -> List[str]:
    lines = [
        f"\n### {caption}\n",
        "| arch | shape | bound (base) | bound (opt) | speedup | bottleneck base→opt | frac base→opt |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rb, ro = base.get((arch, shape)), opt.get((arch, shape))
            if not rb or not ro or "roofline" not in rb or "roofline" not in ro:
                continue
            b, o = rb["roofline"], ro["roofline"]
            tb = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
            to = max(o["t_compute_s"], o["t_memory_s"], o["t_collective_s"])
            lines.append(
                f"| {arch} | {shape} | {fmt_s(tb)} | {fmt_s(to)} | **{tb/to:.2f}×** "
                f"| {b['bottleneck']}→{o['bottleneck']} "
                f"| {b['roofline_fraction']:.3f}→{o['roofline_fraction']:.3f} |"
            )
    return lines


def deployed_table(base: Dict, opt: Dict, caption: str) -> List[str]:
    """Per-cell best-of selection — the §3.3 tuner's profile-and-pick applied
    at configuration granularity. Feasibility guard: a config whose
    memory_analysis temps exceed 16 GB/chip cannot deploy regardless of its
    roofline bound (naive 32k prefill)."""
    HBM = 16e9
    lines = [
        f"\n### {caption}\n",
        "| arch | shape | deployed config | bound | temp GB/chip |",
        "|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            cands = []
            for name, rec in (("paper-faithful", base.get((arch, shape))),
                              ("optimized", opt.get((arch, shape)))):
                if not rec or "roofline" not in rec:
                    continue
                r = rec["roofline"]
                t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
                temp = (rec.get("memory_analysis") or {}).get("temp_size_in_bytes") or 0
                feasible = float(temp or 0) <= HBM
                cands.append((not feasible, t, name, temp))
            if not cands:
                continue
            cands.sort()
            infeas, t, name, temp = cands[0]
            note = "" if not infeas else " ⚠ exceeds HBM"
            lines.append(
                f"| {arch} | {shape} | {name}{note} | {fmt_s(t)} | "
                f"{float(temp or 0)/1e9:.1f} |"
            )
    return lines


def metrics_table(snapshot: Dict, caption: str = "Obs metrics") -> List[str]:
    """Render a ``repro.obs`` metrics snapshot ({counters, gauges,
    histograms}) as a markdown table; histograms show count + p50/p99."""
    lines = [f"\n### {caption}\n",
             "| metric | kind | value |", "|---|---|---|"]
    for name in sorted(snapshot.get("counters", {})):
        lines.append(f"| {name} | counter | {snapshot['counters'][name]:g} |")
    for name in sorted(snapshot.get("gauges", {})):
        lines.append(f"| {name} | gauge | {snapshot['gauges'][name]:g} |")
    for name in sorted(snapshot.get("histograms", {})):
        h = snapshot["histograms"][name]
        lines.append(f"| {name} | histogram | n={h['count']} "
                     f"p50={h['p50']:.4g} p99={h['p99']:.4g} |")
    return lines


def conv_trajectory_table(path: Path = Path("BENCH_conv.json")) -> List[str]:
    """Render the conv perf-trajectory artifact: one row per recorded run
    (history oldest-first, current run last) with per-layer fused/two_kernel
    timings, stamped with timestamp + git rev + backend fingerprint."""
    if not path.exists():
        return []
    try:
        cur = json.loads(path.read_text())
    except json.JSONDecodeError:
        return []
    if not isinstance(cur, dict) or "layers" not in cur:
        return []
    runs = [r for r in cur.get("history", []) if isinstance(r, dict)] + [cur]
    lines = [
        "\n### Conv plan trajectory (BENCH_conv.json)\n",
        "| timestamp | git rev | backend | layer | fused µs | banded µs | "
        "two_kernel µs | xla µs |",
        "|---|---|---|---|---|---|---|---|",
    ]

    def us(entry, plan):
        v = entry.get(plan)
        return f"{v:.0f}" if isinstance(v, (int, float)) else "—"

    for r in runs:
        ts = r.get("timestamp", "?")
        rev = r.get("git_rev", "?")
        backend = r.get("backend", "?")
        for layer, entry in sorted(r.get("layers", {}).items()):
            lines.append(
                f"| {ts} | {rev} | {backend} | {layer} "
                f"| {us(entry, 'fused')} | {us(entry, 'banded')} "
                f"| {us(entry, 'two_kernel')} | {us(entry, 'xla')} |")
    return lines


def main():
    sp = load("pod16x16", 50)
    mp = load("pod2x16x16", 50)
    dense = load("pod16x16", 0)
    opt = load("pod16x16", 50, tag="_opt")
    out = ["<!-- AUTOGENERATED by benchmarks/report.py — do not hand-edit tables -->"]
    out += dryrun_table(sp, "Dry-run, single pod (16×16), column-wise N:M 50% (paper-faithful)")
    out += roofline_table(sp, "Roofline, single pod (16×16), sparse 50% (paper-faithful baseline)")
    if opt:
        out += roofline_table(opt, "Roofline, single pod, sparse 50% OPTIMIZED "
                                   "(chunked attention + shard-local reduce + grouped MoE + decode restructure)")
        out += compare_table(sp, opt, "Baseline → optimized, per-cell step-time bound")
        out += deployed_table(sp, opt, "Deployed configuration per cell "
                                       "(tuner-style best-of, HBM-feasibility-guarded)")
    if dense:
        out += roofline_table(dense, "Roofline, single pod (16×16), dense baseline")
    if mp:
        out += dryrun_table(mp, "Dry-run, multi-pod (2×16×16) — proves the pod axis shards")
    out += conv_trajectory_table()
    print("\n".join(out))


if __name__ == "__main__":
    main()
