"""Paper Table 2 / Fig. 11: end-to-end throughput across sparsity levels and
batch sizes (host CPU, reduced config — the production numbers come from the
roofline artifacts).

Decode tokens/s via the serving engine and train-step wall time, for dense vs
column-wise compressed at 25/50/75% sparsity, batch sizes 1/2/4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import row, time_fn
from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.models import registry as reg
from repro.serve import Engine, ServeConfig


def _cfg(sparsity: float):
    scfg = SparsityConfig(
        sparsity=sparsity, m=None, tile=64,
        format="compressed_xla" if sparsity > 0 else "dense", min_dim=64,
    )
    return smoke_config("qwen2-7b").with_(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=1024, vocab_size=512, sparsity=scfg,
    )


def run(new_tokens: int = 16):
    out = []
    for sparsity in (0.0, 0.25, 0.5, 0.75):
        cfg = _cfg(sparsity)
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        tag = f"s{int(sparsity*100)}"
        for b in (1, 2, 4):
            eng = Engine(cfg, params, ServeConfig(max_new_tokens=new_tokens))
            prompts = np.ones((b, 8), np.int32)
            eng.generate(prompts)  # warm
            res = eng.generate(prompts)
            out.append(
                row(f"table2.decode.{tag}.b{b}",
                    1e6 * res["decode_s"] / max(new_tokens - 1, 1),
                    f"tok_s={res['decode_tok_s']:.1f}")
            )
        # train step (fig 11 analog)
        lfn = reg.loss_fn(cfg)

        @jax.jit
        def tstep(p, batch):
            (l, _), g = jax.value_and_grad(lfn, has_aux=True, allow_int=True)(p, batch)
            return l

        batch = {"tokens": jnp.ones((4, 128), jnp.int32)}
        t = time_fn(tstep, params, batch, iters=5)
        out.append(row(f"fig11.train.{tag}", t, "fwd+bwd b=4 s=128"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
