"""Paper Table 1 (proxy): eval quality across pruning patterns.

ImageNet/ResNet is out of scope for a CPU-only container; this reproduces the
paper's *ordering* claims on a small LM over learnable bigram data:
  (1) row-wise N:M (T=1) is the accuracy upper bound among fixed-M patterns,
  (2) adding the column-wise constraint at fixed M costs accuracy,
  (3) growing M to the full reduction dim (adaptive) recovers it,
  (4) quality degrades with sparsity.
Protocol mirrors the paper: train dense -> one-shot prune -> finetune with
the mask held fixed -> eval NLL (lower is better).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import row
from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig, prune_tree
from repro.data import DataConfig, SyntheticLM
from repro.models import registry as reg
from repro.optim import AdamWConfig, adamw_init, adamw_update

VOCAB = 128


def _cfg():
    return smoke_config("smollm-360m").with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=VOCAB, tie_embeddings=False,
    )


def _is_body_weight(path, leaf):
    keys = jax.tree_util.keystr(path)
    return "embed" not in keys


def _train(cfg, params, data, steps, lr, mask_tree=None, start=0):
    lfn = reg.loss_fn(cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=lr, weight_decay=0.01)

    @jax.jit
    def step(params, opt, batch):
        (l, _), g = jax.value_and_grad(lfn, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
        return params, opt, l

    mask_apply = None
    if mask_tree is not None:
        @jax.jit
        def mask_apply(params):
            return jax.tree_util.tree_map(
                lambda p, m: p * m.astype(p.dtype) if m is not None else p,
                params, mask_tree, is_leaf=lambda x: x is None,
            )

    loss = None
    for k in range(steps):
        batch = {kk: jnp.asarray(v) for kk, v in data.batch_at(start + k).items()}
        params, opt, loss = step(params, opt, batch)
        if mask_apply is not None:
            params = mask_apply(params)  # projection keeps the support fixed
    return params, float(loss)


def _eval(cfg, params, data, n=8, start=100000):
    lfn = jax.jit(lambda p, b: reg.loss_fn(cfg)(p, b)[0])
    losses = [
        float(lfn(params, {k: jnp.asarray(v) for k, v in data.batch_at(start + i).items()}))
        for i in range(n)
    ]
    return float(np.mean(losses))


def run(dense_steps: int = 120, ft_steps: int = 60):
    cfg = _cfg()
    data = SyntheticLM(DataConfig(vocab_size=VOCAB, batch=16, seq_len=48, seed=11))
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    params, _ = _train(cfg, params, data, dense_steps, 3e-3)
    dense_eval = _eval(cfg, params, data)
    out = [row("table1.dense", 0.0, f"eval_nll={dense_eval:.4f}")]

    variants = {
        "row_m4_T1": dict(m=4, tile=1, scheme="rowwise"),
        "col_m4_T8": dict(m=4, tile=8, scheme="colwise"),
        "col_adaptiveM_T8": dict(m=None, tile=8, scheme="colwise"),
        "col_adaptiveM_Tfull": dict(m=None, tile=None, scheme="colwise"),
    }
    for sparsity in (0.25, 0.5, 0.75):
        for name, kw in variants.items():
            scfg = SparsityConfig(sparsity=sparsity, format="masked", min_dim=64, **kw)
            pruned, masks = prune_tree(params, scfg, is_weight=_is_body_weight)
            nll0 = _eval(cfg, pruned, data)
            tuned, _ = _train(cfg, pruned, data, ft_steps, 1e-3,
                              mask_tree=masks, start=dense_steps)
            nll = _eval(cfg, tuned, data)
            out.append(
                row(f"table1.s{int(sparsity*100)}.{name}", 0.0,
                    f"eval_nll={nll:.4f} oneshot={nll0:.4f} dense={dense_eval:.4f}")
            )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
