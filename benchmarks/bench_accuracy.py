"""Paper Table 1 (proxy): eval quality across pruning patterns.

ImageNet/ResNet is out of scope for a CPU-only container; this reproduces the
paper's *ordering* claims on a small LM over learnable bigram data:
  (1) row-wise N:M (T=1) is the accuracy upper bound among fixed-M patterns,
  (2) adding the column-wise constraint at fixed M costs accuracy,
  (3) growing M to the full reduction dim (adaptive) recovers it,
  (4) quality degrades with sparsity.
Protocol mirrors the paper: train dense -> one-shot prune -> finetune with
the mask held fixed -> eval NLL (lower is better).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import row
from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig, prune_tree
from repro.data import DataConfig, SyntheticLM
from repro.models import registry as reg
from repro.optim import AdamWConfig, adamw_init, adamw_update

VOCAB = 128


def _cfg():
    return smoke_config("smollm-360m").with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=VOCAB, tie_embeddings=False,
    )


def _is_body_weight(path, leaf):
    keys = jax.tree_util.keystr(path)
    return "embed" not in keys


def _train(cfg, params, data, steps, lr, mask_tree=None, start=0):
    lfn = reg.loss_fn(cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=lr, weight_decay=0.01)

    @jax.jit
    def step(params, opt, batch):
        (l, _), g = jax.value_and_grad(lfn, has_aux=True)(params, batch)
        params, opt, _ = adamw_update(params, g, opt, ocfg)
        return params, opt, l

    mask_apply = None
    if mask_tree is not None:
        @jax.jit
        def mask_apply(params):
            return jax.tree_util.tree_map(
                lambda p, m: p * m.astype(p.dtype) if m is not None else p,
                params, mask_tree, is_leaf=lambda x: x is None,
            )

    loss = None
    for k in range(steps):
        batch = {kk: jnp.asarray(v) for kk, v in data.batch_at(start + k).items()}
        params, opt, loss = step(params, opt, batch)
        if mask_apply is not None:
            params = mask_apply(params)  # projection keeps the support fixed
    return params, float(loss)


def _eval(cfg, params, data, n=8, start=100000):
    lfn = jax.jit(lambda p, b: reg.loss_fn(cfg)(p, b)[0])
    losses = [
        float(lfn(params, {k: jnp.asarray(v) for k, v in data.batch_at(start + i).items()}))
        for i in range(n)
    ]
    return float(np.mean(losses))


def run_conv(dense_steps: int = 160, ft_steps: int = 60, iters=None,
             batch: int = 16):
    """Conv cell (paper's actual accuracy protocol, proxy scale): dense
    resnet-tiny train -> one-shot column-wise prune -> masked finetune
    *through the sparse-conv backward* -> compress -> compressed-inference
    accuracy, on a learnable synthetic task.  Reports the dense->compressed
    accuracy delta — the conv twin of the Table-1 ordering cells.

    ``iters`` (the --quick knob) shrinks the step counts.
    """
    import jax

    from repro.configs import get_vision_config
    from repro.core import DENSE, compress_conv_tree, prune_conv_tree, unbox_tree
    from repro.models import vision

    if iters is not None:
        dense_steps, ft_steps = 4 * int(iters), 3 * int(iters)
    cfg = get_vision_config("resnet-tiny")
    scfg = cfg.sparsity.with_(format="masked")
    dense_cfg = cfg.with_(sparsity=DENSE)

    params, _ = unbox_tree(vision.vision_init(dense_cfg, jax.random.PRNGKey(0)))
    step = jax.jit(lambda p, m, x, y: vision.train_step(p, m, dense_cfg, x, y,
                                                        lr=0.05))

    def train(params, steps, start):
        mom = vision.sgd_init(params)
        loss = float("nan")
        for k in range(steps):
            x, y = vision.synth_batch(cfg, jax.random.PRNGKey(1000 + start + k),
                                      batch)
            params, mom, loss = step(params, mom, x, y)
        return params, float(loss)

    def accuracy(params, n=4):
        accs = []
        for i in range(n):
            x, y = vision.synth_batch(cfg, jax.random.PRNGKey(777 + i), batch)
            accs.append(vision.vision_accuracy(params, cfg, x, y))
        return float(np.mean(accs))

    params, _ = train(params, dense_steps, 0)
    dense_acc = accuracy(params)
    out = [row("conv.dense", 0.0, f"acc={dense_acc:.3f}")]

    pruned = prune_conv_tree(params, scfg)
    oneshot_acc = accuracy(pruned)
    tuned, _ = train(pruned, ft_steps, dense_steps)
    ft_acc = accuracy(tuned)
    out.append(row("conv.masked_ft", 0.0,
                   f"acc={ft_acc:.3f} oneshot={oneshot_acc:.3f}"))

    # compress every masked conv layer (stored mask pins the support) and
    # run compressed inference — the deployment format's accuracy
    comp_params = compress_conv_tree(
        tuned, scfg.with_(format="compressed_pallas"))
    comp_acc = accuracy(comp_params)
    out.append(row(
        "conv.compressed", 0.0,
        f"acc={comp_acc:.3f} delta_vs_dense={dense_acc - comp_acc:+.3f} "
        f"delta_vs_masked={ft_acc - comp_acc:+.3f}"))
    return out


def run_train_resume(steps: int = 8, iters=None, batch: int = 4):
    """Crash-safe training row: what checkpointing costs and what resuming
    loses.  Three SparseTrainer runs of the same masked-finetune config:
    no checkpoints (baseline wall), ckpt_every=1 (overhead %), and an
    interrupted run (stop at steps/2, fresh process resumes to the budget).
    The resume-determinism contract makes the third bitwise identical to the
    first, so the reported accuracy delta is asserted to be exactly 0."""
    import tempfile

    import jax

    from repro.models import vision
    from repro.train import SparseTrainConfig, SparseTrainer

    if iters is not None:
        steps = max(4, int(iters))

    def mk(ckpt_dir=None):
        return SparseTrainer(SparseTrainConfig(
            steps=steps, batch=batch, lr=0.05,
            ckpt_dir=ckpt_dir, ckpt_every=1 if ckpt_dir else 0))

    def accuracy(tr, n=4):
        vals = []
        for i in range(n):
            x, y = vision.synth_batch(tr.cfg, jax.random.PRNGKey(777 + i),
                                      batch)
            vals.append(vision.vision_accuracy(tr.params, tr.cfg, x, y))
        return float(np.mean(vals))

    def per_step_s(out):
        # drop step 0: it carries the jit compile, not the steady state
        ss = [h["sec_per_step"] for h in out["history"][1:]]
        return float(np.mean(ss)) if ss else float("nan")

    base = mk()
    t_base = per_step_s(base.run())

    with tempfile.TemporaryDirectory() as d:
        ck = mk(d)
        t_ck = per_step_s(ck.run())
    overhead_pct = 100.0 * (t_ck - t_base) / t_base

    with tempfile.TemporaryDirectory() as d:
        mk(d).run(steps // 2)   # "crash" after half the budget
        resumed = mk(d)
        out = resumed.run()     # fresh process: restore + finish
    assert out["start_step"] == steps // 2
    identical = all(
        np.asarray(a).tobytes() == np.asarray(b).tobytes()
        for a, b in zip(jax.tree_util.tree_leaves(base.params),
                        jax.tree_util.tree_leaves(resumed.params)))
    assert identical, "resumed params diverged from the uninterrupted run"
    delta = accuracy(base) - accuracy(resumed)
    assert delta == 0.0, f"resume changed accuracy by {delta}"
    return [
        row("train_resume.ckpt_overhead", t_ck * 1e6,
            f"overhead_pct={overhead_pct:+.1f} base_us={t_base * 1e6:.0f}"),
        row("train_resume.resumed", t_ck * 1e6,
            f"acc_delta={delta:+.4f} bitwise_identical={identical} "
            f"resumed_at={steps // 2} budget={steps}"),
    ]


def run(dense_steps: int = 120, ft_steps: int = 60):
    cfg = _cfg()
    data = SyntheticLM(DataConfig(vocab_size=VOCAB, batch=16, seq_len=48, seed=11))
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    params, _ = _train(cfg, params, data, dense_steps, 3e-3)
    dense_eval = _eval(cfg, params, data)
    out = [row("table1.dense", 0.0, f"eval_nll={dense_eval:.4f}")]

    variants = {
        "row_m4_T1": dict(m=4, tile=1, scheme="rowwise"),
        "col_m4_T8": dict(m=4, tile=8, scheme="colwise"),
        "col_adaptiveM_T8": dict(m=None, tile=8, scheme="colwise"),
        "col_adaptiveM_Tfull": dict(m=None, tile=None, scheme="colwise"),
    }
    for sparsity in (0.25, 0.5, 0.75):
        for name, kw in variants.items():
            scfg = SparsityConfig(sparsity=sparsity, format="masked", min_dim=64, **kw)
            pruned, masks = prune_tree(params, scfg, is_weight=_is_body_weight)
            nll0 = _eval(cfg, pruned, data)
            tuned, _ = _train(cfg, pruned, data, ft_steps, 1e-3,
                              mask_tree=masks, start=dense_steps)
            nll = _eval(cfg, tuned, data)
            out.append(
                row(f"table1.s{int(sparsity*100)}.{name}", 0.0,
                    f"eval_nll={nll:.4f} oneshot={nll0:.4f} dense={dense_eval:.4f}")
            )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
