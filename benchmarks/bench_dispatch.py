"""Dispatched vs fixed-backend execution (paper §3.3 / AITemplate-analog).

For every conv-layer GEMM shape in ``bench_conv_layers.LAYERS`` this bench:

  1. times each *fixed* registered linear candidate (gather-einsum XLA,
     fused Pallas micro-kernel) on the layer's [P, KhKwC] x [KhKwC, O] GEMM,
  2. profiles the shape through ``repro.dispatch`` into a fresh profile DB,
  3. times the *dispatched* execution (``best_impl`` consults the DB),

and reports the dispatched/best-fixed ratio — the acceptance criterion is
ratio ≈ 1 (dispatch never worse than the best fixed backend beyond noise).

The output-position count is capped so the CPU interpret-mode Pallas
candidate stays affordable; relative ordering is what the profiler needs.
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp

from benchmarks.bench_conv_layers import LAYERS, SPARSITY
from benchmarks.timing import row, time_fn
from repro import dispatch
from repro.core import SparsityConfig, colwise_nm_mask, meta_for, pack_colwise
from repro.dispatch import ProfileDB, REGISTRY

MAX_POSITIONS = 256  # cap GEMM rows per layer (interpret-mode Pallas cost)


def _gemm_problem(c, h, o, k, stride):
    kdim = k * k * c
    n_pos_side = (h + 2 * (k // 2 if k > 1 else 0) - k) // stride + 1
    p = min(n_pos_side * n_pos_side, MAX_POSITIONS)
    x = jax.random.normal(jax.random.PRNGKey(0), (p, kdim))
    w = jax.random.normal(jax.random.PRNGKey(1), (kdim, o)) / jnp.sqrt(kdim)
    cfg = SparsityConfig(SPARSITY, m=None, tile=None, format="compressed_xla")
    meta = meta_for(kdim, o, cfg)
    mask = colwise_nm_mask(w, SPARSITY, tile=meta.tile)
    values, idx = pack_colwise(w, mask, meta)
    return x, values, idx, meta


def run(iters: int = 5):
    out = []
    db = ProfileDB(path=tempfile.mktemp(suffix=".json"), autosave=False)
    prev = dispatch.get_db()
    dispatch.set_db(db)
    try:
        for name, c, h, o, k, stride in LAYERS:
            x, values, idx, meta = _gemm_problem(c, h, o, k, stride)
            params = {"values": values, "idx": idx}
            key = dispatch.linear_key_from(x.shape, values.shape)

            # fixed-backend candidates
            fixed_us = {}
            for spec in REGISTRY.feasible(key, param_keys=("values", "idx")):
                fn = jax.jit(lambda x, s=spec: s.apply(params, x))
                fixed_us[spec.name] = time_fn(fn, x, iters=iters, warmup=1,
                                              name=f"dispatch.{name}.{spec.name}")
                out.append(row(f"dispatch.{name}.{spec.name}",
                               fixed_us[spec.name],
                               f"P={x.shape[0]} K={meta.d_in} O={meta.d_out}"))

            # profile into the DB, then run the dispatched path
            rec = dispatch.profile_op(key, db, param_keys=("values", "idx"),
                                      iters=max(iters, 3))

            def dispatched(x):
                spec = dispatch.best_impl(key, param_keys=("values", "idx"))
                return spec.apply(params, x)

            t_disp = time_fn(jax.jit(dispatched), x, iters=iters, warmup=1,
                             name=f"dispatch.{name}.dispatched")
            best_fixed = min(fixed_us.values())
            out.append(row(
                f"dispatch.{name}.dispatched", t_disp,
                f"winner={rec['impl']} ratio_vs_best_fixed="
                f"{t_disp / best_fixed:.2f}x"))
    finally:
        dispatch.set_db(prev)
    return out


if __name__ == "__main__":
    print("\n".join(run()))
