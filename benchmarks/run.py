"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Mapping to the paper:
  bench_conv_layers  -> Fig. 5 (+ Fig. 10): dense vs conventional N:M vs
                        column-wise N:M per conv layer
  bench_fusion       -> Fig. 6/7/8: fused im2col+packing
  bench_blockwidth   -> Fig. 9: LMUL sweep (strip/tile width analogs)
  bench_accuracy     -> Table 1: pruning-pattern accuracy (proxy task)
  bench_e2e          -> Table 2 / Fig. 11: end-to-end throughput vs sparsity
  bench_layout       -> Fig. 12: CNHW vs NHWC
  bench_roofline     -> assignment §Roofline from the dry-run artifacts
  bench_dispatch     -> §3.3: dispatched vs fixed-backend operator selection
  bench_conv_fused   -> fused conv megakernel vs two-kernel/XLA plans
  bench_serve_scheduler -> continuous-batching scheduler vs static engine

``--quick`` runs a smoke subset (conv layers + dispatch, 3 iters) fast
enough for CI / pre-commit, so dispatch-latency regressions are caught
locally; ``--only NAME`` runs a single module.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def _modules():
    import types

    from benchmarks import (
        bench_accuracy,
        bench_blockwidth,
        bench_conv_fused,
        bench_conv_layers,
        bench_dispatch,
        bench_e2e,
        bench_fusion,
        bench_layout,
        bench_roofline,
        bench_serve_scheduler,
    )

    return [
        ("fig5_conv_layers", bench_conv_layers),
        ("conv_fused", bench_conv_fused),
        ("fig6_8_fusion", bench_fusion),
        ("fig9_blockwidth", bench_blockwidth),
        ("table1_accuracy", bench_accuracy),
        # conv cell of the accuracy protocol (dense -> prune -> finetune
        # through the sparse-conv backward -> compressed inference); its own
        # entry so --quick can run it without the full LM Table-1 sweep
        ("conv_accuracy", types.SimpleNamespace(run=bench_accuracy.run_conv)),
        # crash-safe training row: checkpoint overhead % + the asserted-zero
        # accuracy delta of an interrupted-then-resumed finetune
        ("train_resume",
         types.SimpleNamespace(run=bench_accuracy.run_train_resume)),
        ("table2_fig11_e2e", bench_e2e),
        ("fig12_layout", bench_layout),
        ("roofline", bench_roofline),
        ("dispatch", bench_dispatch),
        ("serve_scheduler", bench_serve_scheduler),
    ]


QUICK = {"fig5_conv_layers", "dispatch", "conv_accuracy"}
QUICK_ITERS = 3  # median of 3: the middle sample, robust to one outlier


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smoke subset with few iterations (CI mode)")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help="run a single benchmark module by name")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the obs layer and write a Perfetto-loadable "
                         "Chrome trace of the whole run to PATH")
    args = ap.parse_args(argv)

    if args.trace:
        from repro import obs

        obs.set_enabled(True)

    modules = _modules()
    if args.only:
        modules = [(n, m) for n, m in modules if n == args.only]
        if not modules:
            sys.exit(f"unknown benchmark {args.only!r}; known: "
                     f"{[n for n, _ in _modules()]}")
    elif args.quick:
        modules = [(n, m) for n, m in modules if n in QUICK]

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            # --quick shrinks iterations, but only for modules whose run()
            # takes an iters knob (e2e/accuracy/roofline parameterize
            # differently)
            quick_ok = args.quick and "iters" in inspect.signature(mod.run).parameters
            lines = mod.run(iters=QUICK_ITERS) if quick_ok else mod.run()
            for line in lines:
                print(line)
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name}.ERROR,0.0,{traceback.format_exc(limit=1).splitlines()[-1]}")
    if args.trace:
        from benchmarks.report import metrics_table
        from repro import obs

        n = obs.dump_chrome_trace(args.trace,
                                  metadata={"metrics": obs.snapshot()})
        print(f"# trace: wrote {n} events to {args.trace}", file=sys.stderr)
        for line in metrics_table(obs.snapshot()):
            print(line, file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
