"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Mapping to the paper:
  bench_conv_layers  -> Fig. 5 (+ Fig. 10): dense vs conventional N:M vs
                        column-wise N:M per conv layer
  bench_fusion       -> Fig. 6/7/8: fused im2col+packing
  bench_blockwidth   -> Fig. 9: LMUL sweep (strip/tile width analogs)
  bench_accuracy     -> Table 1: pruning-pattern accuracy (proxy task)
  bench_e2e          -> Table 2 / Fig. 11: end-to-end throughput vs sparsity
  bench_layout       -> Fig. 12: CNHW vs NHWC
  bench_roofline     -> assignment §Roofline from the dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_accuracy,
        bench_blockwidth,
        bench_conv_layers,
        bench_e2e,
        bench_fusion,
        bench_layout,
        bench_roofline,
    )

    print("name,us_per_call,derived")
    modules = [
        ("fig5_conv_layers", bench_conv_layers),
        ("fig6_8_fusion", bench_fusion),
        ("fig9_blockwidth", bench_blockwidth),
        ("table1_accuracy", bench_accuracy),
        ("table2_fig11_e2e", bench_e2e),
        ("fig12_layout", bench_layout),
        ("roofline", bench_roofline),
    ]
    failures = 0
    for name, mod in modules:
        try:
            for line in mod.run():
                print(line)
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name}.ERROR,0.0,{traceback.format_exc(limit=1).splitlines()[-1]}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
