"""Continuous-batching scheduler vs the static-batch engine on one
mixed-length synthetic request trace (CPU smoke config), plus the paged-KV
serving-memory tier (``repro.serve.kv_pages``) vs the contiguous slot pool.

The static engine pads every request in a batch to the longest prompt and
keeps decoding until the batch's largest token budget is exhausted, so
finished sequences burn decode steps producing tokens nobody asked for.  The
scheduler retires sequences the moment they finish and admits the next
request into the freed KV slot, so (useful tokens) / (decode wall-clock) —
the number reported here — should never be lower than the static loop's.

The paged rows compare the two serving-memory disciplines at the SAME
physical KV budget (the contiguous pool's own footprint,
``n_slots * max_len`` rows):

  * contiguous reserves ``max_len`` rows per slot up front, so its
    high-water-mark IS the whole pool and its admission capacity is
    ``budget_rows // max_len`` regardless of actual request sizes;
  * paged reserves ``ceil((prompt + budget) / page_size)`` pages per
    request, so short requests stop paying for the longest one — the
    measured high-water-mark (``pages_peak * page_size`` rows) is lower and
    the admission capacity (max concurrent requests the budget can hold) is
    strictly higher on any mixed-length trace;
  * contiguous chunked prefill pads every prompt to a multiple of the chunk
    width and runs attention over the padding; packed prefill concatenates
    the admitted prompts into one exact-shape stream — zero padded-token
    attention FLOPs.

Rows:
  serve_static_decode   us per *useful* token, decode tok/s (static batches)
  serve_sched_decode    us per useful token, decode tok/s (continuous)
  serve_sched_speedup   —, scheduler/static useful-throughput ratio
  serve_sched_p50       request latency p50 (us), seconds
  serve_sched_p99       request latency p99 (us), seconds
  serve_paged_decode    us per useful token, decode tok/s (paged KV + packed
                        prefill)
  serve_paged_p50/p99   request latency percentiles, paged scheduler
  serve_kv_hwm          contiguous vs paged KV bytes high-water-mark
  serve_admission_capacity  max concurrent requests at the fixed KV budget
  serve_prefill_pad_tokens  padded prompt tokens attention runs over
  serve_chaos_recovery  wall-clock overhead of recovering from a seeded
                        fault schedule (grow-mode preempt-restore +
                        scheduler-iteration fault), all requests still ok
                        and token-identical to the clean run

``--json`` appends to ``BENCH_serve.json`` — like ``BENCH_conv.json``, the
artifact keeps prior runs under ``history`` (env-fingerprinted + git-rev
stamped) so the serving perf trajectory across PRs is recorded, not
overwritten.  ``--quick`` shrinks the trace (CI smoke).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.timing import row
from repro import fault
from repro.configs import smoke_config
from repro.obs import trace as _ot
from repro.core.pruning import SparsityConfig
from repro.dispatch import env_fingerprint
from repro.models import registry as reg
from repro.serve import (
    STATUSES,
    Engine,
    Scheduler,
    ServeConfig,
    latency_percentiles,
    synthetic_trace,
)

ARCH = "smollm-360m"
SPARSITY = 0.5
N_REQUESTS = 10
N_SLOTS = 4
PROMPT_LENS = (4, 24)
# wide budget spread: the static loop decodes every batch to its largest
# budget, so short-budget requests burn whole wasted steps — the structural
# cost continuous batching removes
NEW_TOKENS = (2, 24)
PREFILL_CHUNK = 8
# fixed page size so the bench measures the memory tier, not the
# choose_page_size race (dispatch owns that decision in real serving)
PAGE_SIZE = 8


def _build_engine():
    scfg = SparsityConfig(sparsity=SPARSITY, m=None, tile=None,
                          format="compressed_xla", min_dim=64)
    cfg = smoke_config(ARCH).with_(sparsity=scfg)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_new_tokens=max(NEW_TOKENS)))


def _static_batches(trace, n_slots):
    """The static loop's view of the trace: fixed batches, every prompt
    right-padded to the batch max, decode until the batch's largest budget."""
    for i in range(0, len(trace), n_slots):
        group = trace[i:i + n_slots]
        s_max = max(len(r.prompt) for r in group)
        prompts = np.zeros((len(group), s_max), np.int32)
        for j, r in enumerate(group):
            prompts[j, :len(r.prompt)] = r.prompt
        yield prompts, max(r.max_new_tokens for r in group)


def _run_static(engine, trace):
    """Returns (useful_tokens, decode_seconds) over the whole trace."""
    decode_s = 0.0
    for prompts, budget in _static_batches(trace, N_SLOTS):
        engine.scfg.max_new_tokens = budget
        res = engine.generate(prompts)
        decode_s += res["decode_s"]
    useful = sum(r.max_new_tokens for r in trace)
    return useful, decode_s


def _run_sched(engine, trace, *, paged=False, budget_rows=None, alloc=None):
    kwargs = {}
    if paged:
        kwargs = dict(paged=True, page_size=PAGE_SIZE,
                      kv_budget_rows=budget_rows)
        if alloc is not None:
            kwargs["alloc"] = alloc
    sched = Scheduler(engine, n_slots=N_SLOTS, prefill_chunk=PREFILL_CHUNK,
                      **kwargs)
    completions = sched.run(trace)
    useful = sum(c.n_generated for c in completions)
    p50, p99 = latency_percentiles(completions)
    tokens = {c.uid: c.tokens for c in completions}
    return (useful, sched.stats["decode_s"], p50, p99, sched.page_stats,
            tokens, sched.stats)


CHAOS_SPEC = "page_pool.alloc@grow:iter=2,scheduler.iter:iter=1"
CHAOS_SEED = 0


def _measure_chaos(engine, trace, budget_rows):
    """Recovery-overhead leg: a clean grow-mode paged run vs the SAME run
    under a seeded fault schedule (one injected grow-allocation failure —
    forcing a preempt + restore — plus one lost scheduler iteration).  The
    faulted run must still retire every request ``ok`` with tokens identical
    to the clean run; the number reported is the wall-clock price of that
    recovery, not a correctness tradeoff."""
    # warm BOTH paths: grow-mode executables, plus the restored request's
    # re-prefill shape (the fault schedule is deterministic, so the warmup
    # compiles exactly the shapes the measured faulted run will hit —
    # otherwise the overhead number is mostly jit compilation)
    _run_sched(engine, trace, paged=True, budget_rows=budget_rows,
               alloc="grow")
    with fault.fault_scope(CHAOS_SPEC, seed=CHAOS_SEED):
        _run_sched(engine, trace, paged=True, budget_rows=budget_rows,
                   alloc="grow")
    with _ot.span("bench.serve_chaos_clean"):
        clean = _run_sched(engine, trace, paged=True,
                           budget_rows=budget_rows, alloc="grow")
    with _ot.span("bench.serve_chaos_faulted"):
        with fault.fault_scope(CHAOS_SPEC, seed=CHAOS_SEED) as plan:
            faulted = _run_sched(engine, trace, paged=True,
                                 budget_rows=budget_rows, alloc="grow")
    c_stats, f_stats = clean[6], faulted[6]
    for uid, toks in clean[5].items():
        if not np.array_equal(toks, faulted[5][uid]):
            raise AssertionError(
                f"faulted run diverged from clean run on request {uid} "
                "(preempt-restore must be token-exact)")
    statuses = {s: int(f_stats[f"retired_{s}"]) for s in STATUSES
                if f_stats[f"retired_{s}"]}
    if set(statuses) != {"ok"}:
        raise AssertionError(
            f"recoverable fault schedule lost requests: {statuses}")
    return {
        "spec": CHAOS_SPEC,
        "seed": CHAOS_SEED,
        "fired": dict(plan.fired),
        "clean_total_s": c_stats["total_s"],
        "faulted_total_s": f_stats["total_s"],
        "recovery_overhead": f_stats["total_s"] / max(c_stats["total_s"],
                                                      1e-9),
        "preemptions": int(f_stats["preemptions"]),
        "iter_faults": int(f_stats["iter_faults"]),
        "statuses": statuses,
    }


# ---------------------------------------------------------------------------
# Memory accounting (analytic where the layout is static, measured where not)
# ---------------------------------------------------------------------------


def _kv_row_bytes(cfg) -> int:
    """Bytes one KV cache row (one token position) costs across all layers:
    k + v, [KV heads, head_dim] each."""
    itemsize = np.dtype(cfg.dtype).itemsize
    return 2 * cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim * itemsize


def _contig_max_len(trace) -> int:
    """The contiguous pool's per-slot row reservation — same sizing rule as
    Scheduler.run_iter (the padded final prefill chunk must fit)."""
    needed = max(len(r.prompt) + r.max_new_tokens for r in trace)
    c = PREFILL_CHUNK
    pad_end = max(-(-len(r.prompt) // c) * c for r in trace)
    return max(needed, pad_end)


def _admission_capacity(trace, budget_rows, max_len, page_size):
    """Max concurrent requests each memory discipline can hold inside the
    same physical row budget.  Contiguous admission is slot-granular — every
    request reserves ``max_len`` rows no matter its size.  Paged admission
    reserves ``ceil((prompt + budget) / page_size)`` pages (the scheduler's
    full-budget upfront reservation), so capacity depends on the actual
    trace; we FIFO-fill it the way the scheduler's admission loop would."""
    cap_contig = budget_rows // max_len
    free_pages = budget_rows // page_size
    cap_paged = 0
    for r in trace:
        need = -(-(len(r.prompt) + r.max_new_tokens) // page_size)
        if need > free_pages:
            break
        free_pages -= need
        cap_paged += 1
    return cap_contig, cap_paged


def _prefill_pad_tokens(trace) -> int:
    """Padded prompt tokens the contiguous chunked-prefill path runs
    attention over (each prompt processed as ceil(S/C) chunks of C).  The
    packed path's count is identically zero — prompts are concatenated into
    one exact-shape stream."""
    c = PREFILL_CHUNK
    return sum(-(-len(r.prompt) // c) * c - len(r.prompt) for r in trace)


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------


def measure(iters: int = 3, quick: bool = False):
    """Returns the full result dict (the --json payload body)."""
    n_req = 6 if quick else N_REQUESTS
    engine = _build_engine()
    trace = synthetic_trace(n_req, seed=0, vocab=engine.cfg.vocab_size,
                            prompt_lens=PROMPT_LENS, new_tokens=NEW_TOKENS)
    max_len = _contig_max_len(trace)
    budget_rows = N_SLOTS * max_len  # the contiguous pool's own footprint
    row_bytes = _kv_row_bytes(engine.cfg)

    # warm all three paths (compiles every static batch shape, the
    # scheduler's chunk/pool executables, and the paged/packed steps), then
    # take the best measured run
    _run_static(engine, trace)
    _run_sched(engine, trace)
    _run_sched(engine, trace, paged=True, budget_rows=budget_rows)
    best_static = best_sched = best_paged = None
    for i in range(max(1, iters - 1)):
        with _ot.span("bench.serve_static", rep=i):
            u_s, t_s = _run_static(engine, trace)
        if best_static is None or t_s < best_static[1]:
            best_static = (u_s, t_s)
        with _ot.span("bench.serve_sched", rep=i):
            res_c = _run_sched(engine, trace)
        if best_sched is None or res_c[1] < best_sched[1]:
            best_sched = res_c
        with _ot.span("bench.serve_paged", rep=i):
            res_p = _run_sched(engine, trace, paged=True,
                               budget_rows=budget_rows)
        if best_paged is None or res_p[1] < best_paged[1]:
            best_paged = res_p

    # greedy decoding: the paged scheduler must emit the same tokens as the
    # contiguous slot path — a silent numeric divergence here would make the
    # perf comparison meaningless
    for uid, toks in best_sched[5].items():
        if not np.array_equal(toks, best_paged[5][uid]):
            raise AssertionError(
                f"paged scheduler diverged from contiguous on request {uid}")

    chaos = _measure_chaos(engine, trace, budget_rows)

    u_s, t_s = best_static
    u_c, t_c, p50_c, p99_c = best_sched[:4]
    u_p, t_p, p50_p, p99_p, pstats = best_paged[:5]
    hwm_contig = budget_rows * row_bytes  # preallocated => hwm == pool
    hwm_paged = int(pstats["kv_rows_hwm"]) * row_bytes  # measured peak
    cap_contig, cap_paged = _admission_capacity(
        trace, budget_rows, max_len, PAGE_SIZE)
    pad_contig = _prefill_pad_tokens(trace)
    return {
        "n_requests": n_req,
        "n_slots": N_SLOTS,
        "prefill_chunk": PREFILL_CHUNK,
        "page_size": PAGE_SIZE,
        "max_len": max_len,
        "budget_rows": budget_rows,
        "kv_row_bytes": row_bytes,
        "static": {"useful": u_s, "decode_s": t_s},
        "sched": {"useful": u_c, "decode_s": t_c, "p50_s": p50_c,
                  "p99_s": p99_c},
        "paged": {"useful": u_p, "decode_s": t_p, "p50_s": p50_p,
                  "p99_s": p99_p, "page_stats": pstats},
        "kv_hwm_bytes": {"contig": hwm_contig, "paged": hwm_paged},
        "admission_capacity": {"contig": cap_contig, "paged": cap_paged},
        "prefill_pad_tokens": {"contig": pad_contig, "packed": 0},
        "chaos": chaos,
    }


def rows_from(r) -> list:
    u_s, t_s = r["static"]["useful"], r["static"]["decode_s"]
    u_c, t_c = r["sched"]["useful"], r["sched"]["decode_s"]
    u_p, t_p = r["paged"]["useful"], r["paged"]["decode_s"]
    static_tok_s = u_s / max(t_s, 1e-9)
    sched_tok_s = u_c / max(t_c, 1e-9)
    paged_tok_s = u_p / max(t_p, 1e-9)
    hwm = r["kv_hwm_bytes"]
    cap = r["admission_capacity"]
    pad = r["prefill_pad_tokens"]
    frag = r["paged"]["page_stats"]["page_fragmentation"]
    return [
        row("serve_static_decode", t_s * 1e6 / u_s, f"{static_tok_s:.1f}"),
        row("serve_sched_decode", t_c * 1e6 / u_c, f"{sched_tok_s:.1f}"),
        row("serve_sched_speedup", 0.0, f"{sched_tok_s / static_tok_s:.2f}"),
        row("serve_sched_p50", r["sched"]["p50_s"] * 1e6,
            f"{r['sched']['p50_s']:.3f}"),
        row("serve_sched_p99", r["sched"]["p99_s"] * 1e6,
            f"{r['sched']['p99_s']:.3f}"),
        row("serve_paged_decode", t_p * 1e6 / u_p, f"{paged_tok_s:.1f}"),
        row("serve_paged_p50", r["paged"]["p50_s"] * 1e6,
            f"{r['paged']['p50_s']:.3f}"),
        row("serve_paged_p99", r["paged"]["p99_s"] * 1e6,
            f"{r['paged']['p99_s']:.3f}"),
        row("serve_kv_hwm", 0.0,
            f"contig={hwm['contig'] / 1e6:.3f}MB "
            f"paged={hwm['paged'] / 1e6:.3f}MB "
            f"ratio={hwm['paged'] / max(hwm['contig'], 1):.2f} "
            f"frag={frag:.2f}"),
        row("serve_admission_capacity", 0.0,
            f"contig={cap['contig']} paged={cap['paged']} "
            f"budget_rows={r['budget_rows']}"),
        row("serve_prefill_pad_tokens", 0.0,
            f"contig={pad['contig']} packed={pad['packed']}"),
        row("serve_chaos_recovery",
            (r["chaos"]["faulted_total_s"] - r["chaos"]["clean_total_s"])
            * 1e6,
            f"overhead={r['chaos']['recovery_overhead']:.2f}x "
            f"preemptions={r['chaos']['preemptions']} "
            f"iter_faults={r['chaos']['iter_faults']} "
            f"ok={r['chaos']['statuses'].get('ok', 0)}"),
    ]


def run(iters: int = 3):
    return rows_from(measure(iters=iters))


HISTORY_CAP = 20  # trajectory points kept; beyond this, oldest runs drop


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=5, check=True).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 — not a git checkout / git missing
        return "unknown"


def _write_json(results, iters, quick=False):
    """Append this run to BENCH_serve.json (same trajectory discipline as
    ``bench_conv_fused._write_json`` keeps for BENCH_conv.json): a FULL run
    becomes the top-level payload and the previous one is pushed onto
    ``history`` (capped at :data:`HISTORY_CAP`); every run carries the
    dispatch-layer environment fingerprint + git revision so points from
    different machines/commits are distinguishable.  A ``--quick`` run only
    refreshes the ``smoke`` section of an existing payload."""
    path = Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    old = None
    if path.exists():
        try:
            old = json.loads(path.read_text())
        except json.JSONDecodeError:
            old = None
        if not isinstance(old, dict):
            old = None
    run_payload = {
        "backend": jax.default_backend(),
        "arch": ARCH,
        "sparsity": SPARSITY,
        "iters": iters,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_rev": _git_rev(),
        "fingerprint": env_fingerprint(),
        "serve": results,
    }
    if quick and old is not None and "serve" in old:
        old["smoke"] = run_payload
        payload = old
        note = "refreshed smoke section"
    else:
        history = []
        if old is not None:
            history = old.pop("history", [])
            old.pop("smoke", None)
            history.append(old)
        history = history[-HISTORY_CAP:]
        payload = dict(run_payload, history=history)
        note = f"{len(history)} prior run(s) kept in history"
    path.write_text(json.dumps(payload, indent=1))
    print(f"wrote {path} ({note})")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="append to BENCH_serve.json (perf trajectory "
                         "artifact)")
    ap.add_argument("--quick", action="store_true",
                    help="shorter trace, 3 iters (CI smoke)")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args(argv)

    iters = args.iters if args.iters is not None else (3 if args.quick else 4)
    results = measure(iters=iters, quick=args.quick)
    for line in rows_from(results):
        print(line)
    if args.json:
        _write_json(results, iters, quick=args.quick)


if __name__ == "__main__":
    main()
