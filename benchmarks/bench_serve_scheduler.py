"""Continuous-batching scheduler vs the static-batch engine on one
mixed-length synthetic request trace (CPU smoke config).

The static engine pads every request in a batch to the longest prompt and
keeps decoding until the batch's largest token budget is exhausted, so
finished sequences burn decode steps producing tokens nobody asked for.  The
scheduler retires sequences the moment they finish and admits the next
request into the freed KV slot, so (useful tokens) / (decode wall-clock) —
the number reported here — should never be lower than the static loop's.

Rows:
  serve_static_decode  us per *useful* token, decode tok/s (static batches)
  serve_sched_decode   us per useful token, decode tok/s (continuous)
  serve_sched_speedup  —, scheduler/static useful-throughput ratio
  serve_sched_p50      request latency p50 (us), seconds
  serve_sched_p99      request latency p99 (us), seconds
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.timing import row
from repro.configs import smoke_config
from repro.obs import trace as _ot
from repro.core.pruning import SparsityConfig
from repro.models import registry as reg
from repro.serve import (
    Engine,
    Scheduler,
    ServeConfig,
    latency_percentiles,
    synthetic_trace,
)

ARCH = "smollm-360m"
SPARSITY = 0.5
N_REQUESTS = 10
N_SLOTS = 4
PROMPT_LENS = (4, 24)
# wide budget spread: the static loop decodes every batch to its largest
# budget, so short-budget requests burn whole wasted steps — the structural
# cost continuous batching removes
NEW_TOKENS = (2, 24)
PREFILL_CHUNK = 8


def _build_engine():
    scfg = SparsityConfig(sparsity=SPARSITY, m=None, tile=None,
                          format="compressed_xla", min_dim=64)
    cfg = smoke_config(ARCH).with_(sparsity=scfg)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_new_tokens=max(NEW_TOKENS)))


def _static_batches(trace, n_slots):
    """The static loop's view of the trace: fixed batches, every prompt
    right-padded to the batch max, decode until the batch's largest budget."""
    for i in range(0, len(trace), n_slots):
        group = trace[i:i + n_slots]
        s_max = max(len(r.prompt) for r in group)
        prompts = np.zeros((len(group), s_max), np.int32)
        for j, r in enumerate(group):
            prompts[j, :len(r.prompt)] = r.prompt
        yield prompts, max(r.max_new_tokens for r in group)


def _run_static(engine, trace):
    """Returns (useful_tokens, decode_seconds) over the whole trace."""
    decode_s = 0.0
    for prompts, budget in _static_batches(trace, N_SLOTS):
        engine.scfg.max_new_tokens = budget
        res = engine.generate(prompts)
        decode_s += res["decode_s"]
    useful = sum(r.max_new_tokens for r in trace)
    return useful, decode_s


def _run_sched(engine, trace):
    sched = Scheduler(engine, n_slots=N_SLOTS, prefill_chunk=PREFILL_CHUNK)
    completions = sched.run(trace)
    useful = sum(c.n_generated for c in completions)
    p50, p99 = latency_percentiles(completions)
    return useful, sched.stats["decode_s"], p50, p99


def run(iters: int = 3):
    engine = _build_engine()
    trace = synthetic_trace(N_REQUESTS, seed=0, vocab=engine.cfg.vocab_size,
                            prompt_lens=PROMPT_LENS, new_tokens=NEW_TOKENS)
    # warm both paths (compiles every static batch shape + the scheduler's
    # chunk/pool executables), then take the best measured run
    _run_static(engine, trace)
    _run_sched(engine, trace)
    best_static = best_sched = None
    for i in range(max(1, iters - 1)):
        with _ot.span("bench.serve_static", rep=i):
            u_s, t_s = _run_static(engine, trace)
        if best_static is None or t_s < best_static[1]:
            best_static = (u_s, t_s)
        with _ot.span("bench.serve_sched", rep=i):
            u_c, t_c, p50, p99 = _run_sched(engine, trace)
        if best_sched is None or t_c < best_sched[1]:
            best_sched = (u_c, t_c, p50, p99)

    u_s, t_s = best_static
    u_c, t_c, p50, p99 = best_sched
    static_tok_s = u_s / max(t_s, 1e-9)
    sched_tok_s = u_c / max(t_c, 1e-9)
    return [
        row("serve_static_decode", t_s * 1e6 / u_s, f"{static_tok_s:.1f}"),
        row("serve_sched_decode", t_c * 1e6 / u_c, f"{sched_tok_s:.1f}"),
        row("serve_sched_speedup", 0.0, f"{sched_tok_s / static_tok_s:.2f}"),
        row("serve_sched_p50", p50 * 1e6, f"{p50:.3f}"),
        row("serve_sched_p99", p99 * 1e6, f"{p99:.3f}"),
    ]


if __name__ == "__main__":
    for line in run():
        print(line)
