"""Train chaos smoke: kill a real finetune subprocess mid-run, restart it,
and demand the bitwise resume-determinism contract (the CI gate behind
``make train-chaos-smoke``).

Three subprocess runs of the same ``SparseTrainer`` config (the ``--worker``
submode below), then the parent audits the checkpoint directories:

  1. baseline   dir A, no faults            -> completes the 6-step budget;
  2. chaos      dir B, ``REPRO_FAULTS=train.step:iter=3`` -> the process
                dies at step 3 (nonzero exit), leaving only the async
                checkpoints it managed to commit;
  3. restart    dir B, no faults            -> restores the newest VALID
                checkpoint and completes the original budget.

Asserts: the chaos run really died; the restart resumed (start_step > 0);
the final-step checkpoints of A and B are **bitwise identical** array for
array; dir B leaks no ``tmp.*`` write dirs; the ``keep`` retention budget is
honored; and every surviving checkpoint passes deep (crc) validation.

Usage: PYTHONPATH=src python scripts/train_chaos_smoke.py
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
STEPS = 6
KILL_AT = 3
KEEP = 3


def worker(ckpt_dir: str) -> int:
    from repro.train import SparseTrainConfig, SparseTrainer

    cfg = SparseTrainConfig(steps=STEPS, batch=2, lr=0.05, ckpt_dir=ckpt_dir,
                            ckpt_every=1, keep=KEEP)
    out = SparseTrainer(cfg).run()
    print(f"worker: start={out['start_step']} final={out['final_step']} "
          f"loss={out['loss']:.4f}")
    return 0


def spawn(ckpt_dir: Path, faults: str | None = None) -> subprocess.CompletedProcess:
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("REPRO_FAULTS", None)
    if faults:
        env["REPRO_FAULTS"] = faults
    return subprocess.run(
        [sys.executable, __file__, "--worker", "--dir", str(ckpt_dir)],
        env=env, capture_output=True, text=True, timeout=900)


def final_arrays(ckpt_dir: Path):
    import numpy as np

    d = ckpt_dir / f"step_{STEPS:08d}"
    with np.load(d / "arrays.npz", allow_pickle=False) as z:
        return {k: z[k].copy() for k in z.files}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", action="store_true",
                    help="internal: run one training process")
    ap.add_argument("--dir", default=None, help="checkpoint directory")
    ap.add_argument("--workdir", default=None,
                    help="parent scratch dir (default: mkdtemp)")
    args = ap.parse_args(argv)

    if args.worker:
        return worker(args.dir)

    import tempfile

    root = Path(args.workdir or tempfile.mkdtemp(prefix="repro_train_chaos_"))
    dir_a, dir_b = root / "baseline", root / "chaos"
    failures: list[str] = []

    def check(cond, msg):
        (failures.append(msg) if not cond else None)

    # -- 1. baseline ---------------------------------------------------
    r = spawn(dir_a)
    check(r.returncode == 0, f"baseline run failed:\n{r.stderr[-2000:]}")
    print(f"baseline: exit {r.returncode}  {r.stdout.strip()}")

    # -- 2. chaos: the injected fault kills the process at step 3 ------
    r = spawn(dir_b, faults=f"train.step:iter={KILL_AT}")
    check(r.returncode != 0, "chaos run should have died, exited 0")
    check("InjectedFault" in r.stderr,
          f"chaos run died for the wrong reason:\n{r.stderr[-2000:]}")
    print(f"chaos:    exit {r.returncode} (killed at step {KILL_AT})")

    # -- 3. restart: resume from the newest valid checkpoint -----------
    r = spawn(dir_b)
    check(r.returncode == 0, f"restart run failed:\n{r.stderr[-2000:]}")
    check("start=0" not in r.stdout, "restart did not resume (start=0)")
    check(f"final={STEPS}" in r.stdout,
          f"restart did not reach the budget: {r.stdout.strip()}")
    print(f"restart:  exit {r.returncode}  {r.stdout.strip()}")

    # -- 4. audit the checkpoint directories ---------------------------
    if not failures:
        a, b = final_arrays(dir_a), final_arrays(dir_b)
        check(sorted(a) == sorted(b), "final checkpoints hold different keys")
        diverged = [k for k in a
                    if a[k].dtype != b[k].dtype
                    or a[k].tobytes() != b[k].tobytes()]
        check(not diverged,
              f"{len(diverged)}/{len(a)} arrays diverged from the "
              f"uninterrupted run, e.g. {diverged[:3]}")

        from repro.train import CheckpointManager

        for d in (dir_a, dir_b):
            check(not list(d.glob("tmp.*")), f"{d.name}: leaked tmp.* dirs")
            steps = sorted(d.glob("step_*"))
            check(len(steps) <= KEEP,
                  f"{d.name}: {len(steps)} checkpoints kept, budget {KEEP}")
            mgr = CheckpointManager(d, keep=KEEP)
            bad = {s.name: mgr.validate(s, deep=True) for s in steps
                   if mgr.validate(s, deep=True) is not None}
            check(not bad, f"{d.name}: invalid checkpoints {bad}")
        n_arrays = len(a)
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"TRAIN CHAOS SMOKE OK: killed at step {KILL_AT}, resumed, all "
          f"{n_arrays} final arrays bitwise identical; no tmp leaks, "
          f"keep={KEEP} honored, deep validation clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
