#!/usr/bin/env bash
# Local CI: tier-1 test suite + quick benchmark smoke (catches dispatch
# latency/selection regressions before they land).  Usage: scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serve scheduler smoke =="
python -m repro.launch.serve --arch smollm-360m --smoke --continuous \
    --requests 6 --slots 3 --prompt-len 12 --new-tokens 8 --prefill-chunk 8

echo "== quick benchmarks =="
python -m benchmarks.run --quick

echo "== conv megakernel smoke (writes BENCH_conv.json) =="
python -m benchmarks.bench_conv_fused --quick --json
