#!/usr/bin/env bash
# Local CI: tier-1 test suite + quick benchmark smoke (catches dispatch
# latency/selection regressions before they land).  Usage: scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== contract lints (Pallas/dispatch/registry static checks) =="
python -m repro.analysis src

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== serve scheduler smoke =="
python -m repro.launch.serve --arch smollm-360m --smoke --continuous \
    --requests 6 --slots 3 --prompt-len 12 --new-tokens 8 --prefill-chunk 8

echo "== paged-KV scheduler smoke (packed prefill + paged decode, trace validated) =="
PAGED_TRACE="$(mktemp -t repro_paged_XXXXXX.json)"
trap 'rm -f "$PAGED_TRACE"' EXIT
python -m repro.launch.serve --arch smollm-360m --smoke --continuous \
    --paged --page-size 8 --requests 6 --slots 3 --prompt-len 12 \
    --new-tokens 8 --prefill-chunk 8 --trace "$PAGED_TRACE"
python -m repro.obs.validate "$PAGED_TRACE"

echo "== obs trace smoke (serve --trace -> Perfetto-loadable JSON) =="
OBS_TRACE="$(mktemp -t repro_obs_XXXXXX.json)"
trap 'rm -f "$OBS_TRACE" "$PAGED_TRACE"' EXIT
python -m repro.launch.serve --arch smollm-360m --smoke --continuous \
    --requests 6 --slots 3 --prompt-len 12 --new-tokens 8 --prefill-chunk 8 \
    --trace "$OBS_TRACE"
# validator: non-empty, per-lane monotone timestamps, balanced B/E nesting
python -m repro.obs.validate "$OBS_TRACE"

echo "== chaos smoke (seeded faults: quarantine-degradation + request lifecycle) =="
CHAOS_TRACE="$(mktemp -t repro_chaos_XXXXXX.json)"
trap 'rm -f "$CHAOS_TRACE" "$OBS_TRACE" "$PAGED_TRACE"' EXIT
python scripts/chaos_smoke.py --trace "$CHAOS_TRACE"
python -m repro.obs.validate "$CHAOS_TRACE"

echo "== sparse finetune smoke (conv VJP backward, interpret mode) =="
python -c "from repro.models.vision import train_smoke; train_smoke(steps=2)"

echo "== train chaos smoke (kill -> restart -> bitwise-identical resume) =="
python scripts/train_chaos_smoke.py

echo "== quick benchmarks =="
python -m benchmarks.run --quick

echo "== conv megakernel smoke (writes BENCH_conv.json) =="
python -m benchmarks.bench_conv_fused --quick --json

echo "== banded conv smoke (forced double-buffered DMA path) =="
REPRO_DISPATCH_FORCE=fused_banded_pallas python - <<'PY'
import sys
import jax, jax.numpy as jnp, numpy as np
from repro.core import SparsityConfig, conv_init, conv_apply, unbox_tree
from repro.kernels.pltpu_compat import HAS_ASYNC_COPY

if not HAS_ASYNC_COPY:  # same gate as the banded dispatch predicates
    print("banded DMA smoke SKIPPED: pallas build has no make_async_copy")
    sys.exit(0)
cfg = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                     format="compressed_pallas")
params, _ = unbox_tree(conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3, cfg))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 10, 10))
y = conv_apply(params, x, kh=3, kw=3, stride=1, pad=1)      # forced banded
y_ref = conv_apply(params, x, kh=3, kw=3, stride=1, pad=1,
                   impl="im2col_sparse_xla")
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                           rtol=1e-4, atol=1e-4)
print("banded DMA smoke OK:", y.shape)
PY
