#!/usr/bin/env bash
# Local CI: tier-1 test suite + quick benchmark smoke (catches dispatch
# latency/selection regressions before they land).  Usage: scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== quick benchmarks =="
python -m benchmarks.run --quick
