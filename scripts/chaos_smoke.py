"""Chaos smoke: a seeded fault schedule against the paged serving runtime.

One scripted run asserting the robustness tentpole end to end (the CI gate
behind ``make chaos-smoke``):

  1. baseline paged serve, no faults -> per-request greedy tokens;
  2. pin the pallas paged-attention kernel via a frozen profile DB, then
     re-serve the same trace under a seeded fault schedule:
       * the pinned kernel fails at decode trace time
         (``kernel.paged_attn`` site) -> dispatch quarantines it and
         degrades to the XLA gather reference — the exact impl the baseline
         ran, so surviving requests must be token-identical;
       * one admission's page allocation fails (``page_pool.alloc`` site,
         forced exhaustion) -> that request retires ``failed``;
       * one request carries an already-expired deadline -> ``timeout``.
  3. assert: every request terminal, zero page leaks, fault-free requests
     token-identical to baseline, and a nonzero ``dispatch.quarantine``
     counter in the obs snapshot;
  4. dump the Chrome trace (``--trace``) for ``repro.obs.validate``.

Usage: PYTHONPATH=src python scripts/chaos_smoke.py --trace /tmp/chaos.json
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro import dispatch, fault, obs
from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.dispatch import REGISTRY, ProfileDB, paged_attn_key
from repro.models import registry as reg
from repro.serve import Engine, Request, Scheduler, ServeConfig

ARCH = "smollm-360m"
N_REQ = 6
N_SLOTS = 2
PROMPT = 6
BUDGET = 6
MAX_LEN = 16
PAGE_SIZE = 8


def build_engine():
    scfg = SparsityConfig(sparsity=0.5, m=None, tile=None,
                          format="compressed_xla", min_dim=64)
    cfg = smoke_config(ARCH).with_(sparsity=scfg)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_new_tokens=BUDGET))


def make_trace(cfg, *, deadline_uid=None):
    rng = np.random.default_rng(0)
    out = []
    for uid in range(N_REQ):
        r = Request(uid=uid,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (PROMPT,)).astype(np.int32),
                    max_new_tokens=BUDGET)
        if uid == deadline_uid:
            r.deadline_s = 1e-6  # expired before it can ever admit
        out.append(r)
    return out


def decode_attn_key(cfg):
    """The dispatch key the scheduler's paged decode step resolves (one
    [n_slots, 1] q row block against the paged cache)."""
    max_pages = -(-MAX_LEN // PAGE_SIZE)
    return paged_attn_key(
        q_rows=N_SLOTS, n_heads=cfg.padded_heads, kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, kv_capacity=max_pages * PAGE_SIZE,
        page_size=PAGE_SIZE, dtype=cfg.dtype, phase="decode")


def run_sched(engine, trace):
    sched = Scheduler(engine, n_slots=N_SLOTS, paged=True,
                      page_size=PAGE_SIZE, max_len=MAX_LEN)
    comps = {c.uid: c for c in sched.run(trace)}
    return sched, comps


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the obs Chrome trace here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # -- 1. baseline: heuristic routing (XLA gather reference), no faults --
    engine = build_engine()
    _, baseline = run_sched(engine, make_trace(engine.cfg))
    assert all(c.status == "ok" for c in baseline.values())
    print(f"baseline: {N_REQ} ok, tokens per uid "
          f"{[len(c.tokens) for _, c in sorted(baseline.items())]}")

    # -- 2. pin the pallas kernel via a frozen DB, then arm the schedule --
    key = decode_attn_key(engine.cfg)
    pallas = [s.name for s in REGISTRY.candidates("paged_attn")
              if s.backend == "pallas" and s.feasible(key)[0]]
    if not pallas:
        # pallas build without the paged kernel prerequisites: the
        # quarantine leg of this smoke cannot run (same gate the dispatch
        # predicates use), and a skip must not turn the CI step green-washed
        print("chaos smoke SKIPPED: no feasible pallas paged_attn candidate")
        return 0
    victim = pallas[0]
    db = ProfileDB(path=None)
    db.put(key.token, {"impl": victim, "wall_us": 1.0})
    dispatch.set_db(db)

    obs.set_enabled(True)  # the faulted run is the one worth a trace
    # schedule: kill the pinned kernel wherever it runs (quarantine ->
    # degrade), fail the 4th page allocation (forced exhaustion), and let
    # the deadline on uid 5 expire
    spec = f"kernel.paged_attn@{victim}:n=99,page_pool.alloc:iter=3"
    engine2 = build_engine()  # fresh jit caches: decode re-traces under faults
    with fault.fault_scope(spec, seed=args.seed) as plan:
        sched, chaos = run_sched(
            engine2, make_trace(engine2.cfg, deadline_uid=5))
    dispatch.set_db(None)

    # -- 3. the robustness contract -----------------------------------
    stats = sched.stats
    statuses = {u: c.status for u, c in sorted(chaos.items())}
    print(f"chaos:    statuses {statuses}")
    print(f"          faults fired {dict(plan.fired)}")
    failures = []

    def check(cond, msg):
        (failures.append(msg) if not cond else None)

    check(sorted(chaos) == list(range(N_REQ)),
          "not every request reached a terminal completion")
    check(all(c.status in ("ok", "failed", "timeout") for c in chaos.values()),
          f"unexpected statuses: {statuses}")
    check(sum(1 for c in chaos.values() if c.status == "failed") == 1,
          "the injected page-exhaustion should fail exactly one request")
    check(chaos[5].status == "timeout", "uid 5's expired deadline ignored")
    check(sched.page_stats["pages_active"] == 0,
          "pages still mapped after the run (leak)")
    check(plan.fired.get("kernel.paged_attn", 0) >= 1,
          "the pinned pallas kernel was never fault-probed")
    quarantined = dispatch.quarantined("paged_attn")
    check(victim in quarantined,
          f"{victim} not quarantined (got {sorted(quarantined)})")
    snap = obs.snapshot()
    q_count = snap.get("counters", {}).get("dispatch.quarantine", 0)
    check(q_count >= 1,
          f"dispatch.quarantine counter is {q_count}, expected >= 1")
    # fault-free survivors: the quarantine-degraded rung IS the baseline's
    # impl, so their tokens must match bit for bit
    for uid, c in chaos.items():
        if c.status == "ok" and not np.array_equal(c.tokens,
                                                   baseline[uid].tokens):
            failures.append(f"uid {uid} diverged from the no-fault run")

    if args.trace:
        n = obs.dump_chrome_trace(args.trace,
                                  metadata={"metrics": snap,
                                            "faults": dict(plan.fired)})
        print(f"trace: wrote {n} events to {args.trace}")
    dispatch.clear_quarantine()

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    ok = sum(1 for c in chaos.values() if c.status == "ok")
    print(f"CHAOS SMOKE OK: {ok} ok (token-identical), 1 failed, 1 timeout; "
          f"quarantine degraded {victim} -> paged_attn_ref "
          f"(counter {q_count})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
