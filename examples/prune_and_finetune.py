"""The paper's full workflow on a small LM (CPU):

  1. train dense
  2. one-shot column-wise N:M prune (L1 importance, adaptive M)  [paper §3.1]
  3. finetune with the mask fixed                                 [paper §4.1.2]
  4. compress to the packed format and verify the compressed
     forward matches the masked model exactly                     [paper Fig. 1]
  5. compare against the conventional row-wise N:M baseline

    PYTHONPATH=src python examples/prune_and_finetune.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import SparsityConfig, compress_layer, prune_tree
from repro.data import DataConfig, SyntheticLM
from repro.models import registry as reg
from repro.optim import AdamWConfig, adamw_init, adamw_update

SPARSITY = 0.5


def train(cfg, params, data, steps, lr, masks=None, start=0):
    lfn = reg.loss_fn(cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=lr, weight_decay=0.01)

    @jax.jit
    def step(p, o, batch):
        (l, _), g = jax.value_and_grad(lfn, has_aux=True)(p, batch)
        p, o, _ = adamw_update(p, g, o, ocfg)
        if masks is not None:
            p = jax.tree_util.tree_map(
                lambda w, m: w * m.astype(w.dtype) if m is not None else w,
                p, masks, is_leaf=lambda x: x is None)
        return p, o, l

    loss = None
    for k in range(steps):
        batch = {kk: jnp.asarray(v) for kk, v in data.batch_at(start + k).items()}
        params, opt, loss = step(params, opt, batch)
    return params, float(loss)


def evaluate(cfg, params, data, n=6):
    lfn = jax.jit(lambda p, b: reg.loss_fn(cfg)(p, b)[0])
    return float(np.mean([
        float(lfn(params, {k: jnp.asarray(v) for k, v in data.batch_at(50000 + i).items()}))
        for i in range(n)
    ]))


def main():
    cfg = smoke_config("smollm-360m").with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=128, tie_embeddings=False)
    data = SyntheticLM(DataConfig(vocab_size=128, batch=16, seq_len=48, seed=5))
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))

    print("1) dense training …")
    params, _ = train(cfg, params, data, 120, 3e-3)
    dense_nll = evaluate(cfg, params, data)
    print(f"   dense eval nll = {dense_nll:.4f}")

    not_embed = lambda path, leaf: "embed" not in jax.tree_util.keystr(path)
    results = {}
    for name, kw in {
        "colwise adaptive-M (paper)": dict(m=None, tile=8, scheme="colwise"),
        "rowwise 2:4 baseline": dict(m=4, tile=1, scheme="rowwise"),
    }.items():
        scfg = SparsityConfig(sparsity=SPARSITY, format="masked", min_dim=64, **kw)
        pruned, masks = prune_tree(params, scfg, is_weight=not_embed)
        one_shot = evaluate(cfg, pruned, data)
        tuned, _ = train(cfg, pruned, data, 60, 1e-3, masks=masks, start=200)
        ft = evaluate(cfg, tuned, data)
        results[name] = (one_shot, ft, tuned, masks)
        print(f"2-3) {name}: one-shot {one_shot:.4f} -> finetuned {ft:.4f}")

    # 4) compress the colwise model and verify exact forward equality
    name = "colwise adaptive-M (paper)"
    _, _, tuned, masks = results[name]
    scfg = SparsityConfig(sparsity=SPARSITY, m=None, tile=8, format="compressed_xla",
                          min_dim=64)
    lfn = reg.loss_fn(cfg)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    masked_loss = float(lfn(tuned, batch)[0])

    def compress_inplace(tree, masks):
        if isinstance(tree, dict) and "w" in tree and masks is not None and \
           isinstance(masks, dict) and masks.get("w") is not None:
            comp = compress_layer({"w": tree["w"], "mask": masks["w"],
                                   **({"b": tree["b"]} if "b" in tree else {})}, scfg)
            return comp
        if isinstance(tree, dict):
            return {k: compress_inplace(v, masks.get(k) if isinstance(masks, dict) else None)
                    for k, v in tree.items()}
        return tree

    comp_params = compress_inplace(tuned, masks)
    comp_loss = float(lfn(comp_params, batch)[0])
    print(f"4) compressed forward loss {comp_loss:.6f} vs masked {masked_loss:.6f} "
          f"(delta {abs(comp_loss-masked_loss):.2e})")
    kept = sum(np.asarray(l).size for p, l in
               jax.tree_util.tree_flatten_with_path(comp_params)[0]
               if "values" in jax.tree_util.keystr(p))
    total = sum(np.asarray(l).size for p, l in
                jax.tree_util.tree_flatten_with_path(tuned)[0]
                if jax.tree_util.keystr(p).endswith("['w']"))
    print(f"   stored body weights: {kept} vs dense {total} "
          f"({100*kept/max(total,1):.0f}%)")


if __name__ == "__main__":
    main()
