"""Quickstart: train a small column-wise N:M pruned LM end-to-end on CPU.

    PYTHONPATH=src python examples/quickstart.py

The same Trainer + step builder compile for the 512-chip production mesh via
``repro.launch.dryrun`` / ``repro.launch.train``; here everything runs on the
host device with a reduced config.
"""
import jax

from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer


def main():
    # qwen2-family reduced config with the paper's technique ON: 50% sparsity,
    # adaptive M (full reduction dim), compressed execution.
    scfg = SparsityConfig(sparsity=0.5, m=None, tile=64,
                          format="compressed_xla", min_dim=64)
    cfg = smoke_config("qwen2-0.5b").with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256, sparsity=scfg,
    )
    data = DataConfig(vocab_size=256, batch=16, seq_len=64, seed=0)
    tr = Trainer(cfg, data, AdamWConfig(lr=3e-3, weight_decay=0.01),
                 TrainConfig(steps=120, log_every=20, ckpt_dir="/tmp/repro_quickstart",
                             ckpt_every=50))
    out = tr.run()
    print(f"\narch={cfg.name} (sparse 50% column-wise, compressed)")
    for h in out["history"]:
        print(f"  step {h['step']:>4}  loss {h['loss']:.4f}  "
              f"({h['sec_per_step']*1e3:.0f} ms/step)")
    print(f"final step: {out['final_step']}  stragglers: {len(out['stragglers'])}")
    print("checkpoints in /tmp/repro_quickstart (restart me to resume)")


if __name__ == "__main__":
    main()
