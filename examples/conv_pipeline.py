"""The paper's own domain, end-to-end: sparse convolution built from the two
kernels — fused im2col+packing (Alg. 2) feeding the column-wise N:M sparse
GEMM micro-kernel (Alg. 1, Pallas, interpret mode on CPU).

    PYTHONPATH=src python examples/conv_pipeline.py

Validates a 3-layer CNN block against the dense lax.conv oracle and reports
the FLOP/storage savings per layer.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import SparsityConfig
from repro.kernels.conv_gemm import (
    compress_conv_weights,
    conv2d_cnhw_ref,
    conv2d_colwise_sparse,
)
from repro.core import colwise_nm_mask

LAYERS = [
    # (C_in, C_out, k, stride) — ResNet-ish block
    (8, 16, 3, 1),
    (16, 16, 3, 1),
    (16, 32, 1, 1),
]
SPARSITY = 0.5


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 2, 16, 16))  # CNHW
    total_dense = total_sparse = 0
    for i, (cin, cout, k, stride) in enumerate(LAYERS):
        key, kw = jax.random.split(key)
        w = jax.random.normal(kw, (cout, k, k, cin)) / np.sqrt(k * k * cin)
        cfg = SparsityConfig(sparsity=SPARSITY, m=None, tile=8,
                             format="compressed_pallas")
        values, idx, meta = compress_conv_weights(w, cfg)
        pad = k // 2
        y = conv2d_colwise_sparse(x, values, idx, kh=k, kw=k, stride=stride,
                                  pad=pad, v=32)
        # oracle: dense conv with masked weights
        wmat = w.reshape(cout, -1).T
        mask = colwise_nm_mask(wmat, SPARSITY, m=None, tile=meta.tile)
        w_masked = (wmat * mask).T.reshape(w.shape)
        y_ref = conv2d_cnhw_ref(x, w_masked, stride=stride, pad=pad)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        dense_flops = 2 * np.prod(y.shape) * k * k * cin
        sparse_flops = int(dense_flops * meta.density)
        total_dense += dense_flops
        total_sparse += sparse_flops
        print(f"layer {i}: {cin:>3}->{cout:<3} {k}x{k}  out {tuple(y.shape)}  "
              f"max|err| {err:.2e}  flops {sparse_flops/1e6:.1f}M "
              f"({100*meta.density:.0f}% of dense)")
        x = jax.nn.relu(y)
    print(f"\nblock total: {total_sparse/1e6:.1f}M vs dense {total_dense/1e6:.1f}M flops "
          f"({100*total_sparse/total_dense:.0f}%)")


if __name__ == "__main__":
    main()
