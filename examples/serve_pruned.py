"""Serve a column-wise-pruned model with batched requests (CPU demo).

    PYTHONPATH=src python examples/serve_pruned.py

Compares decode throughput dense vs 50%/75% compressed on the same reduced
qwen2-style config — the FLOP saving the MXU would realize shows up as a
wall-clock saving on the host too, because the compressed contraction is
genuinely shorter.
"""
import numpy as np
import jax

from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.models import registry as reg
from repro.serve import Engine, ServeConfig


def build(sparsity: float):
    scfg = SparsityConfig(
        sparsity=sparsity, m=None, tile=None,  # tile = full shard (tuner's pick for the XLA path)
        format="compressed_xla" if sparsity else "dense", min_dim=64)
    cfg = smoke_config("qwen2-7b").with_(
        n_layers=4, d_model=512, n_heads=4, n_kv_heads=2, head_dim=128,
        d_ff=4096, vocab_size=512, sparsity=scfg)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def main():
    prompts = np.random.default_rng(0).integers(0, 500, (32, 16)).astype(np.int32)
    base = None
    for s in (0.0, 0.5, 0.75):
        cfg, params = build(s)
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=24))
        eng.generate(prompts)  # warm/compile
        res = eng.generate(prompts)
        if base is None:
            base = res["decode_tok_s"]
        print(f"sparsity {int(s*100):>2}%  prefill {res['prefill_s']*1e3:7.1f} ms  "
              f"decode {res['decode_tok_s']:8.1f} tok/s  "
              f"speedup x{res['decode_tok_s']/base:.2f}")
        print(f"   sample: {res['tokens'][0][:12].tolist()}")
    print("\nnote: XLA:CPU pays a hefty scalar-gather penalty that RVV indexed "
          "loads (paper) and the TPU VMEM gather (our Pallas kernel) do not - "
          "the FLOP saving shows through fully at 75%, partially at 50% here.")


if __name__ == "__main__":
    main()
