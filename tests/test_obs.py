"""Observability layer (`repro.obs`): span nesting under exceptions,
ring-buffer overflow semantics, histogram percentile correctness vs numpy,
zero-overhead-when-off guarantees (no events + bit-identical dispatch),
metric registry lifecycle, and cross-process trace-file schema validation."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import dispatch, obs
from repro.dispatch import ProfileDB
from repro.obs import metrics, trace
from repro.obs.validate import TraceValidationError, validate_chrome_trace

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture
def obs_on():
    """Recording on with a clean ring + registry; restores env-derived state
    (and the env-sized ring) afterwards."""
    trace.set_enabled(True)
    obs.reset()
    yield
    trace.set_enabled(None)
    obs.reset()
    trace.configure(None)


# ---------------------------------------------------------------------------
# Spans & nesting
# ---------------------------------------------------------------------------


class TestSpans:
    def test_nesting_closes_under_exceptions(self, obs_on):
        with pytest.raises(ValueError, match="boom"):
            with trace.span("outer", x=1):
                assert trace.current_stack() == ("outer",)
                with trace.span("inner"):
                    assert trace.current_stack() == ("outer", "inner")
                    raise ValueError("boom")
        # the stack unwound and every B got its E, innermost first
        assert trace.current_stack() == ()
        evs = trace.events()
        assert [(e["ph"], e["name"]) for e in evs] == [
            ("B", "outer"), ("B", "inner"), ("E", "inner"), ("E", "outer")]
        # both E events carry the error; the B events carry depth + open args
        assert evs[2]["args"]["error"] == "ValueError: boom"
        assert evs[3]["args"]["error"] == "ValueError: boom"
        assert evs[0]["args"] == {"x": 1, "depth": 0}
        assert evs[1]["args"]["depth"] == 1
        # and the resulting stream passes the schema validator
        stats = validate_chrome_trace({"traceEvents": evs})
        assert stats["spans"] == 2

    def test_set_attaches_end_args(self, obs_on):
        with trace.span("work") as sp:
            sp.set(result=7)
        end = trace.events()[-1]
        assert end["ph"] == "E" and end["args"] == {"result": 7}

    def test_instant_records_thread_scope(self, obs_on):
        trace.instant("tick", n=3)
        (ev,) = trace.events()
        assert ev["ph"] == "i" and ev["s"] == "t" and ev["args"] == {"n": 3}

    def test_ring_overflow_keeps_newest(self, obs_on):
        trace.configure(capacity=8)
        for i in range(20):
            trace.instant("tick", i=i)
        evs = trace.events()
        assert len(evs) == 8
        assert [e["args"]["i"] for e in evs] == list(range(12, 20))
        assert trace.dropped_events() == 12
        trace.reset()
        assert trace.events() == [] and trace.dropped_events() == 0


# ---------------------------------------------------------------------------
# Histograms vs numpy
# ---------------------------------------------------------------------------


class TestHistogram:
    def test_percentiles_bound_numpy_nearest_rank(self, obs_on):
        rng = np.random.default_rng(0)
        data = rng.lognormal(mean=-7.0, sigma=2.0, size=5000)
        h = metrics.histogram("t.lat")
        for v in data:
            h.observe(v)
        data.sort()
        for p in (50, 90, 99):
            true = data[max(int(np.ceil(p / 100 * len(data))), 1) - 1]
            est = h.percentile(p)
            # upper bucket edge: bounds the nearest-rank value from above,
            # off by at most one bucket ratio (factor 2)
            assert true <= est <= true * 2.0 + 1e-12, (p, true, est)
        assert h.percentile(100) == pytest.approx(data[-1])
        s = h.summary()
        assert s["count"] == 5000
        assert s["min"] == pytest.approx(data[0])
        assert s["sum"] == pytest.approx(data.sum())

    def test_empty_and_bad_p(self, obs_on):
        h = metrics.histogram("t.empty")
        assert h.percentile(50) == 0.0
        assert h.summary()["count"] == 0
        h.observe(1.0)
        with pytest.raises(ValueError, match="outside"):
            h.percentile(101)

    def test_registry_kind_mismatch_raises(self, obs_on):
        metrics.counter("t.kind")
        with pytest.raises(TypeError):
            metrics.gauge("t.kind")

    def test_reset_zeroes_cached_references_in_place(self, obs_on):
        c = metrics.counter("t.cached")
        c.inc(5)
        metrics.reset()
        assert c.value == 0
        c.inc(2)
        assert metrics.snapshot()["counters"]["t.cached"] == 2


# ---------------------------------------------------------------------------
# Zero overhead when off
# ---------------------------------------------------------------------------


class TestDisabled:
    def test_no_events_no_metrics_when_off(self):
        trace.set_enabled(False)
        obs.reset()
        try:
            with trace.span("hot", x=1) as sp:
                sp.set(y=2)
                trace.instant("tick")
            metrics.counter("off.c").inc(3)
            metrics.gauge("off.g").set(4)
            metrics.histogram("off.h").observe(0.5)
            assert trace.events() == []
            snap = metrics.snapshot()
            assert snap["counters"]["off.c"] == 0
            assert snap["gauges"]["off.g"] == 0
            assert snap["histograms"]["off.h"]["count"] == 0
        finally:
            trace.set_enabled(None)
            obs.reset()

    def test_null_span_is_shared_singleton(self):
        trace.set_enabled(False)
        try:
            assert trace.span("a") is trace.span("b")
        finally:
            trace.set_enabled(None)

    def test_dispatch_resolution_bit_identical(self, tmp_path):
        """Turning obs on must not change which impl dispatch picks."""
        key = dispatch.linear_key(batch=8, d_in=64, d_out=64, k_kept=32,
                                  tile=16)
        db = ProfileDB(path=str(tmp_path / "db.json"))
        try:
            trace.set_enabled(False)
            dispatch.set_db(db)  # clears the memo
            off = dispatch.best_impl(key)
            trace.set_enabled(True)
            dispatch.set_db(db)
            on = dispatch.best_impl(key)
        finally:
            trace.set_enabled(None)
            dispatch.set_db(None)
            obs.reset()
        assert off is on or (off.name == on.name
                             and off.geometry == on.geometry)

    def test_dispatch_emits_decision_when_on(self, obs_on, tmp_path):
        key = dispatch.linear_key(batch=8, d_in=64, d_out=64, k_kept=32,
                                  tile=16)
        try:
            dispatch.set_db(ProfileDB(path=str(tmp_path / "db.json")))
            spec = dispatch.best_impl(key)
        finally:
            dispatch.set_db(None)
        dec = [e for e in trace.events() if e["name"] == "dispatch.decision"]
        assert len(dec) == 1
        args = dec[0]["args"]
        assert args["impl"] == spec.name
        assert args["token"] == key.token
        assert args["source"] in ("forced", "legacy", "degraded", "db",
                                  "profiled", "heuristic")
        assert "geometry" in args


# ---------------------------------------------------------------------------
# Trace export & validation
# ---------------------------------------------------------------------------


class TestExport:
    def test_dump_and_validate_roundtrip(self, obs_on, tmp_path):
        with trace.span("a"):
            with trace.span("b"):
                trace.instant("tick")
        path = tmp_path / "t.json"
        n = trace.dump_chrome_trace(path, metadata={"metrics": obs.snapshot()})
        assert n == 5
        stats = validate_chrome_trace(str(path))
        assert stats == {"events": 5, "spans": 2, "instants": 1, "lanes": 1}
        payload = json.loads(path.read_text())
        assert payload["otherData"]["dropped_events"] == 0
        assert "metrics" in payload["otherData"]

    def test_validator_rejects_unbalanced(self):
        evs = [{"name": "a", "ph": "B", "ts": 1.0, "pid": 1, "tid": 1}]
        with pytest.raises(TraceValidationError, match="open"):
            validate_chrome_trace({"traceEvents": evs})
        with pytest.raises(TraceValidationError, match="empty"):
            validate_chrome_trace({"traceEvents": []})

    def test_validator_rejects_nonmonotonic(self):
        evs = [
            {"name": "a", "ph": "i", "ts": 2.0, "pid": 1, "tid": 1},
            {"name": "b", "ph": "i", "ts": 1.0, "pid": 1, "tid": 1},
        ]
        with pytest.raises(TraceValidationError, match="backwards"):
            validate_chrome_trace({"traceEvents": evs})

    def test_cross_process_atexit_trace(self, tmp_path):
        """REPRO_OBS + REPRO_OBS_TRACE make a plain process emit a valid
        trace file at interpreter exit with no explicit dump call."""
        out = tmp_path / "proc.json"
        code = (
            "from repro.obs import trace\n"
            "with trace.span('outer', job='x'):\n"
            "    with trace.span('inner'):\n"
            "        trace.instant('tick', n=1)\n"
        )
        env = dict(os.environ, REPRO_OBS="1", REPRO_OBS_TRACE=str(out),
                   PYTHONPATH=str(REPO / "src"))
        subprocess.run([sys.executable, "-c", code], check=True, env=env,
                       cwd=str(REPO), timeout=120)
        stats = validate_chrome_trace(str(out))
        assert stats["spans"] == 2 and stats["instants"] == 1
        names = [e["name"]
                 for e in json.loads(out.read_text())["traceEvents"]]
        assert names == ["outer", "inner", "tick", "inner", "outer"]
