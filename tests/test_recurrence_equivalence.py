"""The chunked parallel forms (Mamba2 SSD, mLSTM) must compute exactly the
same function as their sequential single-token recurrences — this is the
correctness contract that lets training use the parallel form while decode
uses O(1) state updates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.sparse_linear import unbox_tree
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


def seq_from_decode(decode_fn, params, cfg, cache, x):
    """Run a per-token decode over a sequence; stack outputs."""
    outs = []
    for t in range(x.shape[1]):
        y, cache = decode_fn(params, cfg, x[:, t : t + 1], cache)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


class TestMamba2Equivalence:
    @pytest.mark.parametrize("chunk", [4, 8, 16])
    def test_chunked_equals_sequential(self, chunk):
        cfg = smoke_config("zamba2-7b").with_(
            d_model=32, ssm_head_dim=8, ssm_state=8, ssm_chunk=chunk, expand=2)
        params, _ = unbox_tree(ssm_mod.mamba_init(jax.random.PRNGKey(0), cfg)), None
        params = params[0]
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
        y_par = ssm_mod.mamba_apply(params, cfg, x)
        cache = ssm_mod.mamba_cache_init(cfg, 2)
        y_seq = seq_from_decode(ssm_mod.mamba_decode, params, cfg, cache, x)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)

    def test_ragged_chunk(self):
        # seq length not a multiple of the requested chunk: apply() shrinks it
        cfg = smoke_config("zamba2-7b").with_(
            d_model=32, ssm_head_dim=8, ssm_state=8, ssm_chunk=5, expand=2)
        params, _ = unbox_tree(ssm_mod.mamba_init(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 13, 32)) * 0.5
        y_par = ssm_mod.mamba_apply(params, cfg, x)
        cache = ssm_mod.mamba_cache_init(cfg, 1)
        y_seq = seq_from_decode(ssm_mod.mamba_decode, params, cfg, cache, x)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)


class TestMLSTMEquivalence:
    @pytest.mark.parametrize("chunk", [4, 8, 16])
    def test_chunked_equals_sequential(self, chunk):
        cfg = smoke_config("xlstm-350m").with_(
            d_model=32, n_heads=2, n_kv_heads=2, ssm_chunk=chunk, expand=2)
        params, _ = unbox_tree(xlstm_mod.mlstm_init(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
        y_par = xlstm_mod.mlstm_apply(params, cfg, x)
        cache = xlstm_mod.mlstm_cache_init(cfg, 2)
        y_seq = seq_from_decode(xlstm_mod.mlstm_decode, params, cfg, cache, x)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                                   rtol=3e-4, atol=3e-4)

    def test_long_sequence_stability(self):
        # exponential gating over a long sequence stays finite (stabilizer)
        cfg = smoke_config("xlstm-350m").with_(
            d_model=32, n_heads=2, n_kv_heads=2, ssm_chunk=16, expand=2)
        params, _ = unbox_tree(xlstm_mod.mlstm_init(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 32)) * 2.0
        y = xlstm_mod.mlstm_apply(params, cfg, x)
        assert bool(jnp.isfinite(y).all())


class TestSLSTMDecode:
    def test_scan_equals_stepwise(self):
        cfg = smoke_config("xlstm-350m").with_(
            d_model=32, n_heads=2, n_kv_heads=2, expand=2)
        params, _ = unbox_tree(xlstm_mod.slstm_init(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32)) * 0.5
        y_scan = xlstm_mod.slstm_apply(params, cfg, x)
        cache = xlstm_mod.slstm_cache_init(cfg, 2)
        y_seq = seq_from_decode(xlstm_mod.slstm_decode, params, cfg, cache, x)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)
