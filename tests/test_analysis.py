"""Cross-layer contract checker (`repro.analysis`): per-rule good/bad
fixtures, waiver/baseline round-trips, reporter determinism, the zero-
findings gate over the real tree, and seeded regressions proving each rule
family turns its bug class into a non-zero exit."""
import dataclasses
import json
import subprocess
import sys
import textwrap
import time
import warnings
from pathlib import Path

import pytest

from repro import env
from repro.analysis import engine
from repro.analysis.__main__ import main as analysis_main

REPO = Path(__file__).resolve().parent.parent
BASELINE = REPO / "src" / "repro" / "analysis" / "baseline.json"


# ---------------------------------------------------------------------------
# Fixture mini-repo: enough root markers for find_root + the cross-file
# facts (fault sites, documented obs names, declared env knobs) WITHOUT
# src/repro/dispatch/registry.py, so the DP project rules skip and nothing
# imports jax.
# ---------------------------------------------------------------------------


def make_repo(tmp_path: Path, files: dict) -> Path:
    root = tmp_path / "fixrepo"
    (root / "docs").mkdir(parents=True)
    (root / "docs" / "observability.md").write_text(textwrap.dedent("""\
        # schema
        | `demo.event` | instant | x |
        Counters: `demo.count`.
    """))
    (root / "src" / "repro").mkdir(parents=True)
    (root / "src" / "repro" / "fault.py").write_text(
        'SITES = ("demo.site", "other.site")\n')
    (root / "src" / "repro" / "env.py").write_text(textwrap.dedent("""\
        KNOBS = (
            EnvVar("REPRO_DEMO", "int", 0, "demo knob"),
        )
    """))
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return root


def run_rules(root: Path, only):
    return engine.run([root / "src"], only=only)


def rule_ids(report):
    return sorted({f.rule for f in report.findings})


# ---------------------------------------------------------------------------
# PK1xx: pallas kernel lints
# ---------------------------------------------------------------------------

GOOD_ROTATED = """\
    from repro.kernels.pltpu_compat import make_async_copy, double_buffer_rotate

    def _kernel(x_ref, o_ref, buf, sem):
        def dma(slot, idx):
            return make_async_copy(x_ref.at[idx], buf.at[slot], sem.at[slot])
        double_buffer_rotate(dma, 0, 4)
"""

BAD_UNWAITED = """\
    from repro.kernels.pltpu_compat import make_async_copy

    def _kernel(x_ref, o_ref, buf, sem):
        cp = make_async_copy(x_ref.at[0], buf.at[0], sem)
        cp.start()
"""

BAD_MANUAL_PAIR = """\
    from repro.kernels.pltpu_compat import make_async_copy

    def _kernel(x_ref, o_ref, buf, sem):
        cp = make_async_copy(x_ref.at[0], buf.at[0], sem)
        cp.start()
        cp.wait()
"""


class TestKernelRules:
    def test_pk101_unpaired_async_copy(self, tmp_path):
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": BAD_UNWAITED})
        report = run_rules(root, only=["PK101"])
        assert rule_ids(report) == ["PK101"]
        (f,) = report.findings
        assert "never waited" in f.msg
        assert f.waiver_key.endswith(":_kernel")  # line-free anchor

    def test_pk101_rotate_protocol_is_clean(self, tmp_path):
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": GOOD_ROTATED})
        assert run_rules(root, only=["PK101", "PK102"]).findings == []

    def test_pk102_manual_start_wait_pair(self, tmp_path):
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": BAD_MANUAL_PAIR})
        assert rule_ids(run_rules(root, only=["PK101", "PK102"])) == ["PK102"]

    def test_pk103_any_operand_direct_index(self, tmp_path):
        bad = """\
            def _kernel(x_ref, o_ref):
                o_ref[...] = x_ref[0]

            def call(x):
                return pallas_call(
                    _kernel,
                    in_specs=[BlockSpec(memory_space=ANY)],
                    out_specs=BlockSpec((8, 8), lambda i: (0, 0)),
                )(x)
        """
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": bad})
        (f,) = run_rules(root, only=["PK103"]).findings
        assert f.rule == "PK103" and "x_ref" in f.msg
        # .at[...] windows are the sanctioned access and stay clean
        good = bad.replace("x_ref[0]", "x_ref.at[0]")
        root2 = make_repo(tmp_path / "g", {"src/repro/kernels/k.py": good})
        assert run_rules(root2, only=["PK103"]).findings == []

    def test_pk104_bare_dot_in_kernel(self, tmp_path):
        bad = """\
            def _kernel(x_ref, o_ref):
                o_ref[...] = jnp.dot(x_ref[...], x_ref[...])

            def call(x):
                return pallas_call(
                    _kernel,
                    in_specs=[BlockSpec((8, 8), lambda i: (0, 0))],
                    out_specs=BlockSpec((8, 8), lambda i: (0, 0)),
                )(x)
        """
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": bad})
        (f,) = run_rules(root, only=["PK104"]).findings
        assert "dot_f32" in f.msg
        good = bad.replace("jnp.dot", "dot_f32_helper")  # any Name call
        root2 = make_repo(tmp_path / "g", {"src/repro/kernels/k.py": good})
        assert run_rules(root2, only=["PK104"]).findings == []

    def test_pk105_single_buffered_scratch(self, tmp_path):
        src = """\
            from functools import partial
            from repro.kernels.pltpu_compat import make_async_copy, double_buffer_rotate

            def _kernel(x_ref, o_ref, buf, sem):
                def dma(slot, idx):
                    return make_async_copy(x_ref.at[idx], buf.at[slot], sem.at[slot])
                double_buffer_rotate(dma, 0, 4)

            def call(x):
                return pallas_call(
                    partial(_kernel),
                    in_specs=[BlockSpec(memory_space=ANY)],
                    out_specs=BlockSpec((8, 8), lambda i: (0, 0)),
                    scratch_shapes=[VMEM((1, 8, 128), jnp.float32), SEM],
                )(x)
        """
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": src})
        (f,) = run_rules(root, only=["PK105"]).findings
        assert f.rule == "PK105" and "'buf'" in f.msg
        good = src.replace("VMEM((1, 8, 128)", "VMEM((2, 8, 128)")
        root2 = make_repo(tmp_path / "g", {"src/repro/kernels/k.py": good})
        assert run_rules(root2, only=["PK105"]).findings == []
        # symbolic double buffers (2 * hb, ...) count too
        sym = src.replace("VMEM((1, 8, 128)", "VMEM((2 * hb, 8, 128)")
        root3 = make_repo(tmp_path / "s", {"src/repro/kernels/k.py": sym})
        assert run_rules(root3, only=["PK105"]).findings == []


# ---------------------------------------------------------------------------
# RC2xx: registry coherence
# ---------------------------------------------------------------------------


class TestRegistryRules:
    def test_rc201_unknown_fault_site(self, tmp_path):
        src = """\
            from repro import fault

            def f():
                fault.maybe_fail("demo.site", step=1)      # registered
                fault.maybe_fail("bogus.site", step=2)     # not in SITES
                with fault.fault_scope("other.site:n=1, bogus.scope:p=0.5"):
                    pass
        """
        root = make_repo(tmp_path, {"src/repro/mod.py": src})
        report = run_rules(root, only=["RC201"])
        assert [f.waiver_key.rsplit(":", 1)[1] for f in report.findings] == \
            ["bogus.site", "bogus.scope"]  # finding order: by line

    def test_rc202_undocumented_obs_name(self, tmp_path):
        src = """\
            from repro.obs import trace as _ot
            from repro.obs import metrics as _om
            from repro.obs.trace import instant

            _C = _om.counter("demo.count")                 # documented
            _BAD = _om.counter("demo.rogue_counter")       # not in docs

            def f():
                _ot.instant("demo.event", x=1)             # documented
                instant("demo.rogue_event")                # direct import, bad
                private.counter("demo.also_rogue")         # private registry: exempt
        """
        root = make_repo(tmp_path, {"src/repro/mod.py": src})
        report = run_rules(root, only=["RC202"])
        names = sorted(f.waiver_key.rsplit(":", 1)[1] for f in report.findings)
        assert names == ["demo.rogue_counter", "demo.rogue_event"]

    def test_rc203_stray_env_reads(self, tmp_path):
        src = """\
            import os
            from repro import env as _env

            def f():
                a = _env.get("REPRO_DEMO")                  # declared: ok
                b = os.environ.get("REPRO_STRAY")           # direct read: bad
                c = os.environ["REPRO_SUBSCRIPT"]           # direct read: bad
                d = os.getenv("REPRO_GETENV")               # direct read: bad
                e = _env.get("REPRO_UNDECLARED")            # undeclared: bad
                f = os.environ.get("OTHER_PREFIX")          # out of scope
                return a, b, c, d, e, f
        """
        root = make_repo(tmp_path, {"src/repro/mod.py": src})
        report = run_rules(root, only=["RC203"])
        names = sorted(f.waiver_key.rsplit(":", 1)[1] for f in report.findings)
        assert names == ["REPRO_GETENV", "REPRO_STRAY", "REPRO_SUBSCRIPT",
                         "REPRO_UNDECLARED"]

    def test_e000_syntax_error_is_a_finding(self, tmp_path):
        root = make_repo(tmp_path, {"src/repro/mod.py": "def f(:\n"})
        report = run_rules(root, only=["RC203"])  # E000 fires regardless
        assert rule_ids(report) == ["E000"]


# ---------------------------------------------------------------------------
# Engine mechanics: baseline/waivers, reporters, determinism
# ---------------------------------------------------------------------------


class TestEngine:
    def test_waiver_roundtrip_and_unused_waiver(self, tmp_path):
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": BAD_UNWAITED})
        report = run_rules(root, only=["PK101"])
        (f,) = report.findings
        waived = engine.run([root / "src"], only=["PK101"],
                            baseline={f.waiver_key: "known debt"})
        assert waived.findings == [] and len(waived.waived) == 1
        stale = engine.run([root / "src"], only=["PK101"],
                           baseline={f.waiver_key: "x",
                                     "PK101:gone.py:fn": "stale"})
        assert stale.unused_waivers == ["PK101:gone.py:fn"]

    def test_cli_exit_codes(self, tmp_path, capsys):
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": BAD_UNWAITED})
        assert analysis_main([str(root / "src"), "--no-baseline",
                              "--only", "PK101"]) == 1
        assert analysis_main([str(root / "src"), "--no-baseline",
                              "--only", "PK102"]) == 0
        assert analysis_main(["--list-rules"]) == 0
        listed = capsys.readouterr().out
        for rid in ("PK101", "PK102", "PK103", "PK104", "PK105",
                    "DP301", "DP302", "RC201", "RC202", "RC203"):
            assert rid in listed
        assert analysis_main([str(root / "nope")]) == 2

    def test_json_reporter_schema(self, tmp_path):
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": BAD_UNWAITED})
        report = run_rules(root, only=["PK101"])
        payload = json.loads(engine.render_json(report))
        assert payload["version"] == engine.JSON_SCHEMA_VERSION
        assert set(payload) == {"version", "files", "findings", "waived",
                                "unused_waivers"}
        (f,) = payload["findings"]
        assert set(f) == {"rule", "path", "line", "msg", "waiver_key"}
        assert f["path"].startswith("src/")  # root-relative POSIX

    def test_cross_process_determinism(self):
        def one_run():
            return subprocess.run(
                [sys.executable, "-m", "repro.analysis", "src", "--json"],
                cwd=REPO, capture_output=True,
                env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
        a, b = one_run(), one_run()
        assert a.returncode == 0, a.stdout.decode() + a.stderr.decode()
        assert a.stdout == b.stdout  # byte-identical reports

    def test_committed_baseline_matches_shipped_tree(self):
        # the tier-1 gate: the real src/ under the committed baseline is clean
        report = engine.run([REPO / "src"],
                            baseline=engine.load_baseline(BASELINE))
        assert report.findings == [], engine.render_text(report)
        assert report.unused_waivers == []
        assert report.files > 50

    def test_analyzer_runtime_budget(self):
        start = time.monotonic()
        engine.run([REPO / "src"], baseline=engine.load_baseline(BASELINE))
        assert time.monotonic() - start < 10.0


# ---------------------------------------------------------------------------
# Seeded regressions: each rule family catches its bug class end-to-end
# ---------------------------------------------------------------------------


class TestSeededRegressions:
    def test_dp301_catches_dtype_undercounting_predicate(self):
        from repro.dispatch import registry as R

        base = next(s for s in R.REGISTRY.candidates("linear")
                    if s.backend == "pallas"
                    and s.name.startswith("compressed_pallas"))
        # the PR 3 bug, reintroduced: a predicate that assumes bf16 operands
        # under-counts every f32 key's footprint 2x
        bf16_only = dataclasses.replace(
            base, name=base.name.partition("@")[0] + "@seededbug",
            vmem_bytes=lambda key, _vm=base.vmem_bytes: _vm(
                dataclasses.replace(key, dtype="bf16")))
        R.REGISTRY.register(bf16_only)
        try:
            report = engine.run([REPO / "src"], only=["DP301"])
            assert any("@seededbug" in f.msg and "f32" in f.msg
                       for f in report.findings), \
                engine.render_text(report)
        finally:
            R.REGISTRY._impls["linear"].pop(bf16_only.name, None)
            R.REGISTRY.generation += 1
        # and the live registry itself is clean
        assert engine.run([REPO / "src"], only=["DP301", "DP302"]).findings \
            == []

    def test_pk101_catches_unwaited_copy_via_cli(self, tmp_path):
        root = make_repo(tmp_path, {"src/repro/kernels/k.py": BAD_UNWAITED})
        assert analysis_main([str(root / "src"), "--no-baseline"]) == 1

    def test_rc201_catches_unregistered_site_via_cli(self, tmp_path):
        root = make_repo(tmp_path, {
            "src/repro/mod.py":
            "from repro import fault\nfault.maybe_fail('new.unregistered')\n"})
        assert analysis_main([str(root / "src"), "--no-baseline"]) == 1

    def test_rc203_catches_stray_env_read_via_cli(self, tmp_path):
        root = make_repo(tmp_path, {
            "src/repro/mod.py":
            "import os\nx = os.environ.get('REPRO_NEW_THING')\n"})
        assert analysis_main([str(root / "src"), "--no-baseline"]) == 1


# ---------------------------------------------------------------------------
# Satellites: the env registry and the fault unknown-site warning
# ---------------------------------------------------------------------------


class TestEnvRegistry:
    def test_parse_kinds(self, monkeypatch):
        monkeypatch.delenv("REPRO_OBS", raising=False)
        assert env.get("REPRO_OBS") is False
        monkeypatch.setenv("REPRO_OBS", "on")
        assert env.get("REPRO_OBS") is True
        monkeypatch.setenv("REPRO_DISPATCH", "off")
        assert env.get("REPRO_DISPATCH") is False
        monkeypatch.delenv("REPRO_DISPATCH", raising=False)
        assert env.get("REPRO_DISPATCH") is True
        monkeypatch.setenv("REPRO_OBS_RING", "not-an-int")
        assert env.get("REPRO_OBS_RING") == 65536  # unparsable -> default
        monkeypatch.setenv("REPRO_OBS_TRACE", "")
        assert env.get("REPRO_OBS_TRACE") is None  # empty string -> default
        monkeypatch.setenv("REPRO_FAULTS_SEED", "7")
        assert env.get("REPRO_FAULTS_SEED") == 7

    def test_undeclared_knob_raises(self):
        with pytest.raises(KeyError, match="REPRO_NOT_A_KNOB"):
            env.get("REPRO_NOT_A_KNOB")

    def test_doc_table_pinned_to_registry(self):
        doc = (REPO / "docs" / "static-analysis.md").read_text()
        assert env.env_table_md() in doc, \
            "docs/static-analysis.md env table drifted; re-run " \
            "`python -m repro.env` and paste between the env-table markers"

    def test_knobs_sorted_and_prefixed(self):
        names = env.declared()
        assert list(names) == sorted(names)
        assert all(n.startswith("REPRO_") for n in names)


class TestUnknownSiteWarning:
    def test_warns_once_and_counts(self):
        from repro import fault

        site = "test_analysis.never_registered"
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            with fault.fault_scope(f"{site}:n=1"):
                pass
            with fault.fault_scope(f"{site}:n=1"):  # second arm: silent
                pass
        ours = [w for w in caught if site in str(w.message)]
        assert len(ours) == 1
        assert issubclass(ours[0].category, RuntimeWarning)

    def test_registered_sites_stay_silent(self):
        from repro import fault

        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            with fault.fault_scope("scheduler.iter:n=1"):
                pass
        assert [w for w in caught if "fault site" in str(w.message)] == []
