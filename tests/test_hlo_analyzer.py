"""Validate the loop-aware HLO analyzer against XLA's own cost analysis on
loop-free graphs, and against hand-computed trip-count math on scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_analyzer import HloCost, analyze_hlo, xla_cost_analysis


def compiled_text(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return c, c.as_text()


class TestHloAnalyzer:
    def test_plain_matmul_flops(self):
        x = jnp.zeros((128, 256), jnp.float32)
        w = jnp.zeros((256, 64), jnp.float32)
        c, txt = compiled_text(lambda a, b: a @ b, x, w)
        got = analyze_hlo(txt)
        expect = 2 * 128 * 256 * 64
        assert got["flops"] == pytest.approx(expect, rel=0.01)
        # agrees with XLA's own count on a loop-free graph
        assert got["flops"] == pytest.approx(xla_cost_analysis(c)["flops"], rel=0.05)

    def test_batched_dot(self):
        x = jnp.zeros((4, 32, 16))
        w = jnp.zeros((4, 16, 8))
        _, txt = compiled_text(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), x, w)
        got = analyze_hlo(txt)
        assert got["flops"] == pytest.approx(2 * 4 * 32 * 16 * 8, rel=0.01)

    def test_scan_multiplies_trip_count(self):
        x = jnp.zeros((64, 64))
        w = jnp.zeros((64, 64))

        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        c, txt = compiled_text(f, x, w)
        got = analyze_hlo(txt)
        per_iter = 2 * 64 * 64 * 64
        assert got["flops"] >= 7 * per_iter
        assert got["flops"] < 7 * per_iter * 1.5  # elementwise slack
        # XLA undercounts — that's the bug this module exists to fix
        assert xla_cost_analysis(c)["flops"] < 2 * per_iter

    def test_nested_scan(self):
        def f(x, w):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        x = jnp.zeros((32, 32))
        w = jnp.zeros((32, 32))
        _, txt = compiled_text(f, x, w)
        got = analyze_hlo(txt)
        per = 2 * 32 * 32 * 32
        assert got["flops"] >= 15 * per
        assert got["flops"] < 15 * per * 1.5

    def test_bytes_positive_and_fusion_boundary(self):
        x = jnp.zeros((1024, 1024))
        _, txt = compiled_text(lambda a: jnp.tanh(a) * 2 + 1, x)
        got = analyze_hlo(txt)
        # boundary traffic should be ~ read + write of the array, not 4 passes
        nbytes = 1024 * 1024 * 4
        assert nbytes * 1.5 <= got["bytes"] <= nbytes * 6
