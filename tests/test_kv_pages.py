"""Paged KV serving-memory tier (repro.serve.kv_pages): PagePool allocator
invariants (unit + fuzzed), packed-prefill stream construction, and
greedy-decoding equivalence of the paged scheduler against the contiguous
slot path and the static engine."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.models import registry as reg
from repro.serve import (
    Engine,
    PageError,
    PagePool,
    Request,
    Scheduler,
    ServeConfig,
    pack_prompts,
    synthetic_trace,
)


def _smoke_cfg(arch="smollm-360m", sparsity=0.5):
    scfg = SparsityConfig(sparsity=sparsity, m=None, tile=None,
                          format="compressed_xla", min_dim=64)
    return smoke_config(arch).with_(sparsity=scfg)


@pytest.fixture(scope="module")
def engine():
    cfg = _smoke_cfg()
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_new_tokens=8))


# ---------------------------------------------------------------------------
# PagePool allocator
# ---------------------------------------------------------------------------


class TestPagePool:
    def test_alloc_free_roundtrip(self):
        pool = PagePool(8, page_size=4)
        t = pool.alloc(0, 10)  # 10 rows -> 3 pages
        assert len(t.pages) == 3 and t.capacity == 12
        assert pool.n_free == 5 and pool.n_mapped == 3
        pool.free(0)
        assert pool.n_free == 8 and pool.n_mapped == 0 and pool.n_seqs == 0

    def test_trash_page_is_outside_the_pool(self):
        pool = PagePool(8, page_size=4)
        assert pool.trash_page == 8
        t = pool.alloc(0, 32)  # whole pool
        assert sorted(t.pages) == list(range(8))  # trash page never mapped

    def test_pages_for_and_can_admit(self):
        pool = PagePool(4, page_size=8)
        assert pool.pages_for(1) == 1
        assert pool.pages_for(8) == 1
        assert pool.pages_for(9) == 2
        assert pool.can_admit(32) and not pool.can_admit(33)
        pool.alloc(0, 17)  # 3 pages
        assert pool.can_admit(8) and not pool.can_admit(9)

    def test_double_alloc_raises(self):
        pool = PagePool(4, page_size=4)
        pool.alloc(0, 4)
        with pytest.raises(PageError, match="already holds"):
            pool.alloc(0, 4)

    def test_insufficient_pages_raises_and_leaves_pool_intact(self):
        pool = PagePool(2, page_size=4)
        with pytest.raises(PageError, match="free"):
            pool.alloc(0, 12)
        assert pool.n_free == 2
        pool.check_invariants()

    def test_advance_bounded_by_capacity(self):
        pool = PagePool(4, page_size=4)
        pool.alloc(0, 6)  # capacity 8
        for _ in range(8):
            pool.advance(0)
        with pytest.raises(PageError, match="capacity"):
            pool.advance(0)

    def test_free_unknown_seq_raises(self):
        pool = PagePool(4, page_size=4)
        with pytest.raises(PageError, match="no page table"):
            pool.free(3)

    def test_grow_extends_mapping(self):
        pool = PagePool(8, page_size=4)
        pool.alloc(0, 4)
        t = pool.grow(0, 13)  # -> 4 pages
        assert len(t.pages) == 4 and t.capacity == 16
        pool.check_invariants()

    def test_table_array_pads_with_trash_page(self):
        pool = PagePool(8, page_size=4)
        pool.alloc(1, 10)  # slot 1 only
        arr = pool.table_array(n_slots=3, width=4)
        assert arr.shape == (3, 4) and arr.dtype == np.int32
        # inactive slots + entries past the mapping point at the trash page
        assert (arr[0] == pool.trash_page).all()
        assert (arr[2] == pool.trash_page).all()
        assert list(arr[1, :3]) == pool.table(1).pages
        assert arr[1, 3] == pool.trash_page

    def test_table_array_overflow_raises(self):
        pool = PagePool(8, page_size=4)
        pool.alloc(0, 32)  # 8 pages > width 4
        with pytest.raises(PageError):
            pool.table_array(n_slots=1, width=4)

    def test_fragmentation_tracks_unused_tail_rows(self):
        pool = PagePool(8, page_size=8)
        pool.alloc(0, 9)  # 2 pages = 16 rows mapped
        pool.advance(0, by=9)  # 9 used
        assert pool.used_rows == 9 and pool.mapped_rows == 16
        assert pool.fragmentation() == pytest.approx(7 / 16)

    def test_fuzzed_interleavings_hold_invariants(self):
        """Random admit/advance/retire interleavings: every intermediate
        state passes check_invariants and retiring everything returns the
        pool to fully-free (no leak, no double-map)."""
        rng = np.random.default_rng(0)
        for trial in range(20):
            pool = PagePool(int(rng.integers(4, 16)),
                            page_size=int(rng.integers(1, 9)))
            live = {}
            next_seq = 0
            for _ in range(200):
                op = rng.random()
                if op < 0.45:
                    rows = int(rng.integers(1, 4 * pool.page_size))
                    if pool.can_admit(rows):
                        t = pool.alloc(next_seq, rows)
                        live[next_seq] = t
                        next_seq += 1
                elif op < 0.75 and live:
                    sid = int(rng.choice(list(live)))
                    t = live[sid]
                    if t.pos < t.capacity:
                        pool.advance(sid)
                elif live:
                    sid = int(rng.choice(list(live)))
                    pool.free(sid)
                    del live[sid]
                pool.check_invariants()
            for sid in list(live):
                pool.free(sid)
            assert pool.n_free == pool.n_pages and pool.n_mapped == 0, \
                f"trial {trial} leaked pages"


# ---------------------------------------------------------------------------
# Packed prefill stream
# ---------------------------------------------------------------------------


class TestPackPrompts:
    def test_stream_layout(self):
        packed = pack_prompts([[5, 6, 7], [8, 9]], slots=[2, 0])
        np.testing.assert_array_equal(packed.tokens, [5, 6, 7, 8, 9])
        np.testing.assert_array_equal(packed.slot_ids, [2, 2, 2, 0, 0])
        np.testing.assert_array_equal(packed.positions, [0, 1, 2, 0, 1])
        np.testing.assert_array_equal(packed.last_idx, [2, 4])
        np.testing.assert_array_equal(packed.seq_lens, [3, 2])
        assert packed.total_tokens == 5

    def test_errors(self):
        with pytest.raises(PageError, match="mismatch"):
            pack_prompts([[1]], slots=[0, 1])
        with pytest.raises(PageError, match="empty batch"):
            pack_prompts([], slots=[])
        with pytest.raises(PageError, match="empty prompt"):
            pack_prompts([[1], []], slots=[0, 1])


# ---------------------------------------------------------------------------
# Paged scheduler equivalence (greedy)
# ---------------------------------------------------------------------------


class TestPagedSchedulerEquivalence:
    def test_paged_matches_contiguous_and_static(self, engine):
        """The paged scheduler (packed prefill + paged decode) must emit
        token-identical greedy completions to the contiguous slot path AND
        to the static per-request engine."""
        engine.scfg.max_new_tokens = 8
        trace = synthetic_trace(6, seed=5, vocab=engine.cfg.vocab_size,
                                prompt_lens=(3, 14), new_tokens=(2, 8))
        contig = {c.uid: c.tokens
                  for c in Scheduler(engine, n_slots=3,
                                     prefill_chunk=4).run(trace)}
        paged_sched = Scheduler(engine, n_slots=3, prefill_chunk=4,
                                paged=True, page_size=8)
        paged = {c.uid: c.tokens for c in paged_sched.run(trace)}
        assert sorted(paged) == [r.uid for r in trace]
        for req in trace:
            np.testing.assert_array_equal(
                paged[req.uid], contig[req.uid],
                err_msg=f"paged vs contiguous, uid={req.uid}")
            engine.scfg.max_new_tokens = req.max_new_tokens
            ref = engine.generate(req.prompt[None, :])
            np.testing.assert_array_equal(
                paged[req.uid], ref["tokens"][0],
                err_msg=f"paged vs static, uid={req.uid}")
        stats = paged_sched.page_stats
        assert stats["pages_peak"] > 0
        assert stats["pages_active"] == 0  # everything retired

    def test_tight_budget_queues_but_completes(self, engine):
        """With pages for only ~one max-size request, admission serializes
        (free-page accounting) but every request still finishes with the
        same greedy tokens."""
        engine.scfg.max_new_tokens = 4
        reqs = [Request(uid=u, prompt=(np.arange(5, dtype=np.int32) + 2 + u),
                        max_new_tokens=4) for u in range(3)]
        contig = {c.uid: c.tokens
                  for c in Scheduler(engine, n_slots=3,
                                     prefill_chunk=4).run(reqs)}
        # 9 rows/request at ps=4 -> 3 pages each; 4 pages total => one at a
        # time (plus headroom the next admission can't fit in)
        sched = Scheduler(engine, n_slots=3, prefill_chunk=4, paged=True,
                          page_size=4, kv_budget_rows=16)
        paged = {c.uid: c.tokens for c in sched.run(reqs)}
        for u in contig:
            np.testing.assert_array_equal(paged[u], contig[u])

    def test_budget_too_small_for_one_request_raises(self, engine):
        reqs = [Request(uid=0, prompt=np.arange(8, dtype=np.int32) + 1,
                        max_new_tokens=8)]
        sched = Scheduler(engine, n_slots=2, prefill_chunk=4, paged=True,
                          page_size=4, kv_budget_rows=8)
        with pytest.raises(ValueError, match="cannot hold"):
            sched.run(reqs)

    def test_page_size_validation(self, engine):
        with pytest.raises(ValueError, match="page_size"):
            Scheduler(engine, n_slots=2, paged=True, page_size=0)
