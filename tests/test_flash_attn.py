"""Flash-attention Pallas kernel vs the naive oracle (interpret mode),
swept over shapes, dtypes, GQA ratios, causal/full, ragged blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import (
    flash_attention,
    flash_attention_pallas,
    flash_attention_ref,
)
from repro.models.attention import sdpa_gqa

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "bh,sq,sk,d,bq,bk,causal",
        [
            (2, 32, 32, 16, 8, 8, True),
            (1, 16, 48, 16, 8, 16, False),   # cross-attn-like
            (2, 24, 24, 32, 16, 8, True),    # ragged q blocks
            (1, 8, 8, 16, 128, 128, True),   # blocks > dims
            (3, 33, 17, 16, 8, 8, True),     # ragged both
        ],
    )
    def test_matches_ref(self, dtype, bh, sq, sk, d, bq, bk, causal):
        ks = jax.random.split(jax.random.PRNGKey(bh * sq + sk), 3)
        q = jax.random.normal(ks[0], (bh, sq, d), dtype)
        k = jax.random.normal(ks[1], (bh, sk, d), dtype)
        v = jax.random.normal(ks[2], (bh, sk, d), dtype)
        ref = flash_attention_ref(q, k, v, causal=causal)
        out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                     block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **TOL[dtype])

    @pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (5, 2)])
    def test_gqa_wrapper_matches_sdpa(self, h, kvh):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        b, sq, d = 2, 16, 16
        q = jax.random.normal(ks[0], (b, sq, h, d))
        k = jax.random.normal(ks[1], (b, sq, kvh, d))
        v = jax.random.normal(ks[2], (b, sq, kvh, d))
        ref = sdpa_gqa(q, k, v, causal=True)
        out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)

    def test_numerical_stability_large_logits(self):
        # online softmax must survive logits that overflow a naive exp
        q = jnp.full((1, 8, 16), 30.0)
        k = jnp.full((1, 8, 16), 30.0)
        v = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 16))
        out = flash_attention_pallas(q, k, v, causal=False, block_q=4,
                                     block_k=4, interpret=True)
        assert bool(jnp.isfinite(out).all())


def test_model_level_pallas_attention():
    """attn_impl='pallas' routes model attention through the flash kernel
    (interpret mode on CPU) and matches the naive model bit-for-tolerance."""
    from repro.configs import smoke_config
    from repro.models import registry as reg

    cfg_n = smoke_config("qwen2-0.5b").with_(attn_impl="naive", n_layers=1)
    cfg_p = cfg_n.with_(attn_impl="pallas")
    params, _ = reg.init_params(cfg_n, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg_n.vocab_size)}
    ln = reg.forward_fn(cfg_n)(params, batch)
    lp = reg.forward_fn(cfg_p)(params, batch)
    np.testing.assert_allclose(np.asarray(ln), np.asarray(lp), rtol=2e-4, atol=2e-4)
