"""Minimal deterministic stand-in for the `hypothesis` API this suite uses.

The container may not ship `hypothesis`; rather than losing collection of
every module that imports it (`test_pruning`, `test_sharding_rules`,
`test_substrate`), `conftest.py` installs this stub into ``sys.modules`` when
the real package is absent.  Property tests then still *run* — each
``@given`` draws a small, deterministically-seeded set of examples instead of
hypothesis' adaptive search.  When the real package is installed the stub is
never used and full property testing is active.

Supported surface (extend as tests need it): ``given``, ``settings``,
``strategies.sampled_from / integers / lists / floats / booleans``.
"""
from __future__ import annotations

import functools
import random

STUB_MAX_EXAMPLES = 5  # cap per test: the stub trades coverage for runtime


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("stub strategy filter never satisfied")

        return _Strategy(draw)


class _Strategies:
    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements._draw(rng) for _ in range(n)]

        return _Strategy(draw)


strategies = _Strategies()


def settings(**kw):
    """Records the requested settings on the test; `given` honours
    max_examples (capped) and ignores the rest (deadline etc.)."""

    def deco(fn):
        fn._stub_settings = kw
        return fn

    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        cfg = getattr(fn, "_stub_settings", {})
        n = min(int(cfg.get("max_examples", STUB_MAX_EXAMPLES)), STUB_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(fn.__qualname__)  # deterministic per test
            for _ in range(max(n, 1)):
                drawn = [s._draw(rng) for s in strats]
                kdrawn = {k: s._draw(rng) for k, s in kwstrats.items()}
                fn(*args, *drawn, **kwargs, **kdrawn)

        # pytest follows __wrapped__ when inspecting the signature and would
        # treat the drawn parameters as fixtures to inject; hide it so the
        # wrapper's (*args, **kwargs) signature is what collection sees
        del wrapper.__wrapped__
        wrapper.hypothesis_stub = True
        return wrapper

    return deco


class HealthCheck:
    too_slow = data_too_large = filter_too_much = all = None


def assume(condition):
    if not condition:
        raise _StubAssumeError("stub assume() failed — refine the strategy")


class _StubAssumeError(AssertionError):
    pass
