"""Continuous-batching scheduler: equivalence vs the static engine, slot-pool
invariants, chunked prefill, per-phase dispatch plans, and the EOS fixes."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dispatch
from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.core.sparse_linear import linear_init, unbox_tree
from repro.dispatch import ProfileDB
from repro.models import registry as reg
from repro.serve import (
    STATUSES,
    Engine,
    Request,
    Scheduler,
    ServeConfig,
    SlotError,
    SlotPool,
    synthetic_trace,
)

REPO = Path(__file__).resolve().parent.parent


def _smoke_cfg(arch="smollm-360m", sparsity=0.5):
    scfg = SparsityConfig(sparsity=sparsity, m=None, tile=None,
                          format="compressed_xla", min_dim=64)
    return smoke_config(arch).with_(sparsity=scfg)


@pytest.fixture(scope="module")
def engine():
    cfg = _smoke_cfg()
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_new_tokens=8))


# ---------------------------------------------------------------------------
# Scheduler vs static engine (greedy equivalence, per request)
# ---------------------------------------------------------------------------


class TestSchedulerEquivalence:
    def test_mixed_length_batch_matches_static_engine(self, engine):
        trace = synthetic_trace(6, seed=3, vocab=engine.cfg.vocab_size,
                                prompt_lens=(3, 14), new_tokens=(2, 8))
        sched = Scheduler(engine, n_slots=3, prefill_chunk=4)
        completions = {c.uid: c for c in sched.run(trace)}
        assert sorted(completions) == [r.uid for r in trace]
        for req in trace:
            engine.scfg.max_new_tokens = req.max_new_tokens
            ref = engine.generate(req.prompt[None, :])
            got = completions[req.uid]
            np.testing.assert_array_equal(
                got.tokens, ref["tokens"][0],
                err_msg=f"uid={req.uid} prompt_len={len(req.prompt)}")

    def test_streaming_yields_before_trace_ends(self, engine):
        """run_iter retires short requests while long ones still decode."""
        engine.scfg.max_new_tokens = 8
        reqs = [Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                        max_new_tokens=8),
                Request(uid=1, prompt=np.arange(3, dtype=np.int32) + 1,
                        max_new_tokens=2)]
        sched = Scheduler(engine, n_slots=2, prefill_chunk=4)
        first = next(iter(sched.run_iter(reqs)))
        assert first.uid == 1  # the small budget retires first

    def test_padded_final_chunk_grows_cache_not_corrupts(self, engine):
        """prompt=9 with chunk=8 pads the final chunk to rows [8, 16); the
        auto-sized cache must hold the padded write (a clamped
        dynamic_update_slice would silently shift back over real rows)."""
        rng = np.random.default_rng(11)
        req = Request(uid=0, max_new_tokens=3,
                      prompt=rng.integers(0, engine.cfg.vocab_size,
                                          (9,)).astype(np.int32))
        sched = Scheduler(engine, n_slots=1, prefill_chunk=8)
        comp = sched.run([req])[0]
        engine.scfg.max_new_tokens = req.max_new_tokens
        ref = engine.generate(req.prompt[None, :])
        np.testing.assert_array_equal(comp.tokens, ref["tokens"][0])

    def test_explicit_max_len_too_small_for_chunk_padding_raises(self, engine):
        req = Request(uid=0, prompt=np.arange(9, dtype=np.int32) + 1,
                      max_new_tokens=2)
        sched = Scheduler(engine, n_slots=1, max_len=11, prefill_chunk=8)
        with pytest.raises(ValueError, match="pads the longest prompt"):
            sched.run([req])

    def test_rejects_recurrent_families(self):
        cfg = _smoke_cfg("xlstm-350m", sparsity=0.0)
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params)
        with pytest.raises(ValueError, match="attention family"):
            Scheduler(eng)


# ---------------------------------------------------------------------------
# Chunked prefill primitive
# ---------------------------------------------------------------------------


class TestChunkedPrefill:
    def test_matches_full_prefill(self, engine):
        cfg = engine.cfg
        b, s, max_len, c_w = 2, 11, 24, 4
        toks = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (b, s)).astype(np.int32)
        logits_full, cache_full = engine._prefill(
            engine.params, {"tokens": jnp.asarray(toks)})
        cache = reg.cache_init_fn(cfg, b, max_len)()
        for start in range(0, s, c_w):
            chunk = toks[:, start:start + c_w]
            if chunk.shape[1] < c_w:
                chunk = np.pad(chunk, ((0, 0), (0, c_w - chunk.shape[1])))
            logits, cache = engine.prefill_chunk_step(cache, chunk, start)
        last = logits[:, (s - 1) % c_w]
        np.testing.assert_allclose(np.asarray(last),
                                   np.asarray(logits_full[:, -1]),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cache["k"][:, :, :s]),
                                   np.asarray(cache_full["k"]),
                                   rtol=1e-4, atol=1e-5)

    def test_decode_accepts_position_vector(self, engine):
        """Scalar pos and an equal [B] vector produce identical steps."""
        cfg = engine.cfg
        b, s, max_len = 2, 6, 12
        toks = np.random.default_rng(1).integers(
            0, cfg.vocab_size, (b, s)).astype(np.int32)
        _, cache = engine.prefill_step(toks, max_len)
        tok = jnp.asarray([[5], [7]], jnp.int32)
        l1, c1 = reg.decode_fn(cfg)(engine.params, dict(cache), tok,
                                    jnp.asarray(s, jnp.int32))
        l2, c2 = reg.decode_fn(cfg)(engine.params, dict(cache), tok,
                                    jnp.full((b,), s, jnp.int32))
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        np.testing.assert_array_equal(np.asarray(c1["k"]), np.asarray(c2["k"]))


# ---------------------------------------------------------------------------
# Slot pool invariants
# ---------------------------------------------------------------------------


class TestSlotPool:
    def test_no_leak_no_double_assign_random_order(self):
        rng = np.random.default_rng(0)
        pool = SlotPool(n_slots=5, max_len=64)
        held = []
        for _ in range(500):
            if held and (pool.n_free == 0 or rng.random() < 0.5):
                idx = held.pop(rng.integers(len(held)))
                pool.free(idx)
            else:
                slot = pool.alloc(request_id=int(rng.integers(1000)))
                assert slot.index not in held
                held.append(slot.index)
            pool.check_invariants()
            assert pool.n_free + pool.n_active == pool.n_slots
        for idx in held:
            pool.free(idx)
        assert pool.n_free == pool.n_slots

    def test_double_free_and_exhaustion_raise(self):
        pool = SlotPool(n_slots=1, max_len=8)
        slot = pool.alloc(request_id=0)
        with pytest.raises(SlotError, match="no free slots"):
            pool.alloc(request_id=1)
        pool.free(slot.index)
        with pytest.raises(SlotError, match="inactive"):
            pool.free(slot.index)

    def test_advance_bounds_checked(self):
        pool = SlotPool(n_slots=1, max_len=4)
        slot = pool.alloc(request_id=0)
        pool.advance(slot.index, by=4)
        with pytest.raises(SlotError, match="exceeds"):
            pool.advance(slot.index)

    def test_pool_drains_clean_after_run(self, engine):
        trace = synthetic_trace(5, seed=7, vocab=engine.cfg.vocab_size,
                                prompt_lens=(2, 8), new_tokens=(1, 4))
        sched = Scheduler(engine, n_slots=2, prefill_chunk=4)
        comps = sched.run(trace)
        assert len(comps) == len(trace)
        assert sched.stats["generated_tokens"] == sum(
            c.n_generated for c in comps)


# ---------------------------------------------------------------------------
# Per-phase dispatch
# ---------------------------------------------------------------------------


PLAN_SNIPPET = r"""
import json, sys
import jax
from repro import dispatch
from repro.core.pruning import SparsityConfig
from repro.core.sparse_linear import linear_init, unbox_tree
from repro.dispatch import ProfileDB

dispatch.set_db(ProfileDB(path=sys.argv[1], autosave=False))
cfg = SparsityConfig(sparsity=0.5, format="compressed_xla", min_dim=8, tile=16)
vals, _ = unbox_tree(linear_init(jax.random.PRNGKey(0), 64, 64, cfg))
plan = dispatch.plan_params({"l": vals},
                            phase_hints={"prefill": 1024, "decode": 8})
print(json.dumps(plan, sort_keys=True))
"""


class TestPerPhaseDispatch:
    @pytest.fixture()
    def db(self, tmp_path):
        db = ProfileDB(path=str(tmp_path / "db.json"), autosave=False)
        prev = dispatch.get_db()
        dispatch.set_db(db)
        yield db
        dispatch.set_db(prev)

    def test_phase_tokens_distinct(self):
        k_pre = dispatch.linear_key(1024, 64, 64, 8, 16, phase="prefill")
        k_dec = dispatch.linear_key(8, 64, 64, 8, 16, phase="decode")
        assert "|ph:prefill" in k_pre.token and "|ph:decode" in k_dec.token
        assert k_pre.token != k_dec.token
        # untagged keys keep the exact pre-phase token format
        assert "|ph:" not in dispatch.linear_key(8, 64, 64, 8, 16).token

    def test_plan_params_phase_hints(self, db):
        cfg = SparsityConfig(sparsity=0.5, format="compressed_xla",
                             min_dim=8, tile=16)
        vals, _ = unbox_tree(linear_init(jax.random.PRNGKey(0), 64, 64, cfg))
        plan = dispatch.plan_params(
            {"l": vals}, phase_hints={"prefill": 1024, "decode": 8})
        phases = sorted(t.split("|ph:")[-1] for t in plan if "|ph:" in t)
        assert phases == ["decode", "prefill"]

    def test_profiled_phases_land_in_db(self, db):
        cfg = SparsityConfig(sparsity=0.5, format="compressed_xla",
                             min_dim=8, tile=16)
        vals, _ = unbox_tree(linear_init(jax.random.PRNGKey(0), 64, 64, cfg))
        dispatch.plan_params({"l": vals}, profile=True,
                             phase_hints={"prefill": 64, "decode": 8})
        tokens = list(db._entries)
        assert any("|ph:prefill" in t for t in tokens)
        assert any("|ph:decode" in t for t in tokens)

    def test_engine_plans_both_phases(self, db, engine):
        plan = dispatch.plan_params(
            engine.params, phase_hints={"prefill": 8 * 128, "decode": 8})
        assert any("|ph:prefill" in t for t in plan)
        assert any("|ph:decode" in t for t in plan)
        assert set(plan) <= set(dispatch.plan_params(
            engine.params, phase_hints={"prefill": 8 * 128, "decode": 8}))

    def test_scheduler_plan_matches_trace_geometry(self, engine):
        """The scheduler re-plans with its real shapes: prefill keys bucket
        by the chunk width, decode keys by the slot count — the engine's
        static-path hints would never match the scheduler's traces."""
        from repro.dispatch import bucket_batch

        sched = Scheduler(engine, n_slots=3, prefill_chunk=4)
        pre = [t for t in sched.dispatch_plan if "|ph:prefill" in t]
        dec = [t for t in sched.dispatch_plan if "|ph:decode" in t]
        assert pre and dec
        assert all(f"|b{bucket_batch(4)}|" in t for t in pre)
        assert all(f"|b{bucket_batch(3)}|" in t for t in dec)
        # merged into the engine's plan so both consumers see one view
        assert set(sched.dispatch_plan) <= set(engine.dispatch_plan)

    def test_phase_scope_tags_linear_impl_keys(self):
        with dispatch.phase_scope("decode"):
            assert dispatch.current_phase() == "decode"
            with dispatch.phase_scope("prefill"):
                assert dispatch.current_phase() == "prefill"
            assert dispatch.current_phase() == "decode"
        assert dispatch.current_phase() == ""

    def test_plan_deterministic_across_processes(self, tmp_path):
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        outs = []
        for i in range(2):
            r = subprocess.run(
                [sys.executable, "-c", PLAN_SNIPPET,
                 str(tmp_path / f"db{i}.json")],
                capture_output=True, text=True, timeout=600, env=env,
                cwd=REPO)
            assert r.returncode == 0, r.stderr[-2000:]
            outs.append(json.loads(r.stdout))
        assert outs[0] == outs[1]
        assert any("|ph:prefill" in t for t in outs[0])


# ---------------------------------------------------------------------------
# Engine satellite fixes (shared default config, EOS masking)
# ---------------------------------------------------------------------------


class TestEngineFixes:
    def test_serve_config_not_shared_across_engines(self, engine):
        cfg = engine.cfg
        e2 = Engine(cfg, engine.params)
        e3 = Engine(cfg, engine.params)
        e2.scfg.max_new_tokens = 99
        assert e3.scfg.max_new_tokens != 99
        assert e2.scfg is not e3.scfg

    def test_eos_masks_tail_and_reports_gen_lens(self, engine):
        prompts = np.random.default_rng(5).integers(
            0, engine.cfg.vocab_size, (2, 6)).astype(np.int32)
        engine.scfg.max_new_tokens = 6
        engine.scfg.eos_id = None
        free = engine.generate(prompts)
        assert np.all(free["gen_lens"] == free["tokens"].shape[1])
        # re-run with eos_id set to a token the free run actually emits
        eos = int(free["tokens"][0, 2])
        engine.scfg.eos_id = eos
        res = engine.generate(prompts)
        engine.scfg.eos_id = None
        toks, lens = res["tokens"], res["gen_lens"]
        for b in range(toks.shape[0]):
            n = int(lens[b])
            hit = np.nonzero(toks[b] == eos)[0]
            if hit.size and hit[0] < toks.shape[1] - 1:
                # everything after the first EOS is masked to EOS
                assert np.all(toks[b, hit[0]:] == eos)
                assert n == hit[0] + 1
            else:
                assert n == toks.shape[1]
        # greedy prefix up to EOS matches the unconstrained run
        n0 = int(lens[0])
        np.testing.assert_array_equal(toks[0, :n0], free["tokens"][0, :n0])


# ---------------------------------------------------------------------------
# Stats lifecycle (obs-backed derived view)
# ---------------------------------------------------------------------------


STAT_KEYS = {
    "decode_steps", "decode_s", "total_s", "generated_tokens", "requests",
    "completed_requests", "decode_tok_s", "ttft_p50_s", "ttft_p99_s",
    "tpot_p50_s", "tpot_p99_s", "latency_p50_s", "latency_p99_s",
    "preemptions", "iter_faults",
} | {f"retired_{s}" for s in STATUSES}


class TestStatsLifecycle:
    def test_full_key_set_before_first_run(self, engine):
        """A fresh Scheduler reports the complete all-zeros key set — not the
        pre-obs empty dict that KeyError'd consumers before run()."""
        sched = Scheduler(engine, n_slots=2, prefill_chunk=4)
        stats = sched.stats
        assert set(stats) == STAT_KEYS
        assert all(v == 0 for v in stats.values())

    def test_consistent_during_partial_run_iter(self, engine):
        """stats read mid-generator reflects the work done so far with the
        same key set, and keeps counting to the final totals."""
        engine.scfg.max_new_tokens = 8
        trace = synthetic_trace(5, seed=7, vocab=engine.cfg.vocab_size,
                                prompt_lens=(3, 10), new_tokens=(2, 8))
        sched = Scheduler(engine, n_slots=2, prefill_chunk=4)
        gen = sched.run_iter(trace)
        first = next(gen)
        mid = sched.stats
        assert set(mid) == STAT_KEYS
        assert mid["requests"] == 5
        assert mid["completed_requests"] >= 1
        assert mid["generated_tokens"] >= first.n_generated
        assert mid["decode_s"] > 0 and mid["decode_tok_s"] > 0
        rest = list(gen)
        end = sched.stats
        assert end["completed_requests"] == 5
        assert end["generated_tokens"] == first.n_generated + sum(
            c.n_generated for c in rest)
        assert end["generated_tokens"] >= mid["generated_tokens"]
        assert end["latency_p50_s"] > 0 and end["tpot_p50_s"] >= 0

    def test_rerun_resets_counters(self, engine):
        engine.scfg.max_new_tokens = 4
        trace = synthetic_trace(3, seed=2, vocab=engine.cfg.vocab_size,
                                prompt_lens=(3, 8), new_tokens=(2, 4))
        sched = Scheduler(engine, n_slots=2, prefill_chunk=4)
        sched.run(trace)
        a = sched.stats
        sched.run(trace)
        b = sched.stats
        assert a["completed_requests"] == b["completed_requests"] == 3
        assert b["generated_tokens"] == a["generated_tokens"]  # not 2x
