"""Training/serving substrate tests: data determinism, checkpoint round-trip
+ atomicity + elastic restore, trainer resume, fault machinery, gradient
compression, optimizer behaviour."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.models import registry as reg
from repro.optim import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.grad_compress import (
    compress_with_feedback,
    dequantize_int8,
    quantize_int8,
)
from repro.serve import Engine, ServeConfig
from repro.train import (
    CheckpointManager,
    StepWatchdog,
    StragglerMonitor,
    TrainConfig,
    Trainer,
)


class TestData:
    def test_deterministic_and_resumable(self):
        d1 = SyntheticLM(DataConfig(seed=7))
        d2 = SyntheticLM(DataConfig(seed=7))
        for step in [0, 5, 100, 12345]:
            np.testing.assert_array_equal(
                d1.batch_at(step)["tokens"], d2.batch_at(step)["tokens"]
            )

    def test_seed_changes_stream(self):
        a = SyntheticLM(DataConfig(seed=1)).batch_at(0)["tokens"]
        b = SyntheticLM(DataConfig(seed=2)).batch_at(0)["tokens"]
        assert not np.array_equal(a, b)

    def test_learnable_structure(self):
        # bigram stream should be far from uniform: most transition mass
        # lands on the 8 boosted successors per token
        d = SyntheticLM(DataConfig(vocab_size=64, batch=64, seq_len=256, seed=3))
        toks = d.batch_at(0)["tokens"]
        pairs = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                pairs.setdefault(int(a), []).append(int(b))
        masses = []
        for v in pairs.values():
            if len(v) < 50:
                continue
            _, counts = np.unique(v, return_counts=True)
            top8 = np.sort(counts)[-8:].sum()
            masses.append(top8 / len(v))
        assert masses and np.median(masses) > 0.6, "bigram structure missing"


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        mgr.save(10, {"params": tree}, metadata={"x": 1})
        out, meta = mgr.restore(None, {"params": tree})
        np.testing.assert_array_equal(out["params"]["a"], np.asarray(tree["a"]))
        np.testing.assert_array_equal(out["params"]["b"]["c"], np.asarray(tree["b"]["c"]))
        assert meta["step"] == 10 and meta["x"] == 1

    def test_keeps_latest_and_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.zeros((2,))}
        for s in [1, 2, 3, 4]:
            mgr.save(s, {"params": tree})
        assert mgr.latest_step() == 4
        assert len(list(mgr.dir.glob("step_*"))) == 2

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"a": jnp.zeros((128, 128))}
        mgr.save(1, {"params": tree}, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"params": {"a": jnp.zeros((2, 2))}})
        with pytest.raises(ValueError):
            mgr.restore(None, {"params": {"a": jnp.zeros((3, 3))}})


class TestTrainer:
    def _mk(self, tmp_path=None, steps=6):
        cfg = smoke_config("smollm-360m").with_(n_layers=2, d_model=64, d_ff=96,
                                                n_heads=2, n_kv_heads=1, head_dim=32,
                                                vocab_size=128)
        dcfg = DataConfig(vocab_size=128, batch=16, seq_len=32, seed=1)
        tcfg = TrainConfig(steps=steps, ckpt_dir=str(tmp_path) if tmp_path else None,
                           ckpt_every=3, log_every=1)
        return Trainer(cfg, dcfg, AdamWConfig(lr=3e-3, weight_decay=0.01), tcfg)

    def test_loss_decreases(self, tmp_path):
        tr = self._mk(steps=40)
        out = tr.run()
        losses = [h["loss"] for h in out["history"]]
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05

    def test_checkpoint_resume_bit_identical(self, tmp_path):
        # run to a total budget of 6 steps straight
        tr_a = self._mk(tmp_path / "a", steps=6)
        out_a = tr_a.run()
        # interrupt at 3, restart with the SAME total budget: the restarted
        # run resumes at step 3 and completes the original 6-step schedule
        tr_b = self._mk(tmp_path / "b", steps=3)
        tr_b.run()
        tr_c = self._mk(tmp_path / "b", steps=6)
        out_c = tr_c.run()
        assert out_c["start_step"] == 3
        assert out_a["final_step"] == out_c["final_step"] == 6
        la = jax.tree_util.tree_leaves(tr_a.params)
        lc = jax.tree_util.tree_leaves(tr_c.params)
        for a, c in zip(la, lc):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)


class TestFault:
    def test_watchdog_fires(self):
        fired = []
        wd = StepWatchdog(timeout_s=0.2, abort=lambda: fired.append(1)).start()
        time.sleep(0.6)
        wd.stop()
        assert fired

    def test_watchdog_beats_keep_alive(self):
        fired = []
        wd = StepWatchdog(timeout_s=0.4, abort=lambda: fired.append(1)).start()
        for _ in range(6):
            time.sleep(0.1)
            wd.beat()
        wd.stop()
        assert not fired

    def test_straggler_detection(self):
        mon = StragglerMonitor(window=20, factor=2.0)
        for i in range(10):
            mon.record(i, 1.0)
        assert mon.record(10, 5.0) is True
        assert not mon.record(11, 1.1)
        assert mon.events[0]["step"] == 10


class TestGradCompress:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_quantize_bounded_error(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed % 9973), (256,)) * 3.0
        q, s = quantize_int8(x)
        err = dequantize_int8(q, s) - x
        assert float(jnp.max(jnp.abs(err))) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_reduces_bias(self):
        # accumulate many steps of the same gradient: with error feedback the
        # mean dequantized gradient converges to the true gradient
        g = jax.random.normal(jax.random.PRNGKey(0), (512,))
        err = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        n = 50
        for _ in range(n):
            q, s, err = compress_with_feedback(g, err)
            total = total + dequantize_int8(q, s)
        np.testing.assert_allclose(np.asarray(total / n), np.asarray(g), atol=1e-3)


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        for _ in range(300):
            g = {"w": 2 * (params["w"] - target)}
            params, state, _ = adamw_update(params, g, state, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)

    def test_int_leaves_untouched(self):
        params = {"w": jnp.zeros(4), "idx": jnp.arange(4, dtype=jnp.int32)}
        state = adamw_init(params)
        g = {"w": jnp.ones(4), "idx": np.zeros((4,), dtype=jax.dtypes.float0)}
        p2, _, _ = adamw_update(params, g, state, AdamWConfig())
        np.testing.assert_array_equal(np.asarray(p2["idx"]), np.arange(4))
        assert p2["idx"].dtype == jnp.int32

    def test_grad_clip(self):
        params = {"w": jnp.zeros(4)}
        state = adamw_init(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, gnorm = adamw_update(params, g, state, AdamWConfig(grad_clip=1.0))
        assert float(gnorm) > 1e5  # reported norm is pre-clip


class TestServeEngine:
    def test_generate_greedy_deterministic(self):
        cfg = smoke_config("qwen2-0.5b")
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=6))
        prompts = np.ones((2, 5), np.int32)
        a = eng.generate(prompts)
        b = eng.generate(prompts)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (2, 6)
        assert (a["tokens"] < cfg.vocab_size).all(), "padded-vocab ids leaked"

    def test_generate_recurrent_arch(self):
        cfg = smoke_config("xlstm-350m")
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=4))
        out = eng.generate(np.ones((1, 4), np.int32))
        assert out["tokens"].shape == (1, 4)


class TestTuner:
    def test_tuner_profiles_and_caches(self, tmp_path):
        from repro.core.tuning import Tuner, enumerate_candidates

        cands = enumerate_candidates(512, 512)
        assert any(c.feasible for c in cands)
        t = Tuner(cache_path=str(tmp_path / "cache.json"))
        r1 = t.tune(batch=64, d_in=256, d_out=256, sparsity=0.5)
        assert r1["tile"] in (32, 64, 128, 256) and r1["wall_us"] > 0
        # cached second call: no re-profiling (identical result, fast)
        t2 = Tuner(cache_path=str(tmp_path / "cache.json"))
        r2 = t2.tune(batch=64, d_in=256, d_out=256, sparsity=0.5)
        assert r1 == r2

    def test_vmem_infeasible_rejected(self):
        from repro.core.tuning import enumerate_candidates, VMEM_BYTES

        cands = enumerate_candidates(65536, 2048)  # giant d_in blows VMEM
        assert any(not c.feasible for c in cands)
