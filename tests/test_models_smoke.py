"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models import registry as reg

ARCHS = list_archs()


def make_batch(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 4)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        p = cfg.vision_patches
        pos3 = jnp.broadcast_to(jnp.arange(s)[None, None, :], (b, 3, s))
        batch["mrope_positions"] = pos3
        batch["vision_embeds"] = jax.random.normal(ks[1], (b, p, cfg.d_model))
        batch["vision_pos"] = jnp.broadcast_to(jnp.arange(p)[None, :], (b, p))
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(ks[2], (b, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_full_config_exact(self, arch):
        """The full config carries the exact published hyperparameters."""
        cfg = get_config(arch)
        assert cfg.name == arch
        assert cfg.param_count() > 0

    def test_forward_shapes_and_finite(self, arch):
        cfg = smoke_config(arch)
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        logits = reg.forward_fn(cfg)(params, batch)
        b, s = batch["tokens"].shape
        assert logits.shape == (b, s, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_decreases_loss(self, arch):
        cfg = smoke_config(arch)
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        lfn = reg.loss_fn(cfg)
        lr = 0.1 if cfg.block_pattern != "attn" else 0.5

        @jax.jit
        def step(p):
            (l, m), g = jax.value_and_grad(lambda pp: lfn(pp, batch), has_aux=True)(p)
            p2 = jax.tree_util.tree_map(
                lambda x, gg: x - lr * gg
                if jnp.issubdtype(x.dtype, jnp.floating)
                else x,
                p,
                g,
            )
            return p2, l

        p, l0 = step(params)
        for _ in range(3):
            p, l1 = step(p)
        assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(l1))
        assert float(l1) < float(l0), f"loss did not decrease: {l0} -> {l1}"

    def test_decode_step(self, arch):
        cfg = smoke_config(arch)
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        b, max_len = 2, 32
        cache = reg.cache_init_fn(cfg, b, max_len)()
        tok = jnp.ones((b, 1), jnp.int32)
        pos = jnp.asarray(3, jnp.int32)
        logits, cache2 = reg.decode_fn(cfg)(params, cache, tok, pos)
        assert logits.shape == (b, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())
        # cache structure is preserved
        assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", [a for a in ARCHS if get_config(a).block_pattern == "attn"
                                  and not get_config(a).is_encoder_decoder])
def test_prefill_decode_consistency(arch):
    """prefill(tokens) then decode(next) == forward(tokens+next) last logits."""
    cfg = smoke_config(arch)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(5)
    b, s = 2, 8
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s + 1)[None, None, :], (b, 3, s + 1)
        )
    logits_all = reg.forward_fn(cfg)(params, batch)

    pre_batch = {"tokens": toks[:, :s]}
    if cfg.family == "vlm":
        pre_batch["mrope_positions"] = batch["mrope_positions"][..., :s]
    logits_last, cache = reg.prefill_fn(cfg)(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_last[:, 0]), np.asarray(logits_all[:, s - 1]),
        rtol=2e-4, atol=2e-4,
    )
    # cache from prefill has length s; decode the next token at pos=s needs
    # room — rebuild a longer cache and splice
    full_cache = reg.cache_init_fn(cfg, b, s + 4)()
    full_cache["k"] = full_cache["k"].at[:, :, :s].set(cache["k"])
    full_cache["v"] = full_cache["v"].at[:, :, :s].set(cache["v"])
    logits_dec, _ = reg.decode_fn(cfg)(params, full_cache, toks[:, s:s + 1],
                                       jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_all[:, s]),
        rtol=2e-4, atol=2e-4,
    )


def test_whisper_prefill_decode():
    cfg = smoke_config("whisper-small")
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    enc = jax.random.normal(jax.random.PRNGKey(1), (b, cfg.encoder_seq, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, 8), 0, cfg.vocab_size)
    logits, cache = reg.prefill_fn(cfg)(params, {"enc_embeds": enc, "tokens": toks})
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert cache["xk"].shape[2] == cfg.encoder_seq
