"""Crash-safe finetune resume: SparseTrainer's bitwise resume-determinism
contract, total-budget step accounting, watchdog surfacing, and the
data-seed pinning that guards the contract."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import fault
from repro.train import SparseTrainConfig, SparseTrainer

REPO = Path(__file__).resolve().parent.parent


def _cfg(ckpt_dir=None, steps=5, **kw):
    return SparseTrainConfig(
        steps=steps, batch=2, lr=0.05,
        ckpt_dir=str(ckpt_dir) if ckpt_dir else None,
        ckpt_every=1 if ckpt_dir else 0, **kw)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


class TestSparseTrainer:
    def test_loss_decreases(self):
        out = SparseTrainer(_cfg(steps=12)).run()
        losses = [h["loss"] for h in out["history"]]
        assert np.isfinite(losses).all()
        assert np.mean(losses[-3:]) < np.mean(losses[:3])

    def test_clean_run_result_shape(self, tmp_path):
        out = SparseTrainer(_cfg(tmp_path, steps=3)).run()
        assert out["final_step"] == 3
        assert out["start_step"] == 0
        assert out["preempted"] is False
        assert out["watchdog_fired"] is False

    def test_kill_and_resume_bitwise_identical(self, tmp_path):
        """The contract: kill at step 3, restart with the same config, and
        the final params AND momentum are bitwise identical to the
        uninterrupted run."""
        ta = SparseTrainer(_cfg(tmp_path / "a"))
        ta.run()

        tb = SparseTrainer(_cfg(tmp_path / "b"))
        with fault.fault_scope("train.step:iter=3"):
            with pytest.raises(fault.InjectedFault):
                tb.run()
        tb.ckpt.wait()  # drain the in-flight async save before "restarting"
        assert tb.ckpt.latest_step() == 3

        tc = SparseTrainer(_cfg(tmp_path / "b"))
        out = tc.run()
        assert out["start_step"] == 3 and out["final_step"] == 5
        for a, c in zip(_leaves(ta.params), _leaves(tc.params)):
            assert a.dtype == c.dtype and a.tobytes() == c.tobytes()
        for a, c in zip(_leaves(ta.mom), _leaves(tc.mom)):
            assert a.tobytes() == c.tobytes()

    def test_total_budget_not_additive(self, tmp_path):
        """run(steps) trains TO step `steps`, restored progress included — a
        restart at the budget trains zero additional steps (the off-by-restore
        accounting bug this pins)."""
        SparseTrainer(_cfg(tmp_path)).run()
        t2 = SparseTrainer(_cfg(tmp_path))
        out = t2.run()
        assert out["start_step"] == 5
        assert out["final_step"] == 5
        assert out["history"] == []

    def test_data_seed_mismatch_refused(self, tmp_path):
        SparseTrainer(_cfg(tmp_path, steps=2)).run()
        t2 = SparseTrainer(_cfg(tmp_path, steps=4, data_seed=7))
        with pytest.raises(ValueError, match="data seed"):
            t2.run()

    def test_resume_skips_torn_newest_checkpoint(self, tmp_path):
        """A torn newest checkpoint (writer killed mid-copy) must not poison
        the restart: resume falls back to the newest valid step and still
        reaches the budget."""
        t1 = SparseTrainer(_cfg(tmp_path, steps=3))
        t1.run()
        newest = t1.ckpt.dir / "step_00000003"
        f = newest / "arrays.npz"
        f.write_bytes(f.read_bytes()[:100])
        t2 = SparseTrainer(_cfg(tmp_path, steps=5))
        out = t2.run()
        assert out["start_step"] == 2  # fell back past the torn step-3 dir
        assert out["final_step"] == 5


class TestWatchdog:
    def test_fired_flag_surfaced(self, tmp_path):
        out = SparseTrainer(_cfg(tmp_path, steps=2)).run()
        assert out["watchdog_fired"] is False

    def test_abort_dumps_trace_and_exits_42(self, tmp_path):
        """The default abort emits a fault.watchdog instant and dumps the
        armed trace sink before os._exit(42) — the one artifact that says
        where a hung run hung must survive the abort."""
        trace = tmp_path / "wd_trace.json"
        snippet = (
            "import time\n"
            "from repro.train.fault import StepWatchdog\n"
            "StepWatchdog(timeout_s=0.2).start()\n"
            "time.sleep(30)\n")
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(str(REPO), "src"),
                   REPRO_OBS="on", REPRO_OBS_TRACE=str(trace))
        r = subprocess.run([sys.executable, "-c", snippet], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 42
        events = json.loads(trace.read_text())["traceEvents"]
        assert any(e.get("name") == "fault.watchdog" for e in events)
