"""Multi-device execution tests (8 emulated host devices via subprocess —
the main test process must keep seeing 1 device per the assignment).

Covers: ring collective matmul numerics, a real sharded sparse train step
(pjit EXECUTION, not just compile), and cross-'pod' gradient compression
inside shard_map.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestDistributed:
    def test_ring_collective_matmul(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.sharding.collective_matmul import ring_allgather_matmul
            mesh = jax.make_mesh((8,), ("model",))
            x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
            w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
            with mesh:
                y = ring_allgather_matmul(x, w, mesh, axis="model")
            np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                       rtol=2e-5, atol=2e-5)
            print("RING_OK")
        """)
        assert "RING_OK" in out

    def test_sharded_sparse_train_step_executes(self):
        """One REAL train step of a compressed sparse model on a 2x4 mesh —
        validates the whole sharded path executes, not just compiles."""
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import smoke_config
            from repro.core.pruning import SparsityConfig
            from repro.launch import steps as steps_mod
            from repro.launch.mesh import mesh_tp
            from repro.models import registry as reg
            from repro.optim import AdamWConfig, adamw_init
            from repro.sharding import ShardingCtx, use_ctx

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            scfg = SparsityConfig(0.5, m=None, tile=None, format="compressed_xla",
                                  min_dim=32, shard_local_reduce=True, reduce_groups=4)
            cfg = smoke_config("qwen2-7b").with_(
                n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=256, sparsity=scfg, tp=4, dp=2,
                attn_impl="chunked", attn_chunk=8)
            with use_ctx(ShardingCtx(mesh=mesh)), mesh:
                params, specs = reg.init_params(cfg, jax.random.PRNGKey(0))
                opt = adamw_init(params)
                step = steps_mod.make_train_step(cfg, AdamWConfig(lr=1e-3))
                in_sh, out_sh = steps_mod.train_shardings(
                    cfg, mesh, params, specs, {"tokens": jnp.ones((8, 32), jnp.int32)})
                f = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                            donate_argnums=(0, 1))
                batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                                       (8, 32), 0, 256)}
                p2, o2, m = f(params, opt, batch)
                loss = float(m["loss"])
                assert np.isfinite(loss), loss
                p3, o3, m2 = f(p2, o2, batch)
                assert float(m2["loss"]) < loss  # same batch twice -> improves
            print("SHARDED_STEP_OK", loss)
        """)
        assert "SHARDED_STEP_OK" in out

    def test_crosspod_compressed_psum(self):
        out = run_with_devices("""
            import functools
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from jax.experimental.shard_map import shard_map
            from repro.optim.grad_compress import crosspod_psum_compressed
            mesh = jax.make_mesh((4, 2), ("pod", "data"))
            g = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
            e = jnp.zeros((4, 256))

            f = shard_map(
                functools.partial(crosspod_psum_compressed, axis="pod"),
                mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
                out_specs=(P("pod", None), P("pod", None)), check_rep=False)
            with mesh:
                reduced, err = f(g, e)
            # every pod-shard of `reduced` equals the true sum up to int8 error
            true = np.asarray(g).reshape(4, 1, 256).sum(axis=0)
            got = np.asarray(reduced).reshape(4, 1, 256)
            scale = np.abs(np.asarray(g)).max() / 127 * 4
            for i in range(4):
                np.testing.assert_allclose(got[i], true, atol=4 * scale)
            print("COMPRESS_OK")
        """)
        assert "COMPRESS_OK" in out


def test_shard_map_moe_matches_auto():
    """Manual shard_map MoE == GSPMD-auto MoE when capacity is ample
    (identical routing, no drops)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.models.moe import moe_apply, moe_apply_shard_map, moe_init
        from repro.core.sparse_linear import unbox_tree
        from repro.sharding import ShardingCtx, use_ctx

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config("olmoe-1b-7b").with_(
            d_model=64, d_ff=96, n_experts=8, top_k=2, capacity_factor=8.0,
            tp=4, dp=2, moe_impl="shard_map")
        params, _ = unbox_tree(moe_init(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
        with use_ctx(ShardingCtx(mesh=mesh)), mesh:
            y_manual, aux_m = jax.jit(
                lambda p, xx: moe_apply_shard_map(p, cfg, xx))(params, x)
            y_auto, aux_a = jax.jit(
                lambda p, xx: moe_apply(p, cfg, xx))(params, x)
        np.testing.assert_allclose(np.asarray(y_manual), np.asarray(y_auto),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(float(aux_m), float(aux_a), rtol=1e-3)
        print("MOE_MANUAL_OK")
    """)
    assert "MOE_MANUAL_OK" in out
