"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SparsityConfig, colwise_nm_mask, meta_for, pack_colwise
from repro.kernels.colwise_nm import (
    colwise_nm_matmul,
    colwise_nm_matmul_pallas,
    colwise_nm_matmul_ref,
)
from repro.kernels.conv_gemm import (
    compress_conv_weights,
    conv2d_cnhw_ref,
    conv2d_colwise_sparse,
)
from repro.kernels.im2col_pack import (
    im2col_only,
    im2col_pack,
    im2col_pack_pallas,
    im2col_pack_ref,
    im2col_then_pack,
)

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def make_compressed(key, d_in, d_out, sparsity, m, tile, dtype):
    kw, kx = jax.random.split(key)
    w = jax.random.normal(kw, (d_in, d_out), dtype)
    cfg = SparsityConfig(sparsity=sparsity, m=m, tile=tile, format="compressed_pallas")
    meta = meta_for(d_in, d_out, cfg)
    mask = colwise_nm_mask(w, sparsity, m=cfg.m, tile=meta.tile)
    values, idx = pack_colwise(w, mask, meta)
    return values, idx, meta


class TestColwiseNMKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,d_in,d_out,sparsity,m,tile,bb,bk",
        [
            (16, 64, 32, 0.5, 16, 8, 8, 8),
            (8, 128, 128, 0.5, None, 32, 8, 16),
            (33, 96, 48, 0.75, 24, 16, 16, 8),   # ragged batch
            (4, 256, 64, 0.25, None, 64, 128, 128),  # blocks > dims
            (64, 64, 64, 0.5, 32, 64, 32, 24),   # k not multiple of bk
            (5, 48, 96, 0.5, 12, None, 8, 8),    # tile == d_out
        ],
    )
    def test_matches_ref(self, dtype, b, d_in, d_out, sparsity, m, tile, bb, bk):
        key = jax.random.PRNGKey(b + d_in + d_out)
        values, idx, meta = make_compressed(key, d_in, d_out, sparsity, m, tile, dtype)
        x = jax.random.normal(jax.random.PRNGKey(7), (b, d_in), dtype)
        y_ref = colwise_nm_matmul_ref(x, values, idx)
        y = colwise_nm_matmul_pallas(x, values, idx, block_b=bb, block_k=bk, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **TOL[dtype]
        )

    def test_ops_wrapper_leading_dims(self):
        values, idx, _ = make_compressed(jax.random.PRNGKey(0), 64, 32, 0.5, 16, 8, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64))
        y = colwise_nm_matmul(x, values, idx)
        y_ref = colwise_nm_matmul_ref(x.reshape(-1, 64), values, idx).reshape(2, 3, 32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)

    def test_custom_vjp_matches_ref_grads(self):
        values, idx, _ = make_compressed(jax.random.PRNGKey(2), 64, 32, 0.5, None, 8, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))

        def loss_k(x, v):
            return jnp.sum(jnp.tanh(colwise_nm_matmul(x, v, idx)))

        def loss_r(x, v):
            return jnp.sum(jnp.tanh(colwise_nm_matmul_ref(x, v, idx)))

        gx_k, gv_k = jax.grad(loss_k, argnums=(0, 1))(x, values)
        gx_r, gv_r = jax.grad(loss_r, argnums=(0, 1))(x, values)
        np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gv_k), np.asarray(gv_r), rtol=1e-4, atol=1e-5)

    def test_density_flops_scale(self):
        # compressed contraction length is (1-s) * d_in: the FLOP saving the
        # MXU actually realizes
        for s in [0.25, 0.5, 0.75]:
            values, idx, meta = make_compressed(
                jax.random.PRNGKey(4), 128, 64, s, None, 16, jnp.float32
            )
            assert meta.k_kept == int(round((1 - s) * 128))


class TestIm2colPackKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "c,b,h,w,kh,kw,stride,pad,v",
        [
            (3, 2, 8, 8, 3, 3, 1, 1, 16),
            (4, 1, 16, 16, 1, 1, 1, 0, 32),
            (2, 2, 14, 14, 3, 3, 2, 1, 16),   # strided
            (5, 1, 7, 9, 7, 7, 2, 3, 8),      # stem-like 7x7 s2
            (2, 3, 6, 5, 3, 3, 1, 1, 7),      # ragged V vs width
        ],
    )
    def test_fused_matches_twopass(self, dtype, c, b, h, w, kh, kw, stride, pad, v):
        x = jax.random.normal(jax.random.PRNGKey(c * h + w), (c, b, h, w), dtype)
        ref = im2col_pack_ref(x, kh, kw, stride, pad, v)
        fused = im2col_pack_pallas(x, kh, kw, stride=stride, pad=pad, v=v, interpret=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))

    def test_unfused_baseline_matches(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 8, 8))
        a = im2col_then_pack(x, kh=3, kw=3, stride=1, pad=1, v=16)
        b = im2col_pack(x, kh=3, kw=3, stride=1, pad=1, v=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_im2col_matches_conv(self):
        # patch-matrix GEMM with dense weights == lax conv
        c, b, h, w, o, k = 3, 2, 8, 8, 4, 3
        x = jax.random.normal(jax.random.PRNGKey(1), (c, b, h, w))
        wt = jax.random.normal(jax.random.PRNGKey(2), (o, k, k, c))
        mat = im2col_only(x, kh=k, kw=k, stride=1, pad=1)  # [KhKwC, P]
        y = (wt.reshape(o, -1) @ mat).reshape(o, b, h, w)
        y_ref = conv2d_cnhw_ref(x, wt, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


class TestSparseConvEndToEnd:
    @pytest.mark.parametrize("sparsity", [0.25, 0.5, 0.75])
    def test_sparse_conv_matches_masked_dense_conv(self, sparsity):
        c, b, h, w, o, k = 8, 2, 10, 10, 16, 3
        x = jax.random.normal(jax.random.PRNGKey(3), (c, b, h, w))
        wt = jax.random.normal(jax.random.PRNGKey(4), (o, k, k, c))
        cfg = SparsityConfig(sparsity=sparsity, m=None, tile=8, format="compressed_pallas")
        values, idx, meta = compress_conv_weights(wt, cfg)
        y = conv2d_colwise_sparse(x, values, idx, kh=k, kw=k, stride=1, pad=1, v=16)
        # dense conv with the masked weights is the oracle
        wmat = wt.reshape(o, -1).T
        mask = colwise_nm_mask(wmat, sparsity, m=None, tile=meta.tile)
        wt_masked = (wmat * mask).T.reshape(o, k, k, c)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)
