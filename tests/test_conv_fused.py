"""Conv megakernel + conv dispatch tests: fused-vs-reference equivalence
across stride/pad/ragged/dtype, strip-major GEMM equivalence, geometry
candidates in the dispatch space (frozen-DB cross-process determinism,
extending the test_dispatch.py pattern), and the conv layer abstraction
(conv_init/conv_apply) routing through the registry with real params."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dispatch
from repro.core import (
    SparsityConfig,
    colwise_nm_mask,
    compress_conv_layer,
    conv_apply,
    conv_init,
    unbox_tree,
)
from repro.dispatch import REGISTRY, ProfileDB
from repro.kernels.colwise_nm import (
    colwise_nm_matmul_ref,
    colwise_nm_matmul_strips,
)
from repro.kernels.conv_gemm import (
    compress_conv_weights,
    conv2d_cnhw_ref,
    conv2d_colwise_sparse,
    conv2d_fused,
    conv2d_two_kernel,
    fused_vmem_bytes,
)
from repro.kernels.im2col_pack import im2col_pack_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.fixture
def db(tmp_path):
    d = ProfileDB(path=str(tmp_path / "profile.json"))
    dispatch.set_db(d)
    yield d
    dispatch.set_db(None)


def _sparse_conv_problem(c, b, h, w, o, k, sparsity=0.5, tile=8,
                         dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(c * h + w), (c, b, h, w), dtype)
    wt = jax.random.normal(jax.random.PRNGKey(o + k), (o, k, k, c), dtype)
    cfg = SparsityConfig(sparsity=sparsity, m=None, tile=tile,
                         format="compressed_pallas")
    values, idx, meta = compress_conv_weights(wt, cfg)
    # masked dense conv is the oracle
    wmat = wt.reshape(o, -1).T
    mask = colwise_nm_mask(wmat, sparsity, m=None, tile=meta.tile)
    wt_masked = (wmat * mask).T.reshape(o, k, k, c).astype(dtype)
    return x, values, idx, wt_masked


class TestFusedMegakernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "c,b,h,w,o,k,stride,pad,v",
        [
            (8, 2, 10, 10, 16, 3, 1, 1, 16),
            (8, 1, 10, 10, 16, 3, 2, 1, 16),    # strided
            (5, 2, 9, 7, 8, 3, 1, 0, 8),        # no pad, non-square
            (4, 1, 8, 8, 16, 1, 2, 0, 32),      # 1x1 strided
            (3, 1, 7, 7, 8, 3, 2, 1, 128),      # ragged final strip (P < V)
            (6, 2, 11, 11, 8, 3, 1, 1, 32),     # ragged: P % V != 0
        ],
    )
    def test_fused_matches_reference_conv(self, dtype, c, b, h, w, o, k,
                                          stride, pad, v):
        x, values, idx, wt_masked = _sparse_conv_problem(
            c, b, h, w, o, k, dtype=dtype)
        y = conv2d_fused(x, values, idx, kh=k, kw=k, stride=stride, pad=pad,
                         v=v)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=stride, pad=pad)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            **TOL[dtype])

    def test_fused_block_k_chunking(self):
        # k_kept not divisible by block_k: zero-padded chunks must not leak
        x, values, idx, wt_masked = _sparse_conv_problem(8, 1, 9, 9, 16, 3)
        assert values.shape[1] % 8 != 0 or values.shape[1] > 8
        y = conv2d_fused(x, values, idx, kh=3, kw=3, stride=1, pad=1, v=16,
                         block_k=8)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_strip_major_matches_row_major_gemm(self):
        x, values, idx, _ = _sparse_conv_problem(4, 2, 8, 8, 16, 3)
        strips = im2col_pack_ref(x, 3, 3, 1, 1, 16)  # [S, K, V]
        y = colwise_nm_matmul_strips(strips, values, idx)  # [O, S*V]
        xt = np.asarray(strips).transpose(0, 2, 1).reshape(-1, strips.shape[1])
        y_ref = colwise_nm_matmul_ref(jnp.asarray(xt), values, idx).T
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_two_kernel_matches_fused(self):
        x, values, idx, _ = _sparse_conv_problem(6, 2, 11, 11, 8, 3)
        a = dict(kh=3, kw=3, stride=1, pad=1, v=32)
        y1 = conv2d_fused(x, values, idx, **a)
        y2 = conv2d_two_kernel(x, values, idx, **a)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)


class TestConvDispatch:
    def test_fused_candidates_have_geometry_and_vmem(self):
        specs = [s for s in REGISTRY.candidates("conv")
                 if s.name.startswith("fused_sparse_pallas")]
        assert len(specs) >= 2
        for s in specs:
            assert s.geom("v") > 0 and s.geom("bk") > 0
            assert s.apply is not None and s.make_bench is not None

    def test_fused_infeasible_when_map_exceeds_vmem(self):
        # the megakernel keeps the whole CNHW map in VMEM; a big map must
        # fail its predicate while the two-kernel plan stays available
        key = dispatch.conv_key(512, 224, 224, 512, 3, 3, 1, 1, k_kept=2304,
                                tile=128, batch=8)
        spec = REGISTRY.get("conv", "fused_sparse_pallas")
        ok, reason = spec.feasible(key)
        assert not ok and "VMEM" in reason
        assert fused_vmem_bytes(512, 8, 224, 224, 128, 128, 128) > \
            dispatch.VMEM_BYTES

    def test_conv_key_phase_parity_with_linear_key(self):
        # the conv_key parity fix: phase-tagged conv tokens, untagged format
        # unchanged (existing DBs stay valid)
        plain = dispatch.conv_key(8, 10, 10, 16, 3, 3, 1, 1, 36, 8)
        tagged = dispatch.conv_key(8, 10, 10, 16, 3, 3, 1, 1, 36, 8,
                                   phase="prefill")
        assert tagged.token == plain.token + "|ph:prefill"
        with dispatch.phase_scope("decode"):
            assert dispatch.current_phase() == "decode"

    def test_frozen_db_picks_fused_geometry_variant(self, db):
        x, values, idx, wt_masked = _sparse_conv_problem(8, 2, 10, 10, 16, 3)
        key = dispatch.conv_key(8, 10, 10, 16, 3, 3, 1, 1,
                                values.shape[1], values.shape[2], v=16,
                                batch=2)
        name = [s.name for s in REGISTRY.candidates("conv")
                if s.name.startswith("fused_sparse_pallas@")][0]
        db.put(key.token, {"impl": name, "wall_us": 1.0})
        spec = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert spec.name == name and spec.geometry
        y = conv2d_colwise_sparse(x, values, idx, kh=3, kw=3, stride=1,
                                  pad=1, v=16)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_geometry_selection_cross_process_deterministic(self, db):
        """A frozen DB naming a geometry variant reproduces the identical
        impl+geometry selection in fresh processes (impl and geometry are
        one record — the joint-selection property)."""
        key = dispatch.conv_key(8, 10, 10, 16, 3, 3, 1, 1, 36, 8, batch=2)
        name = [s.name for s in REGISTRY.candidates("conv")
                if s.name.startswith("fused_sparse_pallas@")][0]
        db.put(key.token, {"impl": name, "wall_us": 1.0})
        snippet = (
            "from repro import dispatch\n"
            "key = dispatch.conv_key(8, 10, 10, 16, 3, 3, 1, 1, 36, 8, batch=2)\n"
            "s = dispatch.best_impl(key, param_keys=('values','idx'))\n"
            "print(s.name, dict(s.geometry)['v'], dict(s.geometry)['bk'])\n"
        )
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"),
                   REPRO_DISPATCH_DB=str(db.path))
        outs = []
        for _ in range(2):
            r = subprocess.run([sys.executable, "-c", snippet], env=env,
                               capture_output=True, text=True, timeout=300)
            assert r.returncode == 0, r.stderr
            outs.append(r.stdout.strip())
        spec = REGISTRY.get("conv", name)
        want = f"{name} {spec.geom('v')} {spec.geom('bk')}"
        assert outs == [want, want]


class TestConvLayerAbstraction:
    CFG = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                         format="compressed_pallas")

    def test_conv_init_compressed_params(self):
        params = conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3, self.CFG)
        vals, specs = unbox_tree(params)
        # conv_geom is the op discriminator dispatch.plan_params keys on
        assert set(vals) == {"values", "idx", "conv_geom"}
        assert [int(v) for v in vals["conv_geom"]] == [3, 3, 8]
        n_tiles, k_kept, tile = vals["values"].shape
        assert n_tiles * tile == 16 and vals["idx"].shape == (n_tiles, k_kept)

    def test_conv_apply_round_trip_through_registry(self, db):
        # conv_apply must execute the profile-DB winner with real params
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                                         self.CFG))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 10, 10))
        key = dispatch.conv_key(8, 10, 10, 16, 3, 3, 1, 1,
                                params["values"].shape[1], 8, batch=2)
        db.put(key.token, {"impl": "fused_sparse_pallas", "wall_us": 1.0})
        y = conv_apply(params, x, kh=3, kw=3, stride=1, pad=1)
        # oracle: decompress and run the lax conv
        from repro.core import ColwiseMeta, unpack_colwise

        meta = ColwiseMeta(d_in=72, d_out=16, tile=8, m=72,
                           n=params["values"].shape[1])
        wmat = unpack_colwise(params["values"], params["idx"], meta)
        wt = wmat.T.reshape(16, 3, 3, 8)
        y_ref = conv2d_cnhw_ref(x, wt, stride=1, pad=1)
        assert y.shape == y_ref.shape == (16, 2, 10, 10)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_conv_apply_forced_impl_and_equivalence(self, db):
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(2), 8, 16, 3, 3,
                                         self.CFG))
        x = jax.random.normal(jax.random.PRNGKey(3), (8, 1, 9, 9))
        ys = [np.asarray(conv_apply(params, x, kh=3, kw=3, pad=1, impl=name))
              for name in ("fused_sparse_pallas", "im2col_sparse_pallas",
                           "im2col_sparse_xla")]
        np.testing.assert_allclose(ys[0], ys[1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(ys[0], ys[2], rtol=1e-4, atol=1e-4)

    def test_conv_init_masked_format(self):
        # masked parity with linear_init: weights actually pruned, mask kept
        cfg = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                             format="masked")
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(10), 8, 16, 3, 3,
                                         cfg))
        assert set(params) == {"w", "mask"}
        zero_frac = float((params["w"] == 0).mean())
        assert abs(zero_frac - 0.5) < 0.05
        x = jax.random.normal(jax.random.PRNGKey(11), (8, 1, 8, 8))
        y = conv_apply(params, x, kh=3, kw=3, pad=1)
        y_ref = conv2d_cnhw_ref(
            x, params["w"] * params["mask"].astype(params["w"].dtype),
            stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_vmem_predicate_is_dtype_aware(self):
        # the same map geometry can be feasible in bf16 but not f32
        spec = REGISTRY.get("conv", "fused_sparse_pallas")
        kw = dict(kh=3, kw=3, stride=1, pad=1, k_kept=2304, tile=128)
        f32 = dispatch.conv_key(512, 96, 96, 512, kw["kh"], kw["kw"],
                                kw["stride"], kw["pad"], kw["k_kept"],
                                kw["tile"], dtype="float32")
        bf16 = dispatch.conv_key(512, 96, 96, 512, kw["kh"], kw["kw"],
                                 kw["stride"], kw["pad"], kw["k_kept"],
                                 kw["tile"], dtype="bfloat16")
        assert spec.vmem_bytes(f32) > spec.vmem_bytes(bf16)
        assert not spec.feasible(f32)[0] and spec.feasible(bf16)[0]

    def test_conv_dense_and_bias(self):
        cfg = SparsityConfig()  # disabled -> dense
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(4), 4, 8, 3, 3,
                                         cfg, use_bias=True))
        assert set(params) == {"w", "b"}
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 2, 8, 8))
        y = conv_apply(params, x, kh=3, kw=3, pad=1)
        y_ref = conv2d_cnhw_ref(x, params["w"], stride=1, pad=1) + \
            params["b"][:, None, None, None]
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_compress_conv_layer_matches_masked_dense(self, db):
        cfg = SparsityConfig()
        dense, _ = unbox_tree(conv_init(jax.random.PRNGKey(6), 8, 16, 3, 3,
                                        cfg))
        # compress_conv_layer returns Boxed leaves (same contract as
        # conv_init); apply consumes the unboxed values
        comp, _ = unbox_tree(compress_conv_layer(dense, 3, 3, self.CFG))
        x = jax.random.normal(jax.random.PRNGKey(7), (8, 1, 8, 8))
        y = conv_apply(comp, x, kh=3, kw=3, pad=1)
        wmat = dense["w"].reshape(16, -1).T
        mask = colwise_nm_mask(wmat, 0.5, m=None, tile=8)
        wt_masked = (wmat * mask).T.reshape(16, 3, 3, 8)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_conv_apply_inside_phase_scope(self, db):
        # a conv traced in a phase scope resolves a phase-tagged token; pin
        # different winners per phase and check both execute
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(8), 8, 16, 3, 3,
                                         self.CFG))
        x = jax.random.normal(jax.random.PRNGKey(9), (8, 1, 9, 9))
        base = dispatch.conv_key(8, 9, 9, 16, 3, 3, 1, 1,
                                 params["values"].shape[1], 8, batch=1)
        db.put(base.token + "|ph:prefill",
               {"impl": "fused_sparse_pallas", "wall_us": 1.0})
        with dispatch.phase_scope("prefill"):
            y = conv_apply(params, x, kh=3, kw=3, pad=1)
        y_ref = conv_apply(params, x, kh=3, kw=3, pad=1,
                           impl="im2col_sparse_xla")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
