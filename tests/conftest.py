"""Suite-wide fixtures/shims.

If `hypothesis` is not installed, alias the deterministic stub in
`tests/_hypothesis_stub.py` into ``sys.modules`` *before* test modules are
collected, so `from hypothesis import given, settings, strategies as st`
keeps working and the property tests run with a small fixed example set.
"""
import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401 — real package wins when available
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub", pathlib.Path(__file__).parent / "_hypothesis_stub.py"
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies
