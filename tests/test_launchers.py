"""CLI launcher smoke tests (subprocess — real argv paths)."""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def run_cli(args, timeout=600):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_train_cli_smoke(tmp_path):
    out = run_cli([
        "repro.launch.train", "--arch", "qwen2-0.5b", "--smoke",
        "--steps", "8", "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
    ])
    assert "loss" in out
    assert any(p.name.startswith("step_") for p in tmp_path.iterdir())


def test_serve_cli_smoke():
    out = run_cli([
        "repro.launch.serve", "--arch", "smollm-360m", "--smoke",
        "--batch", "2", "--new-tokens", "6", "--sparsity", "0.5",
    ])
    assert "decode" in out and "tok/s" in out


def test_dryrun_cli_single_cell(tmp_path):
    out = run_cli([
        "repro.launch.dryrun", "--arch", "smollm-360m", "--shape", "decode_32k",
        "--mesh", "single", "--out", str(tmp_path),
    ], timeout=900)
    assert "[ok]" in out
    assert list(tmp_path.glob("*.json"))
