"""Ragged paged flash-attention kernel (kernels/flash_attn/paged.py) vs the
XLA gather reference, the dispatch geometry tier (PAGED_ATTN_GEOMETRY page
sizes, pinned execution keys), and frozen-DB cross-process determinism."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dispatch
from repro.dispatch import (
    DEFAULT_PAGE_SIZE,
    PAGED_ATTN_GEOMETRY,
    REGISTRY,
    ProfileDB,
    choose_page_size,
    paged_attn_key,
)
from repro.kernels.flash_attn import (
    paged_attention,
    paged_attention_pallas,
    paged_attention_ref,
    paged_kernel_available,
)

REPO = Path(__file__).resolve().parent.parent

pytestmark = pytest.mark.skipif(
    not paged_kernel_available(),
    reason="pallas build lacks async-copy or scalar-prefetch support")


def _problem(b=3, sq=1, h=4, kv=2, d=16, n_pages=4, page_size=8,
             lengths=None, seed=0, dtype=jnp.float32, shuffle=False):
    """Random q/new-KV/pages + per-sequence tables.  ``lengths[i]`` rows of
    sequence i's cache are valid; table entries past its mapping point at
    the trash page (last physical page), which holds garbage — exactly the
    serving layout PagePool.table_array produces."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    p_total = b * n_pages + 1  # + trash page
    q = jax.random.normal(keys[0], (b, sq, h, d), dtype)
    k_new = jax.random.normal(keys[1], (b, sq, kv, d), dtype)
    v_new = jax.random.normal(keys[2], (b, sq, kv, d), dtype)
    k_pages = jax.random.normal(keys[3], (p_total, page_size, kv, d), dtype)
    v_pages = jax.random.normal(keys[4], (p_total, page_size, kv, d), dtype)
    pages = np.arange(b * n_pages)
    if shuffle:
        np.random.default_rng(seed).shuffle(pages)
    tables = pages.reshape(b, n_pages).astype(np.int32)
    if lengths is None:
        lengths = [n_pages * page_size] * b
    lengths = np.asarray(lengths, np.int32)
    trash = p_total - 1
    for i in range(b):
        used = -(-int(lengths[i]) // page_size) if lengths[i] else 0
        tables[i, used:] = trash
    return q, k_new, v_new, k_pages, v_pages, jnp.asarray(tables), \
        jnp.asarray(lengths)


def _assert_close(got, want, dtype=jnp.float32):
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


class TestPagedKernelVsRef:
    def test_decode_step_full_pages(self):
        prob = _problem(sq=1)
        ref = paged_attention_ref(*prob)
        got = paged_attention_pallas(*prob, page_size=8, interpret=True)
        _assert_close(got, ref)

    def test_ragged_lengths_including_zero(self):
        """Lengths that end mid-page, on a page boundary, and at zero (a
        fresh sequence whose cache phase must contribute nothing)."""
        prob = _problem(b=3, sq=1, lengths=[13, 16, 0])
        ref = paged_attention_ref(*prob)
        got = paged_attention_pallas(*prob, page_size=8, interpret=True)
        _assert_close(got, ref)

    def test_multirow_q_block_strides_page_boundary(self):
        """sq > block_q exercises the i (q-block) grid dim; lengths chosen
        so pages are full, partial, and empty across the batch."""
        prob = _problem(b=2, sq=12, n_pages=3, page_size=8,
                        lengths=[24, 9])
        ref = paged_attention_ref(*prob)
        got = paged_attention_pallas(*prob, page_size=8, block_q=8,
                                     interpret=True)
        _assert_close(got, ref)

    def test_shuffled_page_tables(self):
        """Physical page order is arbitrary — only the table defines the
        logical sequence."""
        prob = _problem(b=3, sq=4, lengths=[17, 32, 5], shuffle=True)
        ref = paged_attention_ref(*prob)
        got = paged_attention_pallas(*prob, page_size=8, interpret=True)
        _assert_close(got, ref)

    def test_bf16(self):
        prob = _problem(b=2, sq=4, lengths=[11, 26], dtype=jnp.bfloat16)
        ref = paged_attention_ref(*prob)
        got = paged_attention_pallas(*prob, page_size=8, interpret=True)
        _assert_close(got, ref, dtype=jnp.bfloat16)

    def test_page_size_mismatch_raises(self):
        prob = _problem()
        with pytest.raises(ValueError, match="page_size"):
            paged_attention_pallas(*prob, page_size=16, interpret=True)

    def test_gqa_group_mismatch_raises(self):
        q, k_new, v_new, kp, vp, tables, lengths = _problem(h=3, kv=2)
        with pytest.raises(ValueError, match="H % KV"):
            paged_attention_pallas(q, k_new, v_new, kp, vp, tables, lengths,
                                   page_size=8, interpret=True)


class TestPagedDispatch:
    def test_geometry_candidates_registered(self):
        names = {s.name for s in REGISTRY.candidates("paged_attn")}
        assert "paged_attn_ref" in names
        assert "paged_attn_pallas" in names  # default ps16_bq8 geometry
        # one candidate per registered geometry
        assert len(names) == 1 + len(PAGED_ATTN_GEOMETRY)

    def test_pinned_key_restricts_to_matching_page_size(self):
        key = paged_attn_key(q_rows=8, n_heads=4, kv_heads=2, head_dim=16,
                             kv_capacity=64, page_size=8)
        feas = {s.name for s in REGISTRY.candidates("paged_attn")
                if s.feasible(key)[0]}
        assert "paged_attn_ref" in feas  # universal fallback
        for name in feas - {"paged_attn_ref"}:
            assert "ps8" in name, f"{name} feasible under a ps=8 pin"

    def test_planning_key_admits_every_geometry(self):
        key = paged_attn_key(q_rows=8, n_heads=4, kv_heads=2, head_dim=16,
                             kv_capacity=64)  # no page-size pin
        feas = {s.name for s in REGISTRY.candidates("paged_attn")
                if s.feasible(key)[0]}
        assert len(feas) == 1 + len(PAGED_ATTN_GEOMETRY)

    def test_choose_page_size_returns_registered_geometry(self):
        ps = choose_page_size(4, 2, 16, 64, q_rows=8)
        registered = {dict(g)["ps"] for g in PAGED_ATTN_GEOMETRY}
        assert ps in registered or ps == DEFAULT_PAGE_SIZE

    def test_wrapper_matches_forced_ref(self):
        prob = _problem(b=2, sq=1, lengths=[13, 7])
        ref = paged_attention(*prob, page_size=8, impl="paged_attn_ref")
        got = paged_attention(*prob, page_size=8)
        _assert_close(got, ref)

    def test_cross_process_frozen_db_determinism(self, tmp_path):
        """A frozen profile DB pins the same paged-attention geometry in
        fresh processes (same property test_dispatch proves for linear)."""
        db = ProfileDB(path=str(tmp_path / "profile.json"))
        dispatch.set_db(db)
        try:
            key = paged_attn_key(q_rows=8, n_heads=4, kv_heads=2,
                                 head_dim=16, kv_capacity=64, page_size=16,
                                 phase="decode")
            db.put(key.token, {"impl": "paged_attn_pallas", "wall_us": 1.0})
        finally:
            dispatch.set_db(None)
        snippet = (
            "from repro import dispatch\n"
            "key = dispatch.paged_attn_key(q_rows=8, n_heads=4, kv_heads=2,"
            " head_dim=16, kv_capacity=64, page_size=16, phase='decode')\n"
            "print(dispatch.best_impl(key).name)\n"
        )
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"),
                   REPRO_DISPATCH_DB=str(db.path))
        outs = []
        for _ in range(2):
            r = subprocess.run([sys.executable, "-c", snippet], env=env,
                               capture_output=True, text=True, timeout=300)
            assert r.returncode == 0, r.stderr
            outs.append(r.stdout.strip())
        assert outs == ["paged_attn_pallas", "paged_attn_pallas"]
