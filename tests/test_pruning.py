"""Unit + property tests for the core pruning library."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    SparsityConfig,
    colwise_nm_mask,
    compress_layer,
    forward_compressed_xla,
    linear_apply,
    linear_init,
    meta_for,
    pack_colwise,
    prune_tree,
    rowwise_nm_mask,
    unbox_tree,
    unpack_colwise,
    unstructured_mask,
)
from repro.core.pruning import mask_is_colwise, mask_nm_counts, resolve_dims


def rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


# ---------------------------------------------------------------------------
# Mask invariants
# ---------------------------------------------------------------------------


class TestMasks:
    def test_colwise_exact_counts(self):
        w = rand((64, 32))
        mask = colwise_nm_mask(w, 0.5, m=16, tile=8)
        counts = mask_nm_counts(np.asarray(mask), 16)
        assert np.all(counts == 8), "exactly N=8 kept per group of M=16"

    def test_colwise_tile_shared(self):
        w = rand((128, 64))
        mask = colwise_nm_mask(w, 0.75, m=None, tile=16)
        assert mask_is_colwise(np.asarray(mask), 16)

    def test_rowwise_is_tile1(self):
        w = rand((64, 32))
        a = rowwise_nm_mask(w, 0.5, m=4)
        b = colwise_nm_mask(w, 0.5, m=4, tile=1)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rowwise_24(self):
        w = rand((64, 32))
        mask = rowwise_nm_mask(w, 0.5, m=4)
        m = np.asarray(mask).reshape(16, 4, 32)
        assert np.all(m.sum(axis=1) == 2), "2 of every 4 kept per output"

    def test_keeps_largest(self):
        # With tile == d_out the score is the column L1 norm; the mask must
        # keep the top-(1-s) columns.
        w = np.zeros((8, 4), np.float32)
        w[1] = 5.0
        w[3] = 4.0
        w[6] = 3.0
        w[0] = 2.0
        mask = np.asarray(colwise_nm_mask(jnp.asarray(w), 0.5, m=None, tile=None))
        kept_rows = set(np.nonzero(mask[:, 0])[0].tolist())
        assert kept_rows == {1, 3, 6, 0}

    def test_unstructured_count(self):
        w = rand((32, 32))
        mask = unstructured_mask(w, 0.5)
        assert int(np.asarray(mask).sum()) == 512

    @given(
        st.sampled_from([(32, 16), (64, 48), (128, 8)]),
        st.sampled_from([0.25, 0.5, 0.75]),
        st.sampled_from([4, 8, 16, None]),
        st.sampled_from([1, 4, 8, None]),
        st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_mask_properties(self, shape, sparsity, m, tile, seed):
        d_in, d_out = shape
        w = rand((d_in, d_out), seed=seed % 1000)
        cfg = SparsityConfig(sparsity=sparsity, m=m, tile=tile, format="masked")
        t, mm, n, n_tiles, n_groups, k = resolve_dims(d_in, d_out, cfg)
        mask = np.asarray(colwise_nm_mask(w, sparsity, m=m, tile=t))
        assert mask_is_colwise(mask, t)
        counts = mask_nm_counts(mask, mm)
        assert np.all(counts == n)
        # density matches N/M exactly
        assert mask.sum() == n * n_groups * d_out


# ---------------------------------------------------------------------------
# Compressed format round-trip
# ---------------------------------------------------------------------------


class TestFormats:
    @pytest.mark.parametrize("shape,cfg", [
        ((64, 32), SparsityConfig(0.5, m=16, tile=8, format="compressed_xla")),
        ((128, 96), SparsityConfig(0.75, m=None, tile=32, format="compressed_xla")),
        ((48, 48), SparsityConfig(0.25, m=8, tile=None, format="compressed_xla")),
    ])
    def test_pack_unpack_roundtrip(self, shape, cfg):
        d_in, d_out = shape
        w = rand(shape)
        meta = meta_for(d_in, d_out, cfg)
        mask = colwise_nm_mask(w, cfg.sparsity, m=cfg.m, tile=meta.tile)
        values, idx = pack_colwise(w, mask, meta)
        assert values.shape == (meta.n_tiles, meta.k_kept, meta.tile)
        assert idx.shape == (meta.n_tiles, meta.k_kept)
        # indices ascending per tile
        assert np.all(np.diff(np.asarray(idx), axis=1) > 0)
        w_rec = unpack_colwise(values, idx, meta)
        np.testing.assert_allclose(
            np.asarray(w_rec), np.asarray(w * mask.astype(w.dtype)), rtol=1e-6
        )

    def test_forward_matches_masked_dense(self):
        d_in, d_out = 96, 64
        w = rand((d_in, d_out))
        x = rand((5, d_in), seed=3)
        cfg = SparsityConfig(0.5, m=24, tile=16, format="compressed_xla")
        meta = meta_for(d_in, d_out, cfg)
        mask = colwise_nm_mask(w, cfg.sparsity, m=cfg.m, tile=meta.tile)
        values, idx = pack_colwise(w, mask, meta)
        y_ref = x @ (w * mask.astype(w.dtype))
        y = forward_compressed_xla(x, values, idx)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)

    def test_forward_grad_matches(self):
        d_in, d_out = 64, 32
        w = rand((d_in, d_out))
        x = rand((4, d_in), seed=7)
        cfg = SparsityConfig(0.5, m=None, tile=8, format="compressed_xla")
        meta = meta_for(d_in, d_out, cfg)
        mask = colwise_nm_mask(w, cfg.sparsity, tile=meta.tile)
        values, idx = pack_colwise(w, mask, meta)
        wm = w * mask.astype(w.dtype)

        g_ref = jax.grad(lambda xx: jnp.sum(jnp.sin(xx @ wm)))(x)
        g = jax.grad(lambda xx: jnp.sum(jnp.sin(forward_compressed_xla(xx, values, idx))))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)


# ---------------------------------------------------------------------------
# SparseLinear layer
# ---------------------------------------------------------------------------


class TestSparseLinear:
    def test_init_formats(self):
        key = jax.random.PRNGKey(0)
        for fmt in ["dense", "masked", "compressed_xla"]:
            cfg = SparsityConfig(0.5, tile=16, format=fmt, min_dim=1)
            p = linear_init(key, 64, 32, cfg, use_bias=True)
            vals, specs = unbox_tree(p)
            y = linear_apply(vals, rand((3, 64)))
            assert y.shape == (3, 32)
            assert jnp.isfinite(y).all()

    def test_compress_then_apply_equals_masked(self):
        key = jax.random.PRNGKey(1)
        cfg_m = SparsityConfig(0.5, m=32, tile=8, format="masked", min_dim=1)
        p = linear_init(key, 64, 32, cfg_m, use_bias=True)
        vals, _ = unbox_tree(p)
        x = rand((3, 64), seed=5)
        y_masked = linear_apply(vals, x)
        cfg_c = cfg_m.with_(format="compressed_xla")
        comp = compress_layer(vals, cfg_c)
        y_comp = linear_apply(comp, x)
        np.testing.assert_allclose(np.asarray(y_comp), np.asarray(y_masked), atol=1e-5)

    def test_min_dim_skips_small(self):
        cfg = SparsityConfig(0.5, format="compressed_xla", min_dim=256)
        p = linear_init(jax.random.PRNGKey(0), 64, 32, cfg)
        vals, _ = unbox_tree(p)
        assert "w" in vals and "values" not in vals

    def test_prune_tree_only_2d(self):
        params = {
            "w1": rand((64, 64)),
            "b": jnp.zeros((64,)),
            "emb": rand((8, 64)),  # below min_dim
        }
        cfg = SparsityConfig(0.5, format="masked", min_dim=32)
        pruned, masks = prune_tree(params, cfg)
        assert masks["b"] is None and masks["emb"] is None
        assert masks["w1"] is not None
        assert float(jnp.mean(pruned["w1"] == 0)) >= 0.5


class TestReduceMode:
    """Shard-local REDUCE-mode compression (beyond-paper, DESIGN §5)."""

    def test_pack_reduce_matches_masked(self):
        d_in, d_out, g = 64, 48, 4
        w = rand((d_in, d_out))
        from repro.core.formats import pack_reduce, unpack_reduce
        mask = colwise_nm_mask(w, 0.5, m=d_in // g, tile=None)  # tile=d_out
        values, idx = pack_reduce(w, mask, g)
        assert values.shape == (g, (d_in // g) // 2, d_out)
        w_rec = unpack_reduce(values, idx, d_in)
        np.testing.assert_allclose(np.asarray(w_rec),
                                   np.asarray(w * mask.astype(w.dtype)), rtol=1e-6)

    def test_forward_reduce_matches_masked(self):
        from repro.core.formats import pack_reduce
        from repro.core.sparse_linear import forward_compressed_reduce
        d_in, d_out, g = 64, 32, 4
        w = rand((d_in, d_out), seed=2)
        x = rand((3, 5, d_in), seed=3)
        mask = colwise_nm_mask(w, 0.5, m=d_in // g, tile=None)
        values, idx = pack_reduce(w, mask, g)
        y = forward_compressed_reduce(x, values, idx)
        y_ref = x @ (w * mask.astype(w.dtype))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)

    def test_linear_init_reduce_mode(self):
        from repro.core.sparse_linear import linear_apply, linear_init, unbox_tree
        cfg = SparsityConfig(0.5, format="compressed_xla", min_dim=1,
                             shard_local_reduce=True, reduce_groups=4)
        p = linear_init(jax.random.PRNGKey(0), 64, 32, cfg, mode="reduce")
        vals, specs = unbox_tree(p)
        assert "values_r" in vals and vals["values_r"].shape == (4, 8, 32)
        y = linear_apply(vals, rand((3, 64)))
        assert y.shape == (3, 32) and bool(jnp.isfinite(y).all())

    def test_grad_flows(self):
        from repro.core.formats import pack_reduce
        from repro.core.sparse_linear import forward_compressed_reduce
        d_in, d_out, g = 32, 16, 4
        w = rand((d_in, d_out), seed=4)
        x = rand((2, d_in), seed=5)
        mask = colwise_nm_mask(w, 0.5, m=d_in // g, tile=None)
        values, idx = pack_reduce(w, mask, g)
        wm = w * mask.astype(w.dtype)
        gx = jax.grad(lambda xx: jnp.sum(jnp.sin(forward_compressed_reduce(xx, values, idx))))(x)
        gx_ref = jax.grad(lambda xx: jnp.sum(jnp.sin(xx @ wm)))(x)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), atol=1e-5)
