"""Property tests for the logical-axis sharding resolution — the invariants
that keep every (arch × mesh) combination compiling."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding import RULES, resolve_spec


def fake_mesh(shape_dict):
    class M:
        shape = shape_dict
    return M()


MESHES = [
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
    {"data": 1, "model": 1},
]


class TestResolveSpec:
    @given(
        st.sampled_from(MESHES),
        st.lists(st.sampled_from([1, 2, 5, 15, 16, 64, 960, 2048, 151936]),
                 min_size=1, max_size=4),
        st.lists(st.sampled_from(list(RULES) + [None]), min_size=4, max_size=4),
        )
    @settings(max_examples=100, deadline=None)
    def test_invariants(self, mesh_shape, dims, names):
        mesh = fake_mesh(mesh_shape)
        names = names[: len(dims)]
        spec = resolve_spec(dims, names, RULES, mesh)
        assert len(spec) == len(dims)
        used = []
        for dim, part in zip(dims, spec):
            axes = () if part is None else (part if isinstance(part, tuple) else (part,))
            prod = 1
            for ax in axes:
                assert ax in mesh.shape, "only existing mesh axes"
                assert ax not in used, "a mesh axis used at most once"
                used.append(ax)
                prod *= mesh.shape[ax]
            assert dim % prod == 0, "sharded dims stay divisible"

    def test_indivisible_dim_left_unsharded(self):
        mesh = fake_mesh({"data": 16, "model": 16})
        spec = resolve_spec((15, 64), ("heads", "head_dim"), RULES, mesh)
        assert spec[0] is None  # 15 heads cannot shard over 16

    def test_pod_axis_dropped_on_single_pod(self):
        mesh = fake_mesh({"data": 16, "model": 16})
        spec = resolve_spec((256, 128), ("act_batch", None), RULES, mesh)
        assert spec[0] == "data"  # 'pod' silently dropped

    def test_multi_axis_batch(self):
        mesh = fake_mesh({"pod": 2, "data": 16, "model": 16})
        spec = resolve_spec((256, 128), ("act_batch", None), RULES, mesh)
        assert spec[0] == ("pod", "data")

    def test_used_axis_not_reused_across_dims(self):
        mesh = fake_mesh({"data": 16, "model": 16})
        # expert and ffn both want 'model': only the first gets it
        spec = resolve_spec((64, 2048, 1024), ("expert", "embed", "ffn"), RULES, mesh)
        assert spec[0] == "model"
        assert spec[2] is None
