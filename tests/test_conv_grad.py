"""Differentiable sparse-conv path tests: the conv custom VJP against dense
autodiff across every conv plan rung (fused / banded / two-kernel pipelined /
plain / XLA, incl. stride-2, padding, ragged strips and forced rungs), the
f32-accumulated linear backward (bf16 params, 3-D/4-D duplicate scatter),
the Boxed ``compress_conv_layer`` round trip, masked-finetune hooks, and the
resnet-tiny sparse train step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dispatch
from repro.configs import get_vision_config
from repro.core import (
    SparsityConfig,
    apply_conv_mask,
    colwise_nm_mask,
    compress_conv_layer,
    compress_conv_tree,
    conv_apply,
    conv_colwise_nm_mask,
    conv_init,
    mask_project_tree,
    prune_conv_tree,
    refresh_conv_mask,
    unbox_tree,
)
from repro.core.pruning import mask_is_colwise
from repro.dispatch import ProfileDB
from repro.kernels.colwise_nm import colwise_nm_matmul, sparse_grad_dvalues
from repro.kernels.conv_gemm import (
    compress_conv_weights,
    conv2d_cnhw_ref,
    conv2d_sparse,
)
from repro.kernels.pltpu_compat import HAS_ASYNC_COPY
from repro.models import vision


@pytest.fixture
def db(tmp_path):
    d = ProfileDB(path=str(tmp_path / "profile.json"))
    dispatch.set_db(d)
    yield d
    dispatch.set_db(None)


# every rung of the conv plan ladder (docs/kernels.md); the DMA rungs need an
# async-copy-capable pallas build, same gate as their dispatch predicates
RUNGS = [
    "fused_sparse_pallas",
    "fused_banded_pallas",
    "two_kernel_pipelined",
    "im2col_sparse_pallas",
    "im2col_sparse_xla",
]
DMA_RUNGS = {"fused_banded_pallas", "two_kernel_pipelined"}


def _conv_problem(c, b, h, w, o, k, stride, pad, dtype=jnp.float32, seed=0):
    """(x, values, idx, masked dense OHWI oracle, cotangent) for one conv."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (c, b, h, w), dtype)
    wt = jax.random.normal(jax.random.PRNGKey(seed + 1), (o, k, k, c),
                           jnp.float32)
    cfg = SparsityConfig(sparsity=0.5, m=None, tile=8,
                         format="compressed_pallas")
    values, idx, meta = compress_conv_weights(wt, cfg)
    wmat = wt.reshape(o, -1).T
    mask = colwise_nm_mask(wmat, 0.5, m=None, tile=meta.tile)
    wm = ((wmat * mask).T.reshape(o, k, k, c)).astype(dtype)
    y_ref = conv2d_cnhw_ref(x, wm, stride=stride, pad=pad)
    cot = jax.random.normal(jax.random.PRNGKey(seed + 2), y_ref.shape, dtype)
    return x, values.astype(dtype), idx, wm, cot


def _dense_ref_grads(x, wm, stride, pad, cot):
    """(dx, dW_ohwi) of the dense masked oracle under the same cotangent."""
    def loss(x, wm):
        return jnp.sum(conv2d_cnhw_ref(x, wm, stride=stride, pad=pad)
                       .astype(jnp.float32) * cot.astype(jnp.float32))

    return jax.grad(loss, argnums=(0, 1))(x, wm)


def _dvalues_ref(dw_ohwi, idx, tile):
    """Gather the dense oracle's weight grad at the kept packed positions."""
    o = dw_ohwi.shape[0]
    dwmat = np.asarray(dw_ohwi, np.float32).reshape(o, -1).T  # [K, O]
    n_tiles = idx.shape[0]
    return np.stack([dwmat[np.asarray(idx)[t], t * tile:(t + 1) * tile]
                     for t in range(n_tiles)])


class TestConvVJPLadder:
    """jax.grad through conv2d_sparse matches dense autodiff on every rung."""

    @pytest.mark.parametrize("impl", RUNGS)
    @pytest.mark.parametrize(
        "c,b,h,w,o,k,stride,pad",
        [
            (8, 2, 10, 10, 16, 3, 1, 1),   # multi-batch, padded
            (8, 1, 10, 10, 16, 3, 2, 1),   # stride 2
            (5, 2, 9, 7, 8, 3, 1, 0),      # no pad, non-square
            (6, 2, 11, 11, 8, 3, 1, 1),    # ragged: P % V != 0
        ],
    )
    def test_grad_matches_dense_reference(self, db, impl, c, b, h, w, o, k,
                                          stride, pad):
        if impl in DMA_RUNGS and not HAS_ASYNC_COPY:
            pytest.skip("pallas build has no make_async_copy")
        x, values, idx, wm, cot = _conv_problem(c, b, h, w, o, k, stride, pad)

        def loss(x, values):
            y = conv2d_sparse(x, values, idx, kh=k, kw=k, stride=stride,
                              pad=pad, v=16, impl=impl)
            return jnp.sum(y * cot)

        dx, dv = jax.grad(loss, argnums=(0, 1))(x, values)
        dx_ref, dw_ref = _dense_ref_grads(x, wm, stride, pad, cot)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(dv), _dvalues_ref(dw_ref, idx, values.shape[2]),
            rtol=1e-4, atol=1e-4)

    def test_value_and_grad_through_conv_apply(self, db):
        # the layer-level entry point (compressed conv_init params) is
        # differentiable end to end, gradients land on values only
        cfg = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                             format="compressed_pallas")
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                                         cfg, use_bias=True))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 9, 9))

        def loss(p):
            return jnp.sum(conv_apply(p, x, kh=3, kw=3, pad=1) ** 2)

        val, g = jax.value_and_grad(loss, allow_int=True)(params)
        assert np.isfinite(float(val))
        assert np.isfinite(np.asarray(g["values"], np.float32)).all()
        assert np.isfinite(np.asarray(g["b"], np.float32)).all()
        assert g["idx"].dtype == jax.dtypes.float0  # no cotangent for idx

    def test_env_forced_rung_grad(self, db, monkeypatch):
        # REPRO_DISPATCH_FORCE pins the forward rung; the backward must still
        # be the shared VJP and match the dense reference
        if not HAS_ASYNC_COPY:
            pytest.skip("pallas build has no make_async_copy")
        monkeypatch.setenv("REPRO_DISPATCH_FORCE", "fused_banded_pallas")
        x, values, idx, wm, cot = _conv_problem(8, 2, 10, 10, 16, 3, 1, 1)

        def loss(x, values):
            y = conv2d_sparse(x, values, idx, kh=3, kw=3, stride=1, pad=1,
                              v=16)
            return jnp.sum(y * cot)

        dx, dv = jax.grad(loss, argnums=(0, 1))(x, values)
        dx_ref, dw_ref = _dense_ref_grads(x, wm, 1, 1, cot)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(dv), _dvalues_ref(dw_ref, idx, values.shape[2]),
            rtol=1e-4, atol=1e-4)

    def test_grad_tracing_never_profiles(self, db, monkeypatch):
        # REPRO_DISPATCH_PROFILE=1 profiles on a DB miss at *forward* trace
        # time, but a gradient trace resolves through no_profile_scope: the
        # DB must stay empty after jax.grad
        monkeypatch.setenv("REPRO_DISPATCH_PROFILE", "1")
        x, values, idx, _wm, cot = _conv_problem(8, 1, 8, 8, 16, 3, 1, 1)

        def loss(x):
            y = conv2d_sparse(x, values, idx, kh=3, kw=3, stride=1, pad=1,
                              v=16)
            return jnp.sum(y * cot)

        jax.grad(loss)(x)
        assert not [t for t in db.tokens() if t.startswith("conv|")]


class TestLinearBackwardPrecision:
    """The f32-accumulation fixes in colwise_nm's _bwd."""

    def _linear_problem(self, batch_shape, d_in, d_out, tile, seed=0):
        w = jax.random.normal(jax.random.PRNGKey(seed), (d_in, d_out))
        mask = colwise_nm_mask(w, 0.5, m=None, tile=tile)
        from repro.core.formats import meta_for, pack_colwise

        cfg = SparsityConfig(sparsity=0.5, m=None, tile=tile,
                             format="compressed_pallas")
        values, idx = pack_colwise(w, mask, meta_for(d_in, d_out, cfg))
        x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (*batch_shape, d_in))
        cot = jax.random.normal(jax.random.PRNGKey(seed + 2),
                                (*batch_shape, d_out))
        return x, values, idx, (w * mask), cot

    def test_bf16_grads_match_f32_reference(self):
        # bf16 params used to accumulate the grad einsums in bf16; with
        # preferred_element_type=f32 the bf16 grads track the f32 oracle to
        # input-rounding accuracy over a 256-term reduction
        x, values, idx, wm, cot = self._linear_problem((64,), 512, 64, 8)

        def loss(x, values):
            return jnp.sum(colwise_nm_matmul(x, values, idx)
                           .astype(jnp.float32) * cot)

        dx16, dv16 = jax.grad(loss, argnums=(0, 1))(
            x.astype(jnp.bfloat16), values.astype(jnp.bfloat16))
        assert dx16.dtype == jnp.bfloat16 and dv16.dtype == jnp.bfloat16
        dx32, dw32 = jax.grad(
            lambda x, wm: jnp.sum((x @ wm) * cot), argnums=(0, 1))(x, wm)
        dv32 = _dvalues_ref(
            np.asarray(dw32).T.reshape(64, 1, 1, 512), idx, 8)
        scale_x = np.abs(np.asarray(dx32)).max()
        scale_v = np.abs(dv32).max()
        np.testing.assert_allclose(np.asarray(dx16, np.float32),
                                   np.asarray(dx32), rtol=3e-2,
                                   atol=3e-2 * scale_x)
        np.testing.assert_allclose(np.asarray(dv16, np.float32), dv32,
                                   rtol=3e-2, atol=3e-2 * scale_v)

    @pytest.mark.parametrize("batch_shape", [(6,), (2, 3), (2, 2, 3)])
    def test_dx_matches_dense_reference_nd(self, batch_shape):
        # leading batch dims are collapsed by colwise_nm_matmul before the
        # VJP; the duplicate scatter (tiles sharing kept d_in indices) must
        # still reproduce dense autodiff for 2-D/3-D/4-D inputs
        x, values, idx, wm, cot = self._linear_problem(batch_shape, 64, 32, 8)
        assert len(np.unique(np.asarray(idx))) < idx.size  # cross-tile dups

        def loss(x, values):
            return jnp.sum(colwise_nm_matmul(x, values, idx) * cot)

        dx, dv = jax.grad(loss, argnums=(0, 1))(x, values)
        dx_ref, dw_ref = jax.grad(
            lambda x, wm: jnp.sum((x @ wm) * cot), argnums=(0, 1))(x, wm)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(dv),
            _dvalues_ref(np.asarray(dw_ref).T.reshape(32, 1, 1, 64), idx, 8),
            rtol=1e-4, atol=1e-4)

    def test_shared_dvalues_helper_accumulates_f32(self):
        xg = jnp.ones((4, 2, 8), jnp.bfloat16)
        dy = jnp.ones((4, 2, 8), jnp.bfloat16)
        out = sparse_grad_dvalues(xg, dy, jnp.bfloat16)
        assert out.dtype == jnp.bfloat16
        # 4-row reduction of ones is exact; f16-range overflow guard
        np.testing.assert_array_equal(np.asarray(out, np.float32), 4.0)


class TestCompressConvLayerBoxed:
    CFG = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                         format="compressed_pallas")

    def test_boxed_structure_matches_conv_init(self):
        # post-hoc compression must emit the exact Boxed structure conv_init
        # emits for a born-sparse layer: same keys, same logical axes
        dense = conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                          SparsityConfig(), use_bias=True)
        comp = compress_conv_layer(dense, 3, 3, self.CFG)
        born = conv_init(jax.random.PRNGKey(1), 8, 16, 3, 3, self.CFG,
                         use_bias=True)
        assert set(comp) == set(born)
        for key in born:
            assert type(comp[key]).__name__ == "Boxed", key
            assert comp[key].spec == born[key].spec, key
            assert comp[key].value.shape == born[key].value.shape, key
            assert comp[key].value.dtype == born[key].value.dtype, key

    def test_compress_plan_params_round_trip(self, db):
        # the boxed compressed tree round-trips through plan_params exactly
        # like conv_init output: the conv_geom discriminator survives and the
        # planned token equals the one conv_apply resolves at trace time
        dense = conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                          SparsityConfig())
        comp = compress_conv_layer(dense, 3, 3, self.CFG)
        plan = dispatch.plan_params(
            {"layer": comp},
            conv_hints={"": dict(h=8, w=8, batch=2, stride=1, pad=1, v=128)})
        vals, _ = unbox_tree(comp)
        n_tiles, k_kept, tile = vals["values"].shape
        want = dispatch.conv_key(8, 8, 8, 16, 3, 3, 1, 1, k_kept, tile,
                                 v=128, batch=2).token
        assert list(plan) == [want]

    def test_compress_uses_stored_mask(self):
        # masked finetuning moves weights off their magnitude ordering; the
        # stored mask (not a recomputed one) must pin the packed support so
        # compressed inference equals the masked forward exactly
        mcfg = self.CFG.with_(format="masked", min_dim=8)
        params = conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3, mcfg)
        vals, _ = unbox_tree(params)
        # drive kept weights toward zero: a recomputed magnitude mask would
        # select a different support
        shrunk = {**params, "w": type(params["w"])(
            vals["w"] * 1e-3, params["w"].spec)}
        comp, _ = unbox_tree(compress_conv_layer(shrunk, 3, 3, self.CFG))
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 1, 8, 8))
        y = conv_apply(comp, x, kh=3, kw=3, pad=1, impl="im2col_sparse_xla")
        sv, _ = unbox_tree(shrunk)
        y_ref = conv2d_cnhw_ref(x, sv["w"] * sv["mask"].astype(sv["w"].dtype),
                                stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-6)


class TestMaskedFinetuneHooks:
    MCFG = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                          format="masked")

    def test_masked_conv_grad_confined_to_support(self):
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                                         self.MCFG))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 8, 8))
        g = jax.grad(lambda p: jnp.sum(conv_apply(p, x, kh=3, kw=3, pad=1)),
                     allow_int=True)(params)
        off = ~np.asarray(params["mask"])
        assert np.all(np.asarray(g["w"])[off] == 0)

    def test_apply_conv_mask_projects(self):
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                                         self.MCFG))
        drifted = {**params, "w": params["w"] + 1.0}  # resurrects pruned taps
        proj = apply_conv_mask(drifted)
        off = ~np.asarray(params["mask"])
        assert np.all(np.asarray(proj["w"])[off] == 0)
        on = ~off
        np.testing.assert_allclose(np.asarray(proj["w"])[on],
                                   np.asarray(drifted["w"])[on])

    def test_refresh_conv_mask_tracks_weights(self):
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                                         self.MCFG))
        # hand the layer new weights whose importance ordering differs
        new_w = jax.random.normal(jax.random.PRNGKey(7),
                                  params["w"].shape)
        refreshed = refresh_conv_mask({**params, "w": new_w}, self.MCFG)
        want = conv_colwise_nm_mask(new_w, 0.5, m=None, tile=8)
        np.testing.assert_array_equal(np.asarray(refreshed["mask"]),
                                      np.asarray(want))
        gemm_mask = np.asarray(want).reshape(16, -1).T
        assert mask_is_colwise(gemm_mask, 8)
        np.testing.assert_allclose(
            np.asarray(refreshed["w"]),
            np.asarray(new_w * want.astype(new_w.dtype)))

    def test_prune_conv_tree_then_project(self):
        cfg = get_vision_config("resnet-tiny")
        from repro.core import DENSE

        params, _ = unbox_tree(
            vision.vision_init(cfg.with_(sparsity=DENSE),
                               jax.random.PRNGKey(0)))
        pruned = prune_conv_tree(params, self.MCFG.with_(min_dim=16))
        # at least the stage convs got masks; stem (c_in=3 -> d_in=27) never
        assert "mask" not in pruned["stem"]
        assert any("mask" in blk[k] for blk in pruned["blocks"]
                   for k in ("conv1", "conv2") if isinstance(blk[k], dict))
        drift = jax.tree_util.tree_map(lambda p: p + 0.5, pruned)
        proj = mask_project_tree(drift)
        for blk_d, blk_p in zip(drift["blocks"], proj["blocks"]):
            for k in blk_d:
                if isinstance(blk_d[k], dict) and "mask" in blk_d[k]:
                    off = ~np.asarray(blk_d[k]["mask"], bool)
                    assert np.all(np.asarray(blk_p[k]["w"])[off] == 0)


    def test_compress_conv_tree_matches_masked_forward(self, db):
        # the full protocol's last step: prune -> compress_conv_tree; the
        # compressed model must reproduce the masked forward (stored masks
        # pin the packed support) and keep dense layers (stem, head) intact
        cfg = get_vision_config("resnet-tiny")
        from repro.core import DENSE

        params, _ = unbox_tree(
            vision.vision_init(cfg.with_(sparsity=DENSE),
                               jax.random.PRNGKey(0)))
        pruned = prune_conv_tree(params, self.MCFG.with_(min_dim=16))
        comp = compress_conv_tree(
            pruned, self.MCFG.with_(min_dim=16, format="compressed_pallas"))
        assert "w" in comp["stem"] and "w" in comp["head"]  # left dense
        assert any("values" in blk[k] for blk in comp["blocks"]
                   for k in ("conv1", "conv2") if isinstance(blk[k], dict))
        x, _ = vision.synth_batch(cfg, jax.random.PRNGKey(1), 2)
        y_masked = vision.vision_apply(pruned, cfg, x)
        y_comp = vision.vision_apply(comp, cfg, x)
        np.testing.assert_allclose(np.asarray(y_comp), np.asarray(y_masked),
                                   rtol=1e-4, atol=1e-4)


class TestVisionTrainStep:
    def test_train_smoke_reduces_loss(self, db):
        losses = vision.train_smoke(steps=2, verbose=False)
        assert losses[-1] < losses[0]
        assert all(np.isfinite(losses))

    def test_masked_finetune_keeps_support(self, db):
        cfg = get_vision_config("resnet-tiny")
        mcfg = cfg.with_(sparsity=cfg.sparsity.with_(format="masked"))
        params, _ = unbox_tree(vision.vision_init(mcfg, jax.random.PRNGKey(0)))
        x, labels = vision.synth_batch(cfg, jax.random.PRNGKey(1), 4)
        mom = vision.sgd_init(params)
        step = jax.jit(lambda p, m, x, y: vision.train_step(p, m, mcfg, x, y))
        before = [np.asarray(l["mask"], bool)
                  for blk in params["blocks"]
                  for l in blk.values()
                  if isinstance(l, dict) and "mask" in l]
        assert before  # masked layers exist
        params2, mom, loss = step(params, mom, x, labels)
        after = [l for blk in params2["blocks"] for l in blk.values()
                 if isinstance(l, dict) and "mask" in l]
        assert np.isfinite(float(loss))
        for mask, layer in zip(before, after):
            assert np.all(np.asarray(layer["w"])[~mask] == 0)
