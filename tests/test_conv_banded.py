"""Banded conv execution tier tests: the H-tiled megakernel (double-buffered
DMA row bands), the pipelined two-kernel strip GEMM, the four-rung conv plan
ladder in the dispatch registry, conv-aware ``plan_params`` (op
discriminator), and the resnet-tiny vision config exercising the conv
dispatch path end-to-end."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dispatch
from repro.configs import get_vision_config
from repro.core import (
    SparsityConfig,
    colwise_nm_mask,
    compress_conv_layer,
    conv_apply,
    conv_init,
    linear_init,
    unbox_tree,
)
from repro.dispatch import REGISTRY, ProfileDB
from repro.kernels.colwise_nm import (
    colwise_nm_matmul_strips,
    colwise_nm_matmul_strips_pipelined,
)
from repro.kernels.conv_gemm import (
    band_plan,
    banded_vmem_bytes,
    compress_conv_weights,
    conv2d_cnhw_ref,
    conv2d_fused,
    conv2d_fused_banded,
    conv2d_two_kernel,
    conv2d_two_kernel_pipelined,
)
from repro.kernels.im2col_pack import im2col_pack_ref, out_size
from repro.models import vision

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.fixture
def db(tmp_path):
    d = ProfileDB(path=str(tmp_path / "profile.json"))
    dispatch.set_db(d)
    yield d
    dispatch.set_db(None)


def _sparse_conv_problem(c, b, h, w, o, k, sparsity=0.5, tile=8,
                         dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(c * h + w), (c, b, h, w), dtype)
    wt = jax.random.normal(jax.random.PRNGKey(o + k), (o, k, k, c), dtype)
    cfg = SparsityConfig(sparsity=sparsity, m=None, tile=tile,
                         format="compressed_pallas")
    values, idx, meta = compress_conv_weights(wt, cfg)
    wmat = wt.reshape(o, -1).T
    mask = colwise_nm_mask(wmat, sparsity, m=None, tile=meta.tile)
    wt_masked = (wmat * mask).T.reshape(o, k, k, c).astype(dtype)
    return x, values, idx, wt_masked


class TestBandedMegakernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "c,b,h,w,o,k,stride,pad,v,hb",
        [
            (8, 2, 10, 10, 16, 3, 1, 1, 16, 1),   # halo crosses every band
            (8, 2, 10, 10, 16, 3, 1, 1, 16, 2),
            (8, 1, 12, 12, 16, 3, 2, 1, 16, 2),   # stride>1 band origins
            (5, 2, 9, 7, 8, 3, 1, 0, 8, 2),       # no pad, non-square
            (3, 1, 7, 7, 8, 3, 2, 1, 128, 2),     # single ragged strip
            (6, 2, 11, 11, 8, 3, 1, 1, 32, 4),    # ragged final band, deep
            (4, 3, 8, 8, 16, 1, 2, 0, 32, 2),     # 1x1 strided, batch 3
        ],
    )
    def test_banded_matches_reference_conv(self, dtype, c, b, h, w, o, k,
                                           stride, pad, v, hb):
        x, values, idx, wt_masked = _sparse_conv_problem(
            c, b, h, w, o, k, dtype=dtype)
        y = conv2d_fused_banded(x, values, idx, kh=k, kw=k, stride=stride,
                                pad=pad, v=v, hb=hb)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=stride, pad=pad)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            **TOL[dtype])

    def test_banded_matches_fused_when_both_run(self):
        x, values, idx, _ = _sparse_conv_problem(8, 2, 10, 10, 16, 3)
        a = dict(kh=3, kw=3, stride=1, pad=1, v=16)
        y_f = conv2d_fused(x, values, idx, **a)
        y_b = conv2d_fused_banded(x, values, idx, hb=2, **a)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_b),
                                   rtol=1e-5, atol=1e-5)

    def test_bands_are_genuinely_partial(self):
        # the correctness sweep must not silently degenerate to whole-map
        # bands: this geometry keeps under a third of the rows resident, and
        # adjacent bands share halo rows (the band-boundary case)
        b, h, k, stride, pad, v, hb = 2, 10, 3, 1, 1, 16, 1
        ho = wo = out_size(h, k, stride, pad)
        n_bands, rows = band_plan(b=b, h=h, kh=k, stride=stride, pad=pad,
                                  ho=ho, wo=wo, v=v, hb=hb)
        assert rows < b * h // 3
        assert n_bands > 3

    def test_band_plan_covers_every_strip(self):
        # coverage invariant: each band's fixed-size row window contains all
        # valid input rows of its strips — exact re-derivation per strip
        for (b, h, wo_w, k, stride, pad, v, hb) in [
                (2, 10, 10, 3, 1, 1, 16, 1), (1, 12, 12, 3, 2, 1, 16, 3),
                (3, 8, 8, 1, 2, 0, 32, 2), (2, 11, 11, 3, 1, 1, 32, 4)]:
            ho = out_size(h, k, stride, pad)
            wo = out_size(wo_w, k, stride, pad)
            n_pos = b * ho * wo
            n_strips = -(-n_pos // v)
            hb_eff = max(min(hb, n_strips), 1)
            n_bands, rows = band_plan(b=b, h=h, kh=k, stride=stride, pad=pad,
                                      ho=ho, wo=wo, v=v, hb=hb)
            assert n_bands == -(-n_strips // hb_eff)
            def first_row(p):
                bb, rem = divmod(p, ho * wo)
                return bb * h + (rem // wo) * stride - pad

            for g in range(n_bands):
                p0 = g * hb_eff * v
                p1 = min((g + 1) * hb_eff * v, n_pos) - 1
                origin = min(max(first_row(p0), 0), b * h - rows)
                # every in-bounds tap row of every position in the band must
                # fall inside the fixed-size window (first_row is monotonic
                # in p, so checking all positions is cheap and exhaustive)
                for p in range(p0, p1 + 1):
                    bb, rem = divmod(p, ho * wo)
                    for tap in range(k):
                        local = (rem // wo) * stride - pad + tap
                        if 0 <= local < h:
                            r = bb * h + local
                            assert origin <= r < origin + rows, (g, p, tap)

    def test_banded_block_k_chunking(self):
        x, values, idx, wt_masked = _sparse_conv_problem(8, 1, 9, 9, 16, 3)
        y = conv2d_fused_banded(x, values, idx, kh=3, kw=3, stride=1, pad=1,
                                v=16, block_k=8, hb=2)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)


class TestPipelinedStripGemm:
    @pytest.mark.parametrize("hb", [1, 2, 3, 100])  # 100 > n_strips: clamped
    def test_pipelined_matches_plain_strips(self, hb):
        x, values, idx, _ = _sparse_conv_problem(4, 2, 8, 8, 16, 3)
        strips = im2col_pack_ref(x, 3, 3, 1, 1, 16)  # [S, K, V]
        y_plain = colwise_nm_matmul_strips(strips, values, idx)
        y_pipe = colwise_nm_matmul_strips_pipelined(strips, values, idx,
                                                    hb=hb)
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_plain),
                                   rtol=1e-5, atol=1e-5)

    def test_pipelined_two_kernel_matches_reference(self):
        x, values, idx, wt_masked = _sparse_conv_problem(6, 2, 11, 11, 8, 3)
        y = conv2d_two_kernel_pipelined(x, values, idx, kh=3, kw=3, stride=1,
                                        pad=1, v=32, hb=2)
        y_ref = conv2d_cnhw_ref(x, wt_masked, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_pipelined_matches_two_kernel_ragged_final_chunk(self):
        # n_strips odd with hb=2: the final chunk re-covers the previous
        # chunk's tail instead of reading out of bounds
        x, values, idx, _ = _sparse_conv_problem(5, 1, 10, 10, 8, 3)
        a = dict(kh=3, kw=3, stride=1, pad=1, v=16)
        n_pos = 10 * 10
        assert (-(-n_pos // 16)) % 2 == 1
        y1 = conv2d_two_kernel(x, values, idx, **a)
        y2 = conv2d_two_kernel_pipelined(x, values, idx, hb=2, **a)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)


class TestPlanLadder:
    """The four rungs (VMEM-resident -> banded -> pipelined -> XLA) separate
    by their feasibility predicates, and the platform heuristic walks them in
    order as shapes grow."""

    # (key kwargs) per rung: tiny / stem-scale / wide-row / huge
    KEYS = {
        "resident": dict(c=8, h=10, w=10, o=16, kh=3, kw=3, stride=1, pad=1,
                         k_kept=36, tile=8, batch=2),
        "banded": dict(c=64, h=112, w=112, o=64, kh=3, kw=3, stride=2, pad=1,
                       k_kept=288, tile=64, batch=8),
        "pipelined": dict(c=512, h=64, w=2048, o=128, kh=3, kw=3, stride=1,
                          pad=1, k_kept=2304, tile=128, batch=1),
        "xla": dict(c=4096, h=512, w=512, o=128, kh=3, kw=3, stride=1, pad=1,
                    k_kept=18432, tile=128, batch=1),
    }
    FAMILY = {
        "resident": "fused_sparse_pallas",
        "banded": "fused_banded_pallas",
        "pipelined": "two_kernel_pipelined",
        "xla": "im2col_sparse_xla",
    }

    @staticmethod
    def _key(kw):
        return dispatch.conv_key(kw["c"], kw["h"], kw["w"], kw["o"], kw["kh"],
                                 kw["kw"], kw["stride"], kw["pad"],
                                 kw["k_kept"], kw["tile"], batch=kw["batch"])

    def test_predicates_separate_the_rungs(self):
        resident = REGISTRY.get("conv", "fused_sparse_pallas")
        banded = REGISTRY.get("conv", "fused_banded_pallas")
        key_b = self._key(self.KEYS["banded"])
        assert not resident.feasible(key_b)[0]
        assert banded.feasible(key_b)[0]
        key_p = self._key(self.KEYS["pipelined"])
        assert not any(
            s.feasible(key_p)[0] for s in REGISTRY.candidates("conv")
            if s.name.startswith("fused_"))
        assert any(
            s.feasible(key_p)[0] for s in REGISTRY.candidates("conv")
            if s.name.startswith("two_kernel_pipelined"))
        key_x = self._key(self.KEYS["xla"])
        feas = [s.name for s in
                REGISTRY.feasible(key_x, param_keys=("values", "idx"))]
        assert feas == ["im2col_sparse_xla"]

    def test_heuristic_walks_the_ladder(self, db, monkeypatch):
        # the pallas rungs are ahead of XLA only on the matching platform
        monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
        for rung, kw in self.KEYS.items():
            spec = dispatch.best_impl(self._key(kw),
                                      param_keys=("values", "idx"))
            assert spec.name.startswith(self.FAMILY[rung]), (rung, spec.name)

    def test_profiled_db_pins_each_rung(self, db):
        # a profiled winner per rung shape: the frozen-DB selection returns
        # each rung's candidate (and its geometry) for its shape
        for rung, kw in self.KEYS.items():
            key = self._key(kw)
            name = self.FAMILY[rung]
            if rung == "banded":
                name += "@v256_bk128_hb2"  # a non-default banded geometry
            if rung == "pipelined":
                name += "@v128_bk64_hb1"
            db.put(key.token, {"impl": name, "wall_us": 1.0})
            spec = dispatch.best_impl(key, param_keys=("values", "idx"))
            assert spec.name == name, (rung, spec.name)
            if rung in ("banded", "pipelined"):
                assert spec.geom("hb") > 0

    def test_banded_vmem_predicate_is_dtype_aware_of_double_buffer(self):
        # the same band geometry is feasible in bf16 but not f32, and the
        # analytic model counts BOTH band buffers of the double buffer
        spec = REGISTRY.get("conv", "fused_banded_pallas")
        hb = spec.geom("hb")
        # w chosen so hb*v does not divide wo: bands cross an output-row
        # boundary and the window carries the full stride+halo row count
        kw = dict(c=320, h=640, w=1800, o=256, k_kept=1440, tile=128)
        f32 = dispatch.conv_key(kw["c"], kw["h"], kw["w"], kw["o"], 3, 3, 1,
                                1, kw["k_kept"], kw["tile"], dtype="float32")
        bf16 = dispatch.conv_key(kw["c"], kw["h"], kw["w"], kw["o"], 3, 3, 1,
                                 1, kw["k_kept"], kw["tile"],
                                 dtype="bfloat16")
        assert spec.vmem_bytes(f32) > spec.vmem_bytes(bf16)
        assert not spec.feasible(f32)[0] and spec.feasible(bf16)[0]
        ho = out_size(kw["h"], 3, 1, 1)
        wo = out_size(kw["w"], 3, 1, 1)
        _, rows = band_plan(b=1, h=kw["h"], kh=3, stride=1, pad=1, ho=ho,
                            wo=wo, v=spec.geom("v"), hb=hb)
        one_band = kw["c"] * rows * kw["w"] * 4
        assert spec.vmem_bytes(f32) > 2 * one_band

    def test_banded_geometry_cross_process_deterministic(self, db):
        """A frozen DB naming a banded geometry variant reproduces the
        identical impl+geometry (incl. band depth) in fresh processes."""
        kw = self.KEYS["banded"]
        key = self._key(kw)
        name = "fused_banded_pallas@v256_bk128_hb2"
        db.put(key.token, {"impl": name, "wall_us": 1.0})
        snippet = (
            "from repro import dispatch\n"
            f"key = dispatch.conv_key({kw['c']}, {kw['h']}, {kw['w']}, "
            f"{kw['o']}, 3, 3, {kw['stride']}, {kw['pad']}, {kw['k_kept']}, "
            f"{kw['tile']}, batch={kw['batch']})\n"
            "s = dispatch.best_impl(key, param_keys=('values','idx'))\n"
            "print(s.name, s.geom('v'), s.geom('bk'), s.geom('hb'))\n"
        )
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"),
                   REPRO_DISPATCH_DB=str(db.path))
        outs = []
        for _ in range(2):
            r = subprocess.run([sys.executable, "-c", snippet], env=env,
                               capture_output=True, text=True, timeout=300)
            assert r.returncode == 0, r.stderr
            outs.append(r.stdout.strip())
        assert outs == [f"{name} 256 128 2"] * 2

    def test_forced_banded_and_pipelined_execute(self, db):
        # REPRO_DISPATCH_FORCE-style forcing by name runs the DMA plans with
        # real params through the conv layer abstraction
        cfg = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                             format="compressed_pallas")
        params, _ = unbox_tree(conv_init(jax.random.PRNGKey(2), 8, 16, 3, 3,
                                         cfg))
        x = jax.random.normal(jax.random.PRNGKey(3), (8, 2, 9, 9))
        ys = [np.asarray(conv_apply(params, x, kh=3, kw=3, pad=1, impl=name))
              for name in ("fused_banded_pallas", "two_kernel_pipelined",
                           "im2col_sparse_xla")]
        np.testing.assert_allclose(ys[0], ys[2], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(ys[1], ys[2], rtol=1e-4, atol=1e-4)


class TestConvAwarePlanParams:
    CFG = SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=8,
                         format="compressed_pallas")

    def _tree(self):
        return {
            "blk": conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3, self.CFG),
            "head": linear_init(jax.random.PRNGKey(1), 128, 256,
                                self.CFG.with_(min_dim=128)),
        }

    def test_discriminator_separates_ops(self):
        ops = {p: op for p, op, _ in dispatch.iter_op_layers(self._tree())}
        assert ops == {"blk": "conv", "head": "linear"}
        info = next(i for _, op, i in dispatch.iter_op_layers(self._tree())
                    if op == "conv")
        assert (info["kh"], info["kw"], info["c_in"]) == (3, 3, 8)

    def test_iter_compressed_layers_back_compat(self):
        # the legacy generator still yields BOTH kinds (3-tuples)
        out = list(dispatch.iter_compressed_layers(self._tree()))
        assert {p for p, _v, _i in out} == {"blk", "head"}

    def test_conv_layers_planned_under_conv_tokens(self, db):
        plan = dispatch.plan_params(
            self._tree(), batch_hint=8,
            conv_hints={"": {"h": 10, "w": 10, "batch": 2, "stride": 1,
                             "pad": 1, "v": 16}})
        want = dispatch.conv_key(8, 10, 10, 16, 3, 3, 1, 1, 36, 8, v=16,
                                 batch=2).token
        assert want in plan
        # exactly one conv token and one linear token; nothing misfiled
        assert sum(t.startswith("conv|") for t in plan) == 1
        assert sum(t.startswith("linear|") for t in plan) == 1

    def test_conv_without_hint_is_skipped_not_misfiled(self, db):
        plan = dispatch.plan_params(self._tree(), batch_hint=8)
        assert not any(t.startswith("conv|") for t in plan)
        assert sum(t.startswith("linear|") for t in plan) == 1

    def test_longest_hint_key_wins(self, db):
        tree = {"a": {"blk": conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                                       self.CFG)}}
        plan = dispatch.plan_params(
            tree,
            conv_hints={"": {"h": 8, "batch": 1},
                        "a/blk": {"h": 12, "batch": 1, "pad": 1}})
        assert any("|h12|" in t for t in plan), list(plan)

    def test_scan_stacked_conv_geom(self):
        # a lax.scan-stacked conv layer carries an [L, 3] marker; the scan
        # reads layer 0's statics instead of crashing
        p, _ = unbox_tree(conv_init(jax.random.PRNGKey(0), 8, 16, 3, 3,
                                    self.CFG))
        stacked = {k: np.stack([np.asarray(v)] * 4) for k, v in p.items()}
        (path, op, info), = dispatch.iter_op_layers({"scan": stacked})
        assert op == "conv"
        assert (info["kh"], info["kw"], info["c_in"]) == (3, 3, 8)

    def test_compress_conv_layer_carries_discriminator(self):
        dense, _ = unbox_tree(conv_init(jax.random.PRNGKey(6), 8, 16, 3, 3,
                                        SparsityConfig()))
        comp = compress_conv_layer(dense, 3, 3, self.CFG)
        assert [int(v) for v in comp["conv_geom"].value] == [3, 3, 8]
        ops = [op for _, op, _ in dispatch.iter_op_layers({"l": comp})]
        assert ops == ["conv"]


class TestVisionConfig:
    def test_resnet_tiny_forward(self):
        cfg = get_vision_config("resnet-tiny")
        params, specs = unbox_tree(vision.vision_init(cfg,
                                                      jax.random.PRNGKey(0)))
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.c_in, 2, *cfg.image_hw))
        logits = vision.vision_apply(params, cfg, x)
        assert logits.shape == (2, cfg.num_classes)
        assert np.isfinite(np.asarray(logits)).all()

    def test_pruned_convs_present_and_stem_dense(self):
        cfg = get_vision_config("resnet-tiny")
        params, _ = unbox_tree(vision.vision_init(cfg, jax.random.PRNGKey(0)))
        assert "w" in params["stem"]  # 3-channel stem left dense (paper)
        conv_paths = [p for p, op, _ in dispatch.iter_op_layers(params)
                      if op == "conv"]
        assert len(conv_paths) >= 4  # both stages' 3x3s are pruned

    def test_plan_matches_trace_time_conv_tokens(self, db):
        # end-to-end: every conv token the traced forward resolves was
        # pre-planned by plan_params(conv_hints=vision.conv_hints(cfg))
        cfg = get_vision_config("resnet-tiny")
        params, _ = unbox_tree(vision.vision_init(cfg, jax.random.PRNGKey(0)))
        plan = dispatch.plan_params(params, batch_hint=2,
                                    conv_hints=vision.conv_hints(cfg, batch=2))
        seen = []
        orig = dispatch.best_impl

        def spy(key, **kw):
            seen.append(key.token)
            return orig(key, **kw)

        dispatch.best_impl = spy
        try:
            x = jax.random.normal(jax.random.PRNGKey(1),
                                  (cfg.c_in, 2, *cfg.image_hw))
            vision.vision_apply(params, cfg, x)
        finally:
            dispatch.best_impl = orig
        trace_conv = {t for t in seen if t.startswith("conv|")}
        assert trace_conv and trace_conv <= set(plan)

    def test_forward_matches_forced_xla_plan(self, db):
        # the dispatched forward equals the forced XLA-reference-plan forward
        cfg = get_vision_config("resnet-tiny")
        params, _ = unbox_tree(vision.vision_init(cfg, jax.random.PRNGKey(4)))
        x = jax.random.normal(jax.random.PRNGKey(5),
                              (cfg.c_in, 1, *cfg.image_hw))
        y = vision.vision_apply(params, cfg, x)
        y_ref = vision.vision_apply(params, cfg, x, impl="im2col_sparse_xla")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_banded_plan_through_vision_model(self, db):
        # force the DMA megakernel through a whole vision forward
        cfg = get_vision_config("resnet-tiny")
        params, _ = unbox_tree(vision.vision_init(cfg, jax.random.PRNGKey(6)))
        x = jax.random.normal(jax.random.PRNGKey(7),
                              (cfg.c_in, 1, *cfg.image_hw))
        y = vision.vision_apply(params, cfg, x, impl="fused_banded_pallas")
        y_ref = vision.vision_apply(params, cfg, x, impl="im2col_sparse_xla")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
