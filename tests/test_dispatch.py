"""Tests for the operator dispatch & profiling subsystem (`repro.dispatch`):
registry feasibility filtering, profile-DB round-trip + fingerprint/version
invalidation + atomic writes, deterministic selection from a frozen DB
(including across processes), numerical equivalence of every registered
linear candidate, escape hatches, and the absorbed Tuner's fixes."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dispatch
from repro.core import (
    SparsityConfig,
    colwise_nm_mask,
    linear_apply,
    linear_init,
    meta_for,
    pack_colwise,
    unbox_tree,
)
from repro.dispatch import (
    REGISTRY,
    OpKey,
    ProfileDB,
    SCHEMA_VERSION,
    Tuner,
    TuningError,
    linear_key,
    profile_op,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def db(tmp_path):
    d = ProfileDB(path=str(tmp_path / "profile.json"))
    dispatch.set_db(d)
    yield d
    dispatch.set_db(None)


def _small_key():
    return linear_key(batch=8, d_in=64, d_out=64, k_kept=32, tile=16)


# ---------------------------------------------------------------------------
# Registry & feasibility
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_linear_candidates_registered(self):
        names = {s.name for s in REGISTRY.candidates("linear")}
        assert {"dense", "masked", "compressed_xla", "compressed_pallas"} <= names

    def test_conv_candidates_registered(self):
        names = {s.name for s in REGISTRY.candidates("conv")}
        assert {"dense_conv", "im2col_dense_gemm", "im2col_sparse_xla",
                "im2col_sparse_pallas", "fused_sparse_pallas"} <= names

    def test_geometry_variants_registered(self):
        # block geometry lives in the candidate space: one candidate per
        # geometry grid point, default geometry keeping the bare family name
        linear = {s.name for s in REGISTRY.candidates("linear")}
        assert "compressed_pallas" in linear
        assert any(n.startswith("compressed_pallas@") for n in linear)
        conv = {s.name for s in REGISTRY.candidates("conv")}
        assert any(n.startswith("fused_sparse_pallas@") for n in conv)
        for s in REGISTRY.candidates("linear"):
            if s.name.startswith("compressed_pallas"):
                assert s.geom("bb") > 0 and s.geom("bk") > 0

    def test_param_keys_filter(self):
        # a compressed layer can only execute compressed candidates; the
        # pallas family contributes one candidate per geometry point
        names = {s.name for s in
                 REGISTRY.candidates("linear", param_keys=("values", "idx"))}
        assert {n.split("@")[0] for n in names} == {
            "compressed_xla", "compressed_pallas"}
        assert "compressed_pallas" in names

    def test_masked_layer_never_resolves_dense(self):
        # dense (requires {w}) is a strict-subset match for {w, mask} but
        # would silently drop the mask; the most-specific rule must hide it
        names = {s.name for s in
                 REGISTRY.candidates("linear", param_keys=("w", "mask"))}
        assert names == {"masked"}
        names = {s.name for s in REGISTRY.candidates("linear", param_keys=("w",))}
        assert names == {"dense"}

    def test_vmem_infeasibility_filters_pallas(self):
        huge = linear_key(batch=512, d_in=1 << 22, d_out=2048, k_kept=1 << 21,
                          tile=512)
        feas = {s.name for s in
                REGISTRY.feasible(huge, param_keys=("values", "idx"))}
        assert "compressed_pallas" not in feas
        assert "compressed_xla" in feas
        spec = REGISTRY.get("linear", "compressed_pallas")
        ok, reason = spec.feasible(huge)
        assert not ok and "VMEM" in reason

    def test_divisibility_infeasibility(self):
        odd = OpKey(op="linear", batch=8, d_in=64, d_out=60, k_kept=30, tile=7)
        ok, reason = REGISTRY.get("linear", "compressed_pallas").feasible(odd)
        assert not ok

    def test_infeasible_key_still_dispatches(self, db):
        # every predicate failing degrades to smallest-footprint, not a crash
        odd = OpKey(op="linear", batch=8, d_in=64, d_out=60, k_kept=30, tile=7)
        spec = dispatch.best_impl(odd, param_keys=("values", "idx"))
        assert spec.name == "compressed_xla"


# ---------------------------------------------------------------------------
# Profile DB persistence
# ---------------------------------------------------------------------------


class TestProfileDB:
    def test_roundtrip(self, tmp_path):
        p = str(tmp_path / "db.json")
        d1 = ProfileDB(path=p)
        d1.put("k1", {"impl": "compressed_xla", "wall_us": 1.0})
        d2 = ProfileDB(path=p)
        assert d2.get("k1") == {"impl": "compressed_xla", "wall_us": 1.0}
        assert not d2.invalidated

    def test_atomic_write_leaves_no_temp(self, tmp_path):
        p = tmp_path / "db.json"
        d = ProfileDB(path=str(p))
        for i in range(5):
            d.put(f"k{i}", {"impl": "x", "wall_us": float(i)})
        leftovers = [f for f in tmp_path.iterdir() if f.name != "db.json"]
        assert leftovers == []
        json.loads(p.read_text())  # parseable, never torn

    def test_schema_version_mismatch_invalidates(self, tmp_path):
        p = tmp_path / "db.json"
        d = ProfileDB(path=str(p))
        d.put("k1", {"impl": "x"})
        data = json.loads(p.read_text())
        data["version"] = SCHEMA_VERSION - 1
        p.write_text(json.dumps(data))
        d2 = ProfileDB(path=str(p))
        assert d2.invalidated and len(d2) == 0

    def test_fingerprint_mismatch_invalidates(self, tmp_path):
        p = tmp_path / "db.json"
        d = ProfileDB(path=str(p))
        d.put("k1", {"impl": "x"})
        data = json.loads(p.read_text())
        data["fingerprint"]["backend"] = "not-a-real-backend"
        p.write_text(json.dumps(data))
        d2 = ProfileDB(path=str(p))
        assert d2.invalidated and len(d2) == 0

    def test_seed_era_bare_dict_invalidated(self, tmp_path):
        # the seed wrote {key: record} with no version envelope
        p = tmp_path / "tuning_cache.json"
        p.write_text(json.dumps({"b64_i256_o256_s50": {"tile": 64}}))
        d = ProfileDB(path=str(p))
        assert d.invalidated and len(d) == 0

    def test_lru_caps_entries(self, tmp_path):
        d = ProfileDB(path=str(tmp_path / "db.json"), max_entries=3,
                      autosave=False)
        for i in range(6):
            d.put(f"k{i}", {"impl": "x"}, save=False)
        assert len(d) == 3 and d.get("k5") is not None and d.get("k0") is None


# ---------------------------------------------------------------------------
# Selection: frozen DB determinism, overrides, escape hatches
# ---------------------------------------------------------------------------


class TestSelection:
    def test_frozen_db_overrides_heuristic(self, db):
        key = _small_key()
        # CPU heuristic would pick compressed_xla; a frozen profile saying
        # pallas won must be honoured verbatim
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        spec = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert spec.name == "compressed_pallas"

    def test_selection_deterministic(self, db):
        key = _small_key()
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        names = {dispatch.best_impl(key, param_keys=("values", "idx")).name
                 for _ in range(10)}
        assert names == {"compressed_pallas"}

    def test_profile_then_select_consistent(self, db):
        key = _small_key()
        rec = profile_op(key, db, param_keys=("values", "idx"), iters=2)
        assert rec["impl"] in rec["all"]
        assert dispatch.best_impl(key, param_keys=("values", "idx")).name == rec["impl"]

    def test_env_off_restores_legacy_routing(self, db, monkeypatch):
        key = _small_key()
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        monkeypatch.setenv("REPRO_DISPATCH", "off")
        spec = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert spec.name == "compressed_xla"

    def test_explicit_force_wins_even_when_off(self, db, monkeypatch):
        monkeypatch.setenv("REPRO_DISPATCH", "off")
        spec = dispatch.best_impl(_small_key(), param_keys=("values", "idx"),
                                  force="compressed_pallas")
        assert spec.name == "compressed_pallas"

    def test_env_force(self, db, monkeypatch):
        monkeypatch.setenv("REPRO_DISPATCH_FORCE", "compressed_pallas")
        spec = dispatch.best_impl(_small_key(), param_keys=("values", "idx"))
        assert spec.name == "compressed_pallas"

    def test_unknown_force_raises(self, db):
        with pytest.raises(KeyError):
            dispatch.best_impl(_small_key(), force="no_such_impl")

    def test_explicit_force_incompatible_params_raises(self, db):
        # 'dense' is registered but requires {"w"}; explicitly forcing it for
        # a compressed layer is a caller bug, not something to paper over
        with pytest.raises(KeyError, match="requires"):
            dispatch.best_impl(_small_key(), param_keys=("values", "idx"),
                               force="dense")

    def test_env_force_incompatible_params_ignored(self, db, monkeypatch):
        # the process-wide override skips layers it cannot execute
        monkeypatch.setenv("REPRO_DISPATCH_FORCE", "dense")
        spec = dispatch.best_impl(_small_key(), param_keys=("values", "idx"))
        assert spec.name == "compressed_xla"

    def test_new_registration_invalidates_memo(self, db):
        import dataclasses

        key = _small_key()
        first = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert first.name == "compressed_xla"
        spec = REGISTRY.get("linear", "compressed_xla")
        try:
            # re-register under a new name with priority that beats the memo'd
            # winner: best_impl must see it without any manual cache clearing
            REGISTRY.register(dataclasses.replace(spec, name="compressed_xla2",
                                                  priority=1))
            assert dispatch.best_impl(
                key, param_keys=("values", "idx")).name == "compressed_xla2"
        finally:
            del REGISTRY._impls["linear"]["compressed_xla2"]
            REGISTRY.generation += 1

    def test_cross_process_determinism(self, tmp_path, db):
        """A frozen profile DB reproduces identical selections in fresh
        processes (the AITemplate 'bake the winner in' property)."""
        key = _small_key()
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        snippet = (
            "from repro import dispatch\n"
            f"key = dispatch.linear_key(batch=8, d_in=64, d_out=64, k_kept=32, tile=16)\n"
            "print(dispatch.best_impl(key, param_keys=('values','idx')).name)\n"
        )
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"),
                   REPRO_DISPATCH_DB=str(db.path))
        outs = []
        for _ in range(2):
            r = subprocess.run([sys.executable, "-c", snippet], env=env,
                               capture_output=True, text=True, timeout=300)
            assert r.returncode == 0, r.stderr
            outs.append(r.stdout.strip())
        assert outs == ["compressed_pallas", "compressed_pallas"]


# ---------------------------------------------------------------------------
# Numerical equivalence of every registered linear candidate
# ---------------------------------------------------------------------------


class TestLinearEquivalence:
    def _problem(self, d_in=64, d_out=64, batch=4, sparsity=0.5, tile=16):
        w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out)) / (d_in ** 0.5)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, d_in))
        cfg = SparsityConfig(sparsity, m=None, tile=tile, format="compressed_xla")
        meta = meta_for(d_in, d_out, cfg)
        mask = colwise_nm_mask(w, sparsity, tile=meta.tile)
        values, idx = pack_colwise(w, mask, meta)
        return x, w, mask, values, idx

    def test_every_candidate_matches_dense_reference(self):
        x, w, mask, values, idx = self._problem()
        refs = {
            frozenset({"w"}): np.asarray(x @ w),
            frozenset({"w", "mask"}): np.asarray(x @ (w * mask)),
            frozenset({"values", "idx"}): np.asarray(x @ (w * mask)),
        }
        params_by_req = {
            frozenset({"w"}): {"w": w},
            frozenset({"w", "mask"}): {"w": w, "mask": mask},
            frozenset({"values", "idx"}): {"values": values, "idx": idx},
        }
        checked = 0
        for spec in REGISTRY.candidates("linear"):
            assert spec.apply is not None, f"{spec.name} has no apply"
            y = spec.apply(params_by_req[spec.requires], x)
            np.testing.assert_allclose(
                np.asarray(y), refs[spec.requires], rtol=1e-4, atol=1e-4,
                err_msg=f"candidate {spec.name} diverges from dense reference")
            checked += 1
        assert checked >= 4

    def test_linear_apply_executes_db_selection(self, db, monkeypatch):
        # route linear_apply's compressed branch through a counting pallas
        # impl pinned by the profile DB — proves the dispatch layer, not a
        # hardcoded branch, picks the kernel
        x, w, mask, values, idx = self._problem()
        key = dispatch.linear_key_from(x.shape, values.shape)
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        calls = []
        spec = REGISTRY.get("linear", "compressed_pallas")
        counting = dataclasses.replace(
            spec, apply=lambda p, xx: (calls.append(1),
                                       spec.apply(p, xx))[1])
        monkeypatch.setitem(REGISTRY._impls["linear"], "compressed_pallas",
                            counting)
        y = linear_apply({"values": values, "idx": idx}, x)
        assert calls, "profile-DB winner was not executed"
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ (w * mask)),
                                   rtol=1e-4, atol=1e-4)

    def test_linear_apply_off_switch(self, db, monkeypatch):
        x, w, mask, values, idx = self._problem()
        monkeypatch.setenv("REPRO_DISPATCH", "off")
        y = linear_apply({"values": values, "idx": idx}, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ (w * mask)),
                                   rtol=1e-4, atol=1e-4)

    def test_linear_apply_under_jit(self, db):
        x, w, mask, values, idx = self._problem()
        f = jax.jit(lambda x: linear_apply({"values": values, "idx": idx}, x))
        np.testing.assert_allclose(np.asarray(f(x)),
                                   np.asarray(x @ (w * mask)),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Build-time plan (serve Engine integration)
# ---------------------------------------------------------------------------


class TestPlanParams:
    def test_plan_finds_compressed_layers(self, db):
        cfg = SparsityConfig(sparsity=0.5, format="compressed_xla",
                             min_dim=8, tile=16)
        params = linear_init(jax.random.PRNGKey(0), 64, 64, cfg)
        vals, _ = unbox_tree(params)
        tree = {"blocks": [{"mlp": vals}], "head": {"w": jnp.zeros((4, 4))}}
        plan = dispatch.plan_params(tree, batch_hint=8)
        assert len(plan) == 1
        (token, impl), = plan.items()
        assert token.startswith("linear|") and impl in (
            "compressed_xla", "compressed_pallas")

    def test_plan_respects_frozen_db(self, db):
        cfg = SparsityConfig(sparsity=0.5, format="compressed_xla",
                             min_dim=8, tile=16)
        vals, _ = unbox_tree(linear_init(jax.random.PRNGKey(0), 64, 64, cfg))
        token = next(iter(dispatch.plan_params({"l": vals}, batch_hint=8)))
        db.put(token, {"impl": "compressed_pallas", "wall_us": 1.0})
        plan = dispatch.plan_params({"l": vals}, batch_hint=8)
        assert plan[token] == "compressed_pallas"


# ---------------------------------------------------------------------------
# Absorbed Tuner: crash fix, profile=False fallback, stale-cache invalidation
# ---------------------------------------------------------------------------


class TestTunerFixes:
    def test_all_infeasible_raises_named_error(self, tmp_path):
        t = Tuner(cache_path=str(tmp_path / "c.json"))
        with pytest.raises(TuningError, match=r"d_in=10000000"):
            t.tune(batch=1, d_in=10_000_000, d_out=512, profile=False)

    def test_profile_disabled_falls_back_to_smallest_vmem(self, tmp_path):
        from repro.dispatch import enumerate_candidates

        t = Tuner(cache_path=str(tmp_path / "c.json"))
        r = t.tune(batch=8, d_in=256, d_out=256, profile=False)
        feas = [c for c in enumerate_candidates(256, 256) if c.feasible]
        assert r["vmem_bytes"] == min(c.vmem_bytes for c in feas)
        assert r["wall_us"] is None  # nothing was wall-clocked

    def test_stale_seed_cache_not_reused(self, tmp_path):
        p = tmp_path / "tuning_cache.json"
        stale = {"b8_i256_o256_s50": {"tile": 999, "block_b": 1, "block_k": 1,
                                      "wall_us": 0.1, "vmem_bytes": 1}}
        p.write_text(json.dumps(stale))
        t = Tuner(cache_path=str(p))
        assert len(t.db) == 0  # versionless seed cache dropped
        r = t.tune(batch=8, d_in=256, d_out=256, profile=False)
        assert r["tile"] != 999

    def test_tuner_persists_versioned_format(self, tmp_path):
        p = tmp_path / "c.json"
        t = Tuner(cache_path=str(p))
        t.tune(batch=8, d_in=256, d_out=256, profile=False)
        data = json.loads(p.read_text())
        assert data["version"] == SCHEMA_VERSION
        assert "fingerprint" in data and "entries" in data
