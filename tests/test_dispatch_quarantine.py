"""Dispatch quarantine-degradation: failing candidates are denylisted at
runtime and the key re-resolves down the ladder — without restarting the
process, without corrupting the profile DB, and with identical outputs."""
import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import dispatch, fault
from repro.core.formats import meta_for, pack_colwise
from repro.core.pruning import SparsityConfig, colwise_nm_mask
from repro.core.sparse_linear import linear_apply
from repro.dispatch import REGISTRY, ProfileDB, linear_key

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture
def db(tmp_path):
    d = ProfileDB(path=str(tmp_path / "profile.json"))
    dispatch.set_db(d)
    yield d
    dispatch.set_db(None)


@pytest.fixture(autouse=True)
def _clean_quarantine():
    dispatch.clear_quarantine()
    yield
    dispatch.clear_quarantine()


def _small_key(phase=None):
    return linear_key(batch=8, d_in=64, d_out=64, k_kept=32, tile=16,
                      phase=phase)


def _problem(d_in=64, d_out=64, batch=8, sparsity=0.5, tile=16):
    w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out)) / (d_in ** 0.5)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, d_in))
    cfg = SparsityConfig(sparsity, m=None, tile=tile, format="compressed_xla")
    meta = meta_for(d_in, d_out, cfg)
    mask = colwise_nm_mask(w, sparsity, tile=meta.tile)
    values, idx = pack_colwise(w, mask, meta)
    return x, {"values": values, "idx": idx}


class TestQuarantineState:
    def test_quarantine_and_query(self):
        assert dispatch.quarantined() == frozenset()
        assert dispatch.quarantine("linear", "compressed_xla", reason="boom")
        assert ("linear", "compressed_xla") in dispatch.quarantined()
        assert dispatch.quarantined("linear") == frozenset({"compressed_xla"})
        # idempotent: re-quarantining the same pair reports nothing new
        assert not dispatch.quarantine("linear", "compressed_xla")

    def test_clear_restores(self):
        dispatch.quarantine("linear", "compressed_xla")
        dispatch.clear_quarantine()
        assert dispatch.quarantined() == frozenset()

    def test_quarantined_impl_skipped_by_resolution(self, db):
        key = _small_key()
        first = dispatch.best_impl(key, param_keys=("values", "idx"))
        dispatch.quarantine(key.op, first.name)
        nxt = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert nxt.name != first.name

    def test_survives_memoization(self, db):
        """best_impl memoizes per (key, env); the quarantine generation is
        part of the memo key, so a quarantine takes effect immediately
        without any manual cache clearing."""
        key = _small_key()
        first = dispatch.best_impl(key, param_keys=("values", "idx"))
        # prime the memo hard
        for _ in range(3):
            assert dispatch.best_impl(
                key, param_keys=("values", "idx")).name == first.name
        dispatch.quarantine(key.op, first.name)
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name != first.name
        dispatch.clear_quarantine()
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name == first.name

    def test_never_empties_candidate_set(self, db):
        """Quarantining every feasible candidate must not strand the op with
        nothing to run: the filter backs off and resolution proceeds as if
        no quarantine existed (better a suspect impl than none)."""
        key = _small_key()
        for spec in REGISTRY.candidates("linear"):
            dispatch.quarantine("linear", spec.name)
        spec = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert spec.name  # resolved something runnable

    def test_explicit_force_wins_over_quarantine(self, db):
        dispatch.quarantine("linear", "compressed_pallas")
        spec = dispatch.best_impl(_small_key(), param_keys=("values", "idx"),
                                  force="compressed_pallas")
        assert spec.name == "compressed_pallas"

    def test_env_force_yields_to_quarantine(self, db, monkeypatch):
        monkeypatch.setenv("REPRO_DISPATCH_FORCE", "compressed_pallas")
        dispatch.quarantine("linear", "compressed_pallas")
        spec = dispatch.best_impl(_small_key(), param_keys=("values", "idx"))
        assert spec.name != "compressed_pallas"

    def test_frozen_db_selection_deterministic_under_quarantine(self, db):
        """A frozen DB pins the winner; quarantining it degrades down the
        ladder deterministically (same answer every resolve)."""
        key = _small_key()
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name == "compressed_pallas"
        dispatch.quarantine(key.op, "compressed_pallas")
        names = {dispatch.best_impl(key, param_keys=("values", "idx")).name
                 for _ in range(5)}
        assert len(names) == 1 and "compressed_pallas" not in names


class TestRunGuarded:
    def test_injected_failure_degrades_with_identical_output(self, db):
        """Fail the resolved winner once via the dispatch.execute fault site:
        run_guarded quarantines it, re-resolves, and the degraded rung
        produces the same numbers the fallback produces when forced."""
        x, params = _problem()
        key = dispatch.linear_key_from(x.shape, params["values"].shape)
        winner = dispatch.best_impl(key, param_keys=("values", "idx"))
        with fault.fault_scope(f"dispatch.execute@{winner.name}:n=1") as plan:
            y = dispatch.run_guarded(key, winner,
                                     lambda s: s.apply(params, x),
                                     param_keys=("values", "idx"))
        assert plan.fired.get("dispatch.execute") == 1
        assert winner.name in dispatch.quarantined(key.op)
        fallback = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert fallback.name != winner.name
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(fallback.apply(params, x)),
            rtol=1e-5, atol=1e-5)
        # and the degraded result still matches the healthy winner
        dispatch.clear_quarantine()
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(winner.apply(params, x)),
            rtol=1e-4, atol=1e-4)

    def test_real_exception_also_quarantines(self, db):
        key = _small_key()
        winner = dispatch.best_impl(key, param_keys=("values", "idx"))
        boom = dataclasses.replace(
            winner, apply=lambda p, xx: (_ for _ in ()).throw(
                RuntimeError("kernel crashed")))
        x, params = _problem()
        y = dispatch.run_guarded(key, boom, lambda s: s.apply(params, x),
                                 param_keys=("values", "idx"))
        assert winner.name in dispatch.quarantined(key.op)
        assert np.asarray(y).shape == (8, 64)

    def test_raises_when_ladder_exhausted(self, db):
        x, params = _problem()
        key = dispatch.linear_key_from(x.shape, params["values"].shape)
        spec = dispatch.best_impl(key, param_keys=("values", "idx"))
        with fault.fault_scope("dispatch.execute:n=99"):
            with pytest.raises(fault.InjectedFault):
                dispatch.run_guarded(key, spec,
                                     lambda s: s.apply(params, x),
                                     param_keys=("values", "idx"))
        # every feasible candidate was tried and quarantined
        assert len(dispatch.quarantined(key.op)) >= 2

    def test_linear_apply_routes_through_guard(self, db):
        """The model-level entry point degrades transparently: a one-shot
        injected failure changes nothing about the layer's output."""
        x, params = _problem()
        y_ref = np.asarray(linear_apply(params, x))
        dispatch.clear_quarantine()
        key = dispatch.linear_key_from(x.shape, params["values"].shape)
        winner = dispatch.best_impl(key, param_keys=("values", "idx"))
        with fault.fault_scope(f"dispatch.execute@{winner.name}:n=1"):
            y = np.asarray(linear_apply(params, x))
        assert winner.name in dispatch.quarantined(key.op)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


class TestQuarantineTTL:
    """Quarantine entries expire: after the TTL the impl rejoins the
    candidate space on *probation* — a clean guarded run deletes the entry,
    a failed re-probe re-quarantines with exponentially longer TTL."""

    @pytest.fixture
    def clock(self, monkeypatch):
        from repro.dispatch import dispatch as dmod
        t = [100.0]
        monkeypatch.setattr(dmod, "_now", lambda: t[0])
        monkeypatch.setenv("REPRO_DISPATCH_QUARANTINE_TTL_S", "10")
        return t

    def test_expired_entry_rejoins_candidate_space(self, db, clock):
        key = _small_key()
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        dispatch.quarantine(key.op, "compressed_pallas", reason="crash")
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name != "compressed_pallas"
        clock[0] += 10.0
        # TTL elapsed: the entry moves to probation and the DB-pinned winner
        # is eligible (and selected) again
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name == "compressed_pallas"
        info = dispatch.quarantine_info(key.op, "compressed_pallas")
        assert info["probation"] and info["fails"] == 1
        assert info["reason"] == "crash"
        # probation entries are no longer listed as quarantined
        assert dispatch.quarantined(key.op) == frozenset()

    def test_guarded_success_clears_entry(self, db, clock):
        x, params = _problem()
        key = dispatch.linear_key_from(x.shape, params["values"].shape)
        winner = dispatch.best_impl(key, param_keys=("values", "idx"))
        with fault.fault_scope(f"dispatch.execute@{winner.name}:n=1"):
            dispatch.run_guarded(key, winner, lambda s: s.apply(params, x),
                                 param_keys=("values", "idx"))
        assert winner.name in dispatch.quarantined(key.op)
        clock[0] += 10.0
        spec = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert spec.name == winner.name  # probation re-probe
        y = dispatch.run_guarded(key, spec, lambda s: s.apply(params, x),
                                 param_keys=("values", "idx"))
        # clean probe: fully recovered, the entry is gone
        assert dispatch.quarantine_info(key.op, winner.name) is None
        assert dispatch.quarantined(key.op) == frozenset()
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(winner.apply(params, x)),
            rtol=1e-5, atol=1e-5)

    def test_failed_reprobe_requarantines_with_backoff(self, db, clock):
        x, params = _problem()
        key = dispatch.linear_key_from(x.shape, params["values"].shape)
        winner = dispatch.best_impl(key, param_keys=("values", "idx"))
        with fault.fault_scope(f"dispatch.execute@{winner.name}:n=1"):
            dispatch.run_guarded(key, winner, lambda s: s.apply(params, x),
                                 param_keys=("values", "idx"))
        assert dispatch.quarantine_info(key.op, winner.name)["fails"] == 1
        clock[0] += 10.0
        spec = dispatch.best_impl(key, param_keys=("values", "idx"))
        assert spec.name == winner.name
        # the re-probe fails too: re-quarantined, TTL doubled (10 -> 20)
        with fault.fault_scope(f"dispatch.execute@{winner.name}:n=1"):
            dispatch.run_guarded(key, spec, lambda s: s.apply(params, x),
                                 param_keys=("values", "idx"))
        info = dispatch.quarantine_info(key.op, winner.name)
        assert info["fails"] == 2 and not info["probation"]
        assert info["until"] == pytest.approx(clock[0] + 20.0)
        # still degraded after the BASE ttl (backoff in effect) ...
        clock[0] += 10.0
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name != winner.name
        # ... eligible again only after the doubled ttl
        clock[0] += 10.0
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name == winner.name

    def test_nonpositive_ttl_disables_expiry(self, db, clock, monkeypatch):
        monkeypatch.setenv("REPRO_DISPATCH_QUARANTINE_TTL_S", "0")
        key = _small_key()
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        dispatch.quarantine(key.op, "compressed_pallas")
        clock[0] += 1e9
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name != "compressed_pallas"
        assert "compressed_pallas" in dispatch.quarantined(key.op)


class TestProcessLocality:
    def test_quarantine_not_persisted_to_db(self, db):
        """Quarantine is a runtime denylist, not a profiling verdict: the
        profile DB on disk is unchanged by it, so a restart re-trusts the
        profiled winner (the failure may have been transient)."""
        key = _small_key()
        db.put(key.token, {"impl": "compressed_pallas", "wall_us": 1.0})
        before = dict(db.get(key.token))
        dispatch.quarantine(key.op, "compressed_pallas", reason="crash")
        assert dispatch.best_impl(
            key, param_keys=("values", "idx")).name != "compressed_pallas"
        assert dict(db.get(key.token)) == before

    def test_fresh_process_starts_unquarantined(self, db):
        dispatch.quarantine("linear", "compressed_pallas")
        db.put(_small_key().token, {"impl": "compressed_pallas",
                                    "wall_us": 1.0})
        snippet = (
            "from repro import dispatch\n"
            "key = dispatch.linear_key(batch=8, d_in=64, d_out=64, "
            "k_kept=32, tile=16)\n"
            "assert dispatch.quarantined() == frozenset()\n"
            "print(dispatch.best_impl(key, "
            "param_keys=('values','idx')).name)\n")
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(str(REPO), "src"),
                   REPRO_DISPATCH_DB=str(db.path))
        r = subprocess.run([sys.executable, "-c", snippet], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr
        # the restarted process re-trusts the DB-pinned winner
        assert r.stdout.strip() == "compressed_pallas"
