"""Integrity-verified checkpointing: the manifest commit marker, crc
verification, corruption fallback, async-failure propagation, tmp GC, and
dtype discipline of ``repro.train.checkpoint``."""
import json
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import fault
from repro.train.checkpoint import (
    ARRAYS,
    MANIFEST,
    META,
    CheckpointError,
    CheckpointManager,
)


def _tree():
    """Mixed-dtype pytree covering the formats a pruned model checkpoints:
    f32 weights, bf16 activations-scale, int32 packed indices, bool masks."""
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7.0,
        "scale": jnp.full((4, 2), 0.375, dtype=jnp.bfloat16),
        "idx": jnp.arange(8, dtype=jnp.int32).reshape(2, 4),
        "mask": jnp.array([True, False, True, True]),
    }


def _leaves_bitwise_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        assert x.shape == y.shape
        assert x.tobytes() == y.tobytes()


def _truncate(d, frac=0.5):
    f = d / ARRAYS
    data = f.read_bytes()
    f.write_bytes(data[: int(len(data) * frac)])


class TestManifest:
    def test_manifest_is_complete_commit_record(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(3, {"params": _tree()})
        d = mgr.dir / "step_00000003"
        man = json.loads((d / MANIFEST).read_text())
        assert man["step"] == 3
        assert man["arrays_bytes"] == (d / ARRAYS).stat().st_size
        assert set(man["arrays"]) == {
            "params|['w']", "params|['scale']", "params|['idx']",
            "params|['mask']"}
        ent = man["arrays"]["params|['idx']"]
        assert ent["dtype"] == "int32" and ent["shape"] == [2, 4]
        want = zlib.crc32(np.arange(8, dtype=np.int32).tobytes())
        assert ent["crc32"] == want

    def test_mixed_dtype_bitwise_roundtrip(self, tmp_path):
        """bf16 survives the npz void-record round trip, ints and bools keep
        their dtypes — every leaf restores bitwise identical."""
        mgr = CheckpointManager(tmp_path)
        tree = _tree()
        mgr.save(1, {"params": tree}, metadata={"step": 1})
        out, meta = mgr.restore(None, {"params": tree})
        assert meta["step"] == 1
        _leaves_bitwise_equal(tree, out["params"])

    def test_pruned_vision_tree_roundtrip(self, tmp_path):
        """A real pruned-model tree (masked convs with bool masks, dense stem,
        head) round-trips bitwise through save/restore."""
        from repro.configs import get_vision_config
        from repro.core.sparse_linear import unbox_tree
        from repro.models import vision

        cfg = get_vision_config("resnet-tiny")
        params, _ = unbox_tree(vision.vision_init(cfg, jax.random.PRNGKey(0)))
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"params": params})
        out, _ = mgr.restore(1, {"params": params})
        _leaves_bitwise_equal(params, out["params"])

    def test_dtype_mismatch_requires_explicit_cast(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"params": {"a": jnp.ones((2, 2), dtype=jnp.float32)}})
        proto = {"a": jnp.zeros((2, 2), dtype=jnp.bfloat16)}
        with pytest.raises(ValueError, match="dtype mismatch"):
            mgr.restore(None, {"params": proto})
        out, _ = mgr.restore(None, {"params": proto}, cast=True)
        assert np.asarray(out["params"]["a"]).dtype == np.dtype("bfloat16")


class TestCorruptionFallback:
    def test_truncated_newest_falls_back_to_valid(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        t1, t2 = _tree(), jax.tree_util.tree_map(lambda v: v + 1, _tree())
        mgr.save(1, {"params": t1}, metadata={"tag": "one"})
        mgr.save(2, {"params": t2}, metadata={"tag": "two"})
        _truncate(mgr.dir / "step_00000002")
        assert mgr.latest_step() == 1
        out, meta = mgr.restore(None, {"params": t1})
        assert meta["tag"] == "one"
        _leaves_bitwise_equal(t1, out["params"])
        # an EXPLICIT request for the torn step is an error, not a fallback
        with pytest.raises(CheckpointError, match="bytes"):
            mgr.restore(2, {"params": t2})

    @pytest.mark.parametrize("frac", [0.0, 0.25, 0.6, 0.95])
    def test_truncation_fuzz_always_detected(self, tmp_path, frac):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"params": _tree()})
        d = mgr.dir / "step_00000001"
        _truncate(d, frac=frac)
        assert mgr.validate(d) is not None
        assert mgr.latest_step() is None

    def test_missing_meta_invalidates(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"params": _tree()})
        d = mgr.dir / "step_00000001"
        (d / META).unlink()
        assert mgr.validate(d) == "missing meta.json"
        assert mgr.latest_step() is None

    def test_missing_manifest_is_uncommitted(self, tmp_path):
        """No manifest == the writer died before the commit marker: the
        directory is invisible to latest_step/restore."""
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"params": _tree()})
        (mgr.dir / "step_00000001" / MANIFEST).unlink()
        assert mgr.latest_step() is None
        with pytest.raises(CheckpointError, match="no valid checkpoint"):
            mgr.restore(None, {"params": _tree()})

    def test_bit_rot_caught_by_deep_check(self, tmp_path):
        """Same-size corruption passes the shallow size check but fails the
        deep (crc) one; restore(None) skips to the older valid step."""
        mgr = CheckpointManager(tmp_path)
        t1 = _tree()
        mgr.save(1, {"params": t1}, metadata={"tag": "good"})
        mgr.save(2, {"params": t1}, metadata={"tag": "rot"})
        f = mgr.dir / "step_00000002" / ARRAYS
        data = bytearray(f.read_bytes())
        mid = len(data) // 2
        data[mid] ^= 0xFF
        data[mid + 1] ^= 0xFF
        f.write_bytes(bytes(data))
        d = mgr.dir / "step_00000002"
        assert mgr.validate(d) is None          # shallow: size still matches
        assert mgr.validate(d, deep=True) is not None
        out, meta = mgr.restore(None, {"params": t1})
        assert meta["tag"] == "good"
        _leaves_bitwise_equal(t1, out["params"])

    def test_all_invalid_raises_with_reasons(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"params": _tree()})
        _truncate(mgr.dir / "step_00000001", frac=0.3)
        with pytest.raises(CheckpointError, match="skipped"):
            mgr.restore(None, {"params": _tree()})

    def test_empty_dir_raises_file_not_found(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        with pytest.raises(FileNotFoundError):
            mgr.restore(None, {"params": _tree()})
        with pytest.raises(FileNotFoundError):
            mgr.restore(7, {"params": _tree()})


class TestGC:
    def test_orphan_tmp_gc_at_init(self, tmp_path):
        orphan = tmp_path / "tmp.5.12345"
        orphan.mkdir(parents=True)
        (orphan / ARRAYS).write_bytes(b"partial write")
        CheckpointManager(tmp_path)
        assert not orphan.exists()

    def test_keep_gc_counts_only_valid(self, tmp_path):
        """An invalid directory neither counts against `keep` nor shields a
        valid one: corrupt step 4, save step 5 with keep=2 — steps 3 and 5
        survive as the two newest VALID checkpoints."""
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"params": _tree()})
        _truncate(mgr.dir / "step_00000004")
        mgr.save(5, {"params": _tree()})
        names = sorted(p.name for p in mgr.dir.glob("step_*"))
        assert names == ["step_00000003", "step_00000004", "step_00000005"]
        assert mgr.valid_steps() == [5, 3]
        assert mgr.latest_step() == 5


class TestAsyncFailure:
    def test_write_fault_surfaces_on_wait(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        with fault.fault_scope("ckpt.write:n=1"):
            mgr.save(1, {"params": _tree()}, blocking=False)
            with pytest.raises(fault.InjectedFault):
                mgr.wait()
        assert mgr.latest_step() is None
        # the failure was consumed: the manager is reusable
        mgr.save(2, {"params": _tree()})
        assert mgr.latest_step() == 2

    def test_write_fault_surfaces_on_next_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        with fault.fault_scope("ckpt.write:n=1"):
            mgr.save(1, {"params": _tree()}, blocking=False)
            mgr._thread.join()
        with pytest.raises(fault.InjectedFault):
            mgr.save(2, {"params": _tree()})
        mgr.save(2, {"params": _tree()})
        assert mgr.latest_step() == 2

    def test_rename_fault_never_commits(self, tmp_path):
        """A writer killed between the manifest write and the atomic rename
        leaves only a tmp.* orphan — no step dir, and the orphan is GC'd by
        the next manager (a restarted trainer)."""
        mgr = CheckpointManager(tmp_path)
        with fault.fault_scope("ckpt.rename:n=1"):
            with pytest.raises(fault.InjectedFault):
                mgr.save(1, {"params": _tree()})
        assert list(mgr.dir.glob("step_*")) == []
        assert list(mgr.dir.glob("tmp.*")) != []
        mgr2 = CheckpointManager(tmp_path)
        assert list(mgr2.dir.glob("tmp.*")) == []
        assert mgr2.latest_step() is None
