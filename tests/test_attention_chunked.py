"""Chunked (flash-style) attention vs the naive oracle, incl. GQA ratios,
causal masks, kv_len masks, ragged chunk boundaries, and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import sdpa_gqa, sdpa_gqa_chunked


def mk(b, sq, sk, h, kvh, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, kvh, d))
    v = jax.random.normal(ks[2], (b, sk, kvh, d))
    return q, k, v


class TestChunkedAttention:
    @pytest.mark.parametrize(
        "b,sq,sk,h,kvh,d,chunk,causal",
        [
            (2, 16, 16, 4, 2, 8, 4, True),
            (2, 16, 16, 4, 2, 8, 16, True),     # single chunk
            (1, 8, 24, 4, 4, 8, 7, False),      # ragged chunks, MHA
            (2, 12, 12, 6, 2, 8, 5, True),      # ragged + GQA 3:1
            (1, 8, 8, 5, 2, 8, 4, True),        # h % kvh != 0 (mapped)
        ],
    )
    def test_matches_naive(self, b, sq, sk, h, kvh, d, chunk, causal):
        q, k, v = mk(b, sq, sk, h, kvh, d)
        ref = sdpa_gqa(q, k, v, causal=causal)
        out = sdpa_gqa_chunked(q, k, v, causal=causal, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_kv_len_mask(self):
        q, k, v = mk(2, 1, 32, 4, 2, 8)
        kv_len = jnp.asarray([5, 17])
        ref = sdpa_gqa(q, k, v, causal=False, kv_len=kv_len)
        out = sdpa_gqa_chunked(q, k, v, causal=False, kv_len=kv_len, chunk=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_gradients_match(self):
        q, k, v = mk(1, 8, 8, 2, 2, 4)

        def loss_naive(q, k, v):
            return jnp.sum(jnp.tanh(sdpa_gqa(q, k, v, causal=True)))

        def loss_chunk(q, k, v):
            return jnp.sum(jnp.tanh(sdpa_gqa_chunked(q, k, v, causal=True, chunk=3)))

        g_ref = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
        g_out = jax.grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
        for a, b2 in zip(g_out, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b2), rtol=1e-4, atol=1e-4)

    def test_q_offset_decode_window(self):
        # causal with q_offset: queries sit at absolute positions offset+i
        q, k, v = mk(1, 4, 16, 2, 2, 4)
        ref = sdpa_gqa(q, k, v, causal=True, q_offset=12)
        out = sdpa_gqa_chunked(q, k, v, causal=True, q_offset=12, chunk=5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_model_level_equivalence(self):
        from repro.configs import smoke_config
        from repro.models import registry as reg

        cfg_n = smoke_config("qwen2-7b").with_(attn_impl="naive")
        cfg_c = cfg_n.with_(attn_impl="chunked", attn_chunk=8)
        params, _ = reg.init_params(cfg_n, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                              cfg_n.vocab_size)}
        ln = reg.loss_fn(cfg_n)(params, batch)[0]
        lc = reg.loss_fn(cfg_c)(params, batch)[0]
        np.testing.assert_allclose(float(ln), float(lc), rtol=1e-5)
