"""End-to-end behaviour tests: the paper's full workflow + the framework's
train→checkpoint→restore→serve loop on a reduced config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.data import DataConfig
from repro.models import registry as reg
from repro.optim import AdamWConfig
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, Trainer


def test_train_checkpoint_serve_roundtrip(tmp_path):
    """Train a sparse (column-wise compressed) LM, checkpoint, restore in a
    fresh trainer, and serve generations from the restored params."""
    scfg = SparsityConfig(sparsity=0.5, m=None, tile=32,
                          format="compressed_xla", min_dim=64)
    cfg = smoke_config("qwen2-0.5b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, sparsity=scfg)
    dcfg = DataConfig(vocab_size=256, batch=8, seq_len=32, seed=3)
    tcfg = TrainConfig(steps=6, ckpt_dir=str(tmp_path), ckpt_every=3, log_every=1)
    tr = Trainer(cfg, dcfg, AdamWConfig(lr=1e-3), tcfg)
    out = tr.run()
    assert out["final_step"] == 6
    # params contain the compressed format (idx int leaves survive training)
    leaves = jax.tree_util.tree_flatten_with_path(tr.params)[0]
    assert any("idx" in jax.tree_util.keystr(p) for p, _ in leaves)

    tr2 = Trainer(cfg, dcfg, AdamWConfig(lr=1e-3), tcfg)
    assert tr2.maybe_restore() == 6
    for a, b in zip(jax.tree_util.tree_leaves(tr.params),
                    jax.tree_util.tree_leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    eng = Engine(cfg, tr2.params, ServeConfig(max_new_tokens=5))
    res = eng.generate(np.ones((2, 4), np.int32))
    assert res["tokens"].shape == (2, 5)
    assert (res["tokens"] < cfg.vocab_size).all()


def test_sparse_model_forward_finite_and_compressed():
    """A model initialized in compressed format runs and actually stores the
    compressed representation (paper Fig. 1: values + index array)."""
    scfg = SparsityConfig(sparsity=0.5, m=None, tile=16,
                          format="compressed_xla", min_dim=32)
    cfg = smoke_config("smollm-360m").with_(
        n_layers=1, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=96, vocab_size=128, tie_embeddings=False, sparsity=scfg)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)}
    logits = reg.forward_fn(cfg)(params, batch)
    assert bool(jnp.isfinite(logits).all())
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    vals = [l for p, l in flat if "values" in jax.tree_util.keystr(p)]
    assert vals, "compressed layers present"


def test_sparsity_reduces_flops():
    """Compiled HLO FLOPs scale with (1 - sparsity) on the prunable body —
    the MXU-realizable saving the TPU adaptation is built around."""
    from repro.roofline.hlo_analyzer import analyze_hlo

    def flops_at(s):
        scfg = SparsityConfig(sparsity=s, m=None, tile=None,
                              format="compressed_xla" if s else "dense",
                              min_dim=32)
        cfg = smoke_config("qwen2-7b").with_(
            n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
            d_ff=512, vocab_size=128, sparsity=scfg)
        params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
        fwd = reg.forward_fn(cfg)
        txt = jax.jit(fwd).lower(params, batch).compile().as_text()
        return analyze_hlo(txt)["flops"]

    f0, f50, f75 = flops_at(0.0), flops_at(0.5), flops_at(0.75)
    assert f50 < 0.75 * f0, f"50% sparsity should cut >25% of FLOPs: {f50/f0:.2f}"
    assert f75 < f50, "75% < 50%"


def test_elastic_restart_different_batch(tmp_path):
    """Checkpoints are topology/batch independent: restore into a trainer
    with a different data-parallel batch (elastic restart)."""
    cfg = smoke_config("smollm-360m").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
        d_ff=64, vocab_size=128)
    d1 = DataConfig(vocab_size=128, batch=8, seq_len=16, seed=1)
    t1 = Trainer(cfg, d1, AdamWConfig(lr=1e-3),
                 TrainConfig(steps=4, ckpt_dir=str(tmp_path), ckpt_every=2))
    t1.run()
    # "scale down" to batch=4 (different topology), resume to a larger
    # total budget (steps counts from 0, restored progress included)
    d2 = DataConfig(vocab_size=128, batch=4, seq_len=16, seed=1)
    t2 = Trainer(cfg, d2, AdamWConfig(lr=1e-3),
                 TrainConfig(steps=6, ckpt_dir=str(tmp_path), ckpt_every=10))
    step = t2.maybe_restore()
    assert step == 4
    out = t2.run()
    assert out["final_step"] == 6
    assert out["start_step"] == 4
