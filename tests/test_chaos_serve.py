"""Seeded chaos suite for the fault-tolerant serving runtime.

Covers the fault-injection registry itself (grammar, determinism, scoping),
the request lifecycle (deadline, cancel, drain, preempt-restore), the
EOS-early page-stranding accounting, and randomized fault schedules over both
KV tiers — asserting the invariants the robustness work promises: every
request ends at exactly one terminal status, no page or slot leaks, and
fault-free requests are token-identical to a no-fault run.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import fault
from repro.configs import smoke_config
from repro.core.pruning import SparsityConfig
from repro.models import registry as reg
from repro.serve import (
    STATUSES,
    Engine,
    PagePool,
    Request,
    Scheduler,
    ServeConfig,
)

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# Fault-injection registry (no engine needed)
# ---------------------------------------------------------------------------


class TestFaultRegistry:
    def test_off_by_default_and_zero_cost_path(self):
        assert not fault.enabled()
        assert fault.plan() is None
        fault.maybe_fail("page_pool.alloc", seq=0)  # no-op, no plan

    def test_parse_grammar(self):
        p = fault.parse_spec(
            "page_pool.alloc:iter=3, dispatch.execute@compressed_xla:n=2,"
            "scheduler.iter:p=0.25")
        assert len(p.rules) == 3
        assert p.rules[0].iters == frozenset({3})
        assert p.rules[1].match == "compressed_xla" and p.rules[1].n == 2
        assert p.rules[2].p == 0.25

    @pytest.mark.parametrize("bad", [
        "page_pool.alloc", "site:", "site:iter=x", "site:p=1.5",
        "site:frob=1", "@m:n=1",
    ])
    def test_parse_rejects(self, bad):
        with pytest.raises(ValueError):
            fault.parse_spec(bad)

    def test_iter_rule_fires_exactly_kth_probe(self):
        with fault.fault_scope("scheduler.iter:iter=2") as plan:
            fault.maybe_fail("scheduler.iter")
            fault.maybe_fail("scheduler.iter")
            with pytest.raises(fault.InjectedFault) as ei:
                fault.maybe_fail("scheduler.iter")
            assert ei.value.site == "scheduler.iter" and ei.value.hit == 1
            fault.maybe_fail("scheduler.iter")  # past K: never again
        assert plan.probes["scheduler.iter"] == 4
        assert plan.fired["scheduler.iter"] == 1

    def test_n_rule_fires_first_k(self):
        with fault.fault_scope("page_pool.alloc:n=2") as plan:
            for _ in range(2):
                with pytest.raises(fault.InjectedFault):
                    fault.maybe_fail("page_pool.alloc")
            fault.maybe_fail("page_pool.alloc")
        assert plan.fired["page_pool.alloc"] == 2

    def test_match_filters_on_ctx_values(self):
        with fault.fault_scope("dispatch.execute@pallas:n=9") as plan:
            fault.maybe_fail("dispatch.execute", impl="xla")
            with pytest.raises(fault.InjectedFault):
                fault.maybe_fail("dispatch.execute", impl="pallas")
        assert plan.fired["dispatch.execute"] == 1

    def test_p_rule_deterministic_under_seed(self):
        def firing(seed):
            fired = []
            with fault.fault_scope("scheduler.iter:p=0.5", seed=seed):
                for i in range(32):
                    try:
                        fault.maybe_fail("scheduler.iter", it=i)
                        fired.append(False)
                    except fault.InjectedFault:
                        fired.append(True)
            return fired

        a, b = firing(7), firing(7)
        assert a == b and any(a) and not all(a)

    def test_scope_restores_previous_state(self):
        outer = fault.install("scheduler.iter:n=1")
        try:
            with fault.fault_scope("page_pool.alloc:n=1"):
                assert fault.plan().spec == "page_pool.alloc:n=1"
            assert fault.plan() is outer
        finally:
            fault.uninstall()
        assert not fault.enabled()

    def test_configure_from_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULTS", "scheduler.iter:n=1")
        monkeypatch.setenv("REPRO_FAULTS_SEED", "3")
        p = fault.configure()
        assert fault.enabled() and p.seed == 3
        monkeypatch.delenv("REPRO_FAULTS")
        fault.configure()
        assert not fault.enabled()


# ---------------------------------------------------------------------------
# PagePool: injected exhaustion + reservation release
# ---------------------------------------------------------------------------


class TestPagePoolFaults:
    def test_alloc_fault_leaves_pool_unmutated(self):
        pool = PagePool(n_pages=4, page_size=4)
        with fault.fault_scope("page_pool.alloc:n=1"):
            with pytest.raises(fault.InjectedFault):
                pool.alloc(0, 8)
        assert pool.n_free == 4 and pool.n_seqs == 0
        pool.alloc(0, 8)  # recovers normally once the schedule is spent
        pool.check_invariants()

    def test_grow_fault_only_when_claiming_pages(self):
        pool = PagePool(n_pages=4, page_size=4)
        pool.alloc(0, 4)
        with fault.fault_scope("page_pool.alloc@grow:n=1") as plan:
            pool.grow(0, 3)  # within the mapped page: no probe
            assert plan.fired.get("page_pool.alloc") is None
            with pytest.raises(fault.InjectedFault):
                pool.grow(0, 5)  # needs a second page -> probes
        pool.check_invariants()

    def test_release_unused_returns_reserved_tail(self):
        pool = PagePool(n_pages=8, page_size=4)
        pool.alloc(0, 24)  # 6 pages reserved
        pool.advance(0, 6)  # ... but only 6 rows (2 pages) ever written
        assert pool.release_unused(0) == 4
        assert pool.n_free == 6
        assert pool.table(0).capacity == 8
        assert pool.release_unused(0) == 0  # idempotent
        pool.free(0)
        assert pool.n_free == 8
        pool.check_invariants()


# ---------------------------------------------------------------------------
# Scheduler lifecycle + chaos (engine-backed)
# ---------------------------------------------------------------------------


def _smoke_cfg(arch="smollm-360m", sparsity=0.5):
    scfg = SparsityConfig(sparsity=sparsity, m=None, tile=None,
                          format="compressed_xla", min_dim=64)
    return smoke_config(arch).with_(sparsity=scfg)


@pytest.fixture(scope="module")
def engine():
    cfg = _smoke_cfg()
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_new_tokens=16))


@pytest.fixture(autouse=True)
def _no_leftover_faults():
    yield
    fault.uninstall()


def _trace(engine, n, *, prompt=6, budget=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, engine.cfg.vocab_size,
                                        (prompt,)).astype(np.int32),
                    max_new_tokens=budget, **kw)
            for i in range(n)]


def _by_uid(completions):
    return {c.uid: c for c in completions}


class TestLifecycle:
    def test_deadline_expires_queued_and_inflight(self, engine):
        reqs = _trace(engine, 4)
        reqs[3].deadline_s = 1e-6  # expired before it can ever admit
        sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=16)
        comps = _by_uid(sched.run(reqs))
        assert comps[3].status == "timeout" and comps[3].n_generated == 0
        assert all(comps[u].status == "ok" for u in (0, 1, 2))
        assert sched.stats["retired_timeout"] == 1
        assert sched.stats["retired_ok"] == 3

    def test_cancel_queued_request(self, engine):
        sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=16)
        sched.cancel(2)
        comps = _by_uid(sched.run(_trace(engine, 4)))
        assert comps[2].status == "cancelled"
        assert sum(1 for c in comps.values() if c.status == "ok") == 3

    def test_cancel_inflight_midrun(self, engine):
        sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=24)
        gen = sched.run_iter(_trace(engine, 2, budget=12))
        # cancel uid 0 after the run has started (both are in flight)
        sched.cancel(0)
        comps = _by_uid(list(gen))
        assert comps[0].status == "cancelled"
        assert comps[0].n_generated < 12  # cut short, partial tokens kept
        assert comps[1].status == "ok"

    def test_drain_finishes_inflight_flushes_queue(self, engine):
        sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=16)
        draining = {"on": False}
        gen = sched.run_iter(_trace(engine, 6, budget=8),
                             should_drain=lambda: draining["on"])
        first = next(gen)
        draining["on"] = True
        rest = list(gen)
        comps = _by_uid([first] + rest)
        assert len(comps) == 6  # every request reached a terminal status
        ok = [u for u, c in comps.items() if c.status == "ok"]
        flushed = [u for u, c in comps.items() if c.status == "cancelled"]
        assert flushed and ok  # some drained away, in-flight ones finished
        assert sched.stats["retired_cancelled"] == len(flushed)

    def test_heartbeat_called_every_iteration(self, engine):
        beats = []
        sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=16)
        sched.run(_trace(engine, 2), heartbeat=lambda: beats.append(1))
        assert len(beats) >= sched.stats["decode_steps"] >= 1


class TestPreemptRestore:
    def test_grow_preempts_and_restores_token_identical(self, engine):
        baseline = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                             max_len=16)
        want = _by_uid(baseline.run(_trace(engine, 4)))
        # 16-row budget = 4 pages for 2 slots of growing sequences: forces
        # real exhaustion-driven preemption
        tight = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=16, kv_budget_rows=16, alloc="grow")
        got = _by_uid(tight.run(_trace(engine, 4)))
        assert tight.stats["preemptions"] >= 1
        assert all(c.status == "ok" for c in got.values())
        for uid, c in want.items():
            np.testing.assert_array_equal(got[uid].tokens, c.tokens)

    def test_injected_exhaustion_preempts(self, engine):
        sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=16, alloc="grow")
        with fault.fault_scope("page_pool.alloc@grow:iter=2"):
            comps = _by_uid(sched.run(_trace(engine, 3)))
        assert sched.stats["preemptions"] >= 1
        assert all(c.status == "ok" for c in comps.values())

    def test_restore_budget_exhausts_to_failed(self, engine):
        sched = Scheduler(engine, n_slots=1, paged=True, page_size=4,
                          max_len=16, alloc="grow", max_restores=1)
        # every grow-time page claim fails: the only sequence preempts once
        # (restore #1), then hits the restore budget and fails terminally
        with fault.fault_scope("page_pool.alloc@grow:n=99"):
            comps = _by_uid(sched.run(_trace(engine, 1, prompt=3, budget=8)))
        assert comps[0].status == "failed"
        assert sched.stats["preemptions"] == 1


class TestEOSStranding:
    def _eos_from_free_run(self, engine, reqs):
        sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                          max_len=24)
        free = _by_uid(sched.run([Request(r.uid, r.prompt.copy(),
                                          r.max_new_tokens) for r in reqs]))
        # a token some request emits early: with eos set, that request
        # retires well inside its reserved budget
        return int(free[0].tokens[1]), free

    def test_reserve_strands_grow_does_not(self, engine):
        reqs = _trace(engine, 4, prompt=4, budget=16)
        eos, _free = self._eos_from_free_run(engine, reqs)
        engine.scfg.eos_id = eos
        try:
            reserve = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                                max_len=24)
            r_comps = reserve.run(_trace(engine, 4, prompt=4, budget=16))
            grow = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                            max_len=24, alloc="grow")
            g_comps = grow.run(_trace(engine, 4, prompt=4, budget=16))
        finally:
            engine.scfg.eos_id = None
        assert any(c.n_generated < c.tokens.shape[0] or
                   c.n_generated < 16 for c in r_comps)  # EOS fired early
        # reserve measured the unused reservation; grow never created one
        assert reserve.page_stats["pages_stranded"] > 0
        assert grow.page_stats["pages_stranded"] == 0
        # grow maps pages only as decode reaches them, so its footprint
        # never exceeds reserve's upfront worst case (it ties only when
        # every live request runs its full budget anyway)
        assert grow.page_stats["pages_peak"] <= \
            reserve.page_stats["pages_peak"]
        # identical generations either way
        for a, b in zip(sorted(r_comps, key=lambda c: c.uid),
                        sorted(g_comps, key=lambda c: c.uid)):
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_invariant_no_pages_leak_after_eos_early_run(self, engine):
        reqs = _trace(engine, 4, prompt=4, budget=16)
        eos, _ = self._eos_from_free_run(engine, reqs)
        engine.scfg.eos_id = eos
        try:
            sched = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                              max_len=24)
            sched.run(_trace(engine, 4, prompt=4, budget=16))
        finally:
            engine.scfg.eos_id = None
        # run_iter's final check_invariants already ran; the gauges must
        # show an empty pool (nothing still mapped after all retires)
        assert sched.page_stats["pages_active"] == 0


class TestChaos:
    """Randomized seeded fault schedules over both KV tiers."""

    SPEC = "page_pool.alloc:p=0.25,scheduler.iter:p=0.15"

    def _run(self, engine, *, seed, paged, alloc="reserve"):
        kw = dict(paged=paged, max_len=16)
        if paged:
            kw.update(page_size=4, alloc=alloc)
        sched = Scheduler(engine, n_slots=2, **kw)
        with fault.fault_scope(self.SPEC, seed=seed) as plan:
            comps = sched.run(_trace(engine, 6))
        return sched, comps, plan

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("mode", ["contiguous", "reserve", "grow"])
    def test_all_terminal_no_leaks_survivors_identical(self, engine, seed,
                                                       mode):
        baseline = Scheduler(engine, n_slots=2, paged=True, page_size=4,
                             max_len=16)
        want = _by_uid(baseline.run(_trace(engine, 6)))
        sched, comps, plan = self._run(
            engine, seed=seed, paged=mode != "contiguous",
            alloc="grow" if mode == "grow" else "reserve")
        by_uid = _by_uid(comps)
        # 1. every request reached exactly one terminal status
        assert sorted(by_uid) == list(range(6))
        assert all(c.status in STATUSES for c in comps)
        stats = sched.stats
        assert sum(stats[f"retired_{s}"] for s in STATUSES) == 6
        # 2. no page/slot leaks (run_iter's end-of-run check_invariants
        #    already threw if the free/mapped partition broke)
        if mode != "contiguous":
            assert sched.page_stats["pages_active"] == 0
        # 3. fault-free survivors are token-identical to the no-fault run
        for uid, c in by_uid.items():
            if c.status == "ok":
                np.testing.assert_array_equal(
                    c.tokens, want[uid].tokens,
                    err_msg=f"uid {uid} diverged under chaos (seed {seed})")

    def test_chaos_is_replayable(self, engine):
        """Same spec + same seed -> bit-identical statuses and counters."""
        runs = []
        for _ in range(2):
            sched, comps, plan = self._run(engine, seed=5, paged=True)
            runs.append((
                tuple((c.uid, c.status, tuple(c.tokens.tolist()))
                      for c in sorted(comps, key=lambda c: c.uid)),
                dict(plan.fired)))
        assert runs[0] == runs[1]

    def test_decode_unservable_fails_inflight_not_wedges(self, engine):
        """Exhausting the paged-attention ladder at decode trace time must
        terminally fail the in-flight requests, not hang the loop or leak."""
        from repro import dispatch

        sched = Scheduler(engine, n_slots=3, paged=True, page_size=4,
                          max_len=16)
        try:
            with fault.fault_scope("kernel.paged_attn@decode:n=99"):
                comps = _by_uid(sched.run(_trace(engine, 3, budget=4)))
        finally:
            dispatch.clear_quarantine()
        assert all(c.status == "failed" for c in comps.values())
        assert sched.page_stats["pages_active"] == 0


class TestSigtermDrain:
    def test_launcher_drains_on_sigterm(self):
        """End-to-end: SIGTERM mid-serve finishes in-flight requests and
        flushes the queue with terminal statuses instead of dying."""
        import signal

        env = dict(os.environ, PYTHONPATH=os.path.join(str(REPO), "src"))
        # bare --trace prints the per-request event log, so the test can
        # signal as soon as the FIRST admission lands (requests are sized so
        # most of the trace is still queued at that point)
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "smollm-360m", "--smoke", "--continuous", "--paged",
             "--page-size", "4", "--requests", "64", "--slots", "2",
             "--new-tokens", "24", "--trace",
             "--faults", "scheduler.iter:iter=0"],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True)
        lines = []
        try:
            for line in proc.stdout:
                lines.append(line)
                if "[admit]" in line:
                    proc.send_signal(signal.SIGTERM)
                    break
            out, _ = proc.communicate(timeout=500)
        except Exception:
            proc.kill()
            raise
        out = "".join(lines) + (out or "")
        assert proc.returncode == 0, out
        assert "[drain]" in out, out
        assert "cancelled=" in out and "[drained]" in out, out
        assert "status:" in out, out
