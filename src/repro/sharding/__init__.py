from repro.sharding.api import (  # noqa: F401
    RULES,
    ShardingCtx,
    get_ctx,
    logical_constraint,
    resolve_spec,
    set_ctx,
    shd,
    specs_to_shardings,
    use_ctx,
)
