"""Ring collective matmul: overlap the tensor-parallel all-gather with the
matmul it feeds (beyond-paper distributed optimization, DESIGN §5).

Standard TP computes ``y = all_gather(x) @ W_shard`` — the gather must finish
before the MXU starts.  The ring formulation keeps x sharded, multiplies the
resident shard while ppermute-ing the next shard around the ring, so
communication hides behind compute (Wang et al., "Overlap communication with
dependent computation", and the classic Cannon/SUMMA trick):

  for step in 0..n-1:
      y += x_shard @ W[block owned at this step]
      x_shard <- ppermute(x_shard)

Used inside shard_map; numerically identical to the gather-then-matmul path
(tests/test_collective_matmul.py runs it on 8 emulated devices).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def ring_allgather_matmul_local(x_shard: jax.Array, w_full: jax.Array,
                                axis_name: str) -> jax.Array:
    """Per-device body. x_shard: [B, d_in/n]; w_full: [d_in, d_out] (this
    device's full copy of its W — here W replicated for clarity; the block
    actually used rotates with the ring step). Returns [B, d_out] = x @ W.
    """
    n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)
    chunk = x_shard.shape[-1]

    def body(step, carry):
        acc, xs = carry
        # shard arriving at step k originated at device (me + k) mod n and
        # holds x columns [(me+k)%n * chunk : ...]
        src = (me + step) % n
        w_blk = jax.lax.dynamic_slice_in_dim(w_full, src * chunk, chunk, axis=0)
        acc = acc + xs @ w_blk
        xs = jax.lax.ppermute(
            xs, axis_name, perm=[(i, (i - 1) % n) for i in range(n)]
        )
        return acc, xs

    acc0 = jnp.zeros((x_shard.shape[0], w_full.shape[1]), x_shard.dtype)
    acc, _ = jax.lax.fori_loop(0, n, body, (acc0, x_shard))
    return acc


def ring_allgather_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                          axis: str = "model") -> jax.Array:
    """y = x @ w with x's feature dim sharded over `axis`, overlapping the
    gather with partial matmuls. x: [B, d_in]; w: [d_in, d_out]."""
    fn = shard_map(
        functools.partial(ring_allgather_matmul_local, axis_name=axis),
        mesh=mesh,
        in_specs=(P(None, axis), P(None, None)),
        out_specs=P(None, None),
        check_rep=False,
    )
    return fn(x, w)
