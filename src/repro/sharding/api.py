"""Logical-axis sharding: one rules table maps logical dimension names to
mesh axes; resolution checks divisibility per concrete dim so every arch in
the zoo (including awkward head counts) compiles on every mesh.

Model code never mentions mesh axes — it annotates logical names via ``shd``;
param trees carry logical specs in their Boxed leaves.  The launcher installs
a ``ShardingCtx``; with no context installed everything is a no-op (CPU unit
tests see single-device JAX).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Default logical->mesh rules. 'pod' appears only in the multi-pod mesh; axes
# missing from the mesh are dropped at resolution time.
RULES: Dict[str, Tuple[str, ...]] = {
    # --- parameters ---
    "embed": ("data",),          # FSDP: shard the replicated-capable dim over data
    "ffn": ("model",),           # tensor parallel
    "heads": ("model",),
    "kv_heads": ("model",),
    "heads_flat": ("model",),    # flattened H*head_dim projection output
    "kv_flat": ("model",),
    "embed2": (),                # aux embed-sized dims (e.g. zamba fuse output)
    "head_dim": (),
    "vocab": ("model",),
    "expert": ("model",),        # expert parallel
    "tile": ("model",),          # compressed colwise-N:M tile axis == TP axis
    "kept": ("data",),           # FSDP the kept-index dim of compressed values
    "reduce_group": ("model",),  # shard-local reduce-mode group dim == TP axis
    "layers": (),
    # --- activations ---
    "act_batch": ("pod", "data"),
    "act_seq_sp": ("model",),    # Megatron-style sequence parallelism between blocks
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_ffn": ("model",),
    "act_expert": ("model",),
    "act_moe_group": ("pod", "data"),  # MoE dispatch group dim == DP shards
    "act_kv_seq": ("data",),     # long-context decode: shard the KV seq dim
    "act_vocab": ("model",),
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]] = dataclasses.field(default_factory=lambda: dict(RULES))


_CURRENT: Optional[ShardingCtx] = None


def set_ctx(ctx: Optional[ShardingCtx]) -> None:
    global _CURRENT
    _CURRENT = ctx


def get_ctx() -> Optional[ShardingCtx]:
    return _CURRENT


@contextlib.contextmanager
def use_ctx(ctx: Optional[ShardingCtx]):
    prev = get_ctx()
    set_ctx(ctx)
    try:
        yield
    finally:
        set_ctx(prev)


def resolve_spec(
    shape: Sequence[int],
    names: Sequence[Optional[str]],
    rules: Dict[str, Tuple[str, ...]],
    mesh: Mesh,
) -> P:
    """Map logical dim names to a PartitionSpec, keeping only mesh axes that
    exist and divide the dim (axes are applied left-to-right greedily)."""
    assert len(shape) == len(names), (shape, names)
    parts = []
    used: set = set()  # a mesh axis may appear at most once in a spec
    for dim, name in zip(shape, names):
        chosen: list[str] = []
        if name is not None:
            prod = 1
            for ax in rules.get(name, ()):
                if ax not in mesh.shape or ax in used:
                    continue
                size = mesh.shape[ax]
                if dim % (prod * size) == 0:
                    chosen.append(ax)
                    used.add(ax)
                    prod *= size
        parts.append(tuple(chosen) if len(chosen) > 1 else (chosen[0] if chosen else None))
    # trailing Nones can be dropped but keeping them is fine
    return P(*parts)


def shd(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical dim names (no-op without
    an installed context)."""
    ctx = _CURRENT
    if ctx is None or x is None:
        return x
    spec = resolve_spec(x.shape, names, ctx.rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


logical_constraint = shd


def specs_to_shardings(spec_tree, shape_tree, mesh: Mesh, rules=None):
    """Resolve a tree of logical specs (+ matching shapes) to NamedShardings."""
    rules = rules or RULES

    def one(spec, arr):
        shape = arr.shape if hasattr(arr, "shape") else arr
        return NamedSharding(mesh, resolve_spec(shape, spec, rules, mesh))

    return jax.tree_util.tree_map(one, spec_tree, shape_tree, is_leaf=lambda s: isinstance(s, tuple))
