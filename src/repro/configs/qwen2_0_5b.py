"""Qwen2-0.5B: GQA (kv=2), QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    mlp_act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=1e6,
    source="arXiv:2407.10671; hf:Qwen/Qwen2-0.5B",
)
