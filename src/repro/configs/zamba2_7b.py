"""Zamba2-7B: Mamba2 backbone + shared attention block applied every 6th
layer (one set of shared weights, per-application KV cache).
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    block_pattern="mamba_shared_attn",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,            # shared block MLP width
    vocab_size=32000,
    head_dim=112,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_chunk=128,
    expand=2,
    d_conv=4,
    shared_attn_every=6,
    mlp_act="swiglu",
    norm="rmsnorm",
    source="arXiv:2411.15242 (unverified tier)",
)
