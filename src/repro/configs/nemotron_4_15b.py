"""Nemotron-4-15B: GQA (kv=8), squared-ReLU MLP, layernorm.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    head_dim=128,
    mlp_act="sq_relu",
    norm="layernorm",
    source="arXiv:2402.16819 (unverified tier)",
)
