"""xLSTM-350M: mLSTM + sLSTM blocks (7:1 ratio -> every 8th block is sLSTM).
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    block_pattern="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,               # xLSTM blocks carry their own up/down projections
    vocab_size=50304,
    expand=2,
    slstm_every=8,
    ssm_chunk=128,
    norm="rmsnorm",
    source="arXiv:2405.04517 (unverified tier)",
)
