"""Architecture + run configuration dataclasses for the model zoo."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.pruning import DENSE, SparsityConfig


def pad_to_multiple(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_ff: int = 256
    vocab_size: int = 256
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    attn_impl: str = "naive"               # naive | chunked | pallas (flash kernel)
    attn_chunk: int = 512
    use_rope: bool = True                  # whisper uses absolute sinusoidal positions
    rope_theta: float = 1e4
    mrope: bool = False                    # Qwen2-VL M-RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    mlp_act: str = "swiglu"                # swiglu | sq_relu | gelu
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "auto"                 # auto (GSPMD) | shard_map (manual EP)
    # --- SSM / recurrent ---
    block_pattern: str = "attn"            # attn | xlstm | mamba_shared_attn
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    slstm_every: int = 8                   # xlstm: every k-th block is sLSTM
    shared_attn_every: int = 6             # zamba2: shared attn after every k mamba blocks
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500                # frames after the (stubbed) conv frontend
    # --- VLM stub ---
    vision_patches: int = 256              # patch embeddings supplied by input_specs
    # --- the paper's technique ---
    sparsity: SparsityConfig = DENSE
    # --- numerics / runtime ---
    dtype: str = "float32"                 # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = False
    remat_policy: str = "nothing"          # nothing | dots (save matmul outputs)
    max_seq_len: int = 8192
    tp: int = 1                            # tensor-parallel degree (for head padding)
    dp: int = 1                            # data-parallel degree (MoE dispatch groups)
    source: str = ""                       # provenance note

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_heads(self) -> int:
        """q heads padded up to a multiple of tp (zero-init; exact numerics)."""
        return pad_to_multiple(self.n_heads, self.tp)

    @property
    def padded_vocab(self) -> int:
        """vocab padded to a multiple of 128 for clean TP sharding; logits for
        padded ids are masked at the loss/sampling layer."""
        return pad_to_multiple(self.vocab_size, 128)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count N (total, incl. all experts)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        h, kv = self.padded_heads, self.n_kv_heads
        attn = d * hd * (h + 2 * kv) + h * hd * d
        if self.mlp_act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.is_moe:
            mlp = mlp * self.n_experts + d * self.n_experts  # + router
        if self.block_pattern == "xlstm":
            di = self.expand * d
            blk = d * 2 * di + 3 * di * di // 4 + di * d  # rough xlstm cell
            core = self.n_layers * blk
        elif self.block_pattern == "mamba_shared_attn":
            di = self.expand * d
            nh = di // self.ssm_head_dim
            mamba = d * (2 * di + 2 * self.ssm_state + nh) + di * d
            n_shared = self.n_layers // self.shared_attn_every
            core = self.n_layers * mamba + (attn + mlp) + n_shared * 0  # shared params once
        else:
            core = self.n_layers * (attn + mlp)
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            core += self.encoder_layers * (attn + mlp) + self.n_layers * attn  # cross attn
        return core + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.mlp_act == "swiglu" else 2) * d * f
        total = self.param_count()
        return total - (self.n_experts - self.top_k) * per_expert * self.n_layers


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """Architecture config for the conv/vision side of the zoo.

    Small ResNet-style stacks of basic blocks built entirely on
    ``conv_init``/``conv_apply`` (``repro.models.vision``), so the paper's
    column-wise N:M pruning — and the profiled conv execution-plan ladder
    behind it (VMEM-resident / banded / pipelined / XLA) — is exercised
    end-to-end by a zoo config, exactly as the LM configs exercise the
    linear path.
    """

    name: str = "vision"
    family: str = "vision"
    c_in: int = 3
    stem_channels: int = 16
    stage_channels: Tuple[int, ...] = (16, 32)
    stage_blocks: Tuple[int, ...] = (1, 1)
    stage_strides: Tuple[int, ...] = (1, 2)
    image_hw: Tuple[int, int] = (32, 32)
    num_classes: int = 10
    strip_v: int = 128                     # packed-strip width for conv keys
    sparsity: SparsityConfig = DENSE
    dtype: str = "float32"
    source: str = ""

    def with_(self, **kw) -> "VisionConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assignment grid."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# Pure full-attention archs skip long_500k (sub-quadratic attention required);
# SSM/hybrid archs run it. Recorded in DESIGN.md §6.
LONG_CONTEXT_ARCHS = {"xlstm-350m", "zamba2-7b"}
