"""SmolLM-360M: llama-arch small dense LM. [hf:HuggingFaceTB/SmolLM-360M]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,           # padded to a tp multiple at build time
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    mlp_act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)
