"""Qwen2-VL-72B backbone: M-RoPE, dynamic-resolution vision (frontend STUB —
input_specs supplies precomputed patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1e6,
    vision_patches=256,
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B",
)
