"""resnet-tiny: the smallest vision config that exercises the pruned-conv
dispatch path end-to-end (stem + two stages of ResNet basic blocks + linear
head, every conv a ``conv_init`` layer at 50% column-wise sparsity).

Channel widths are sized so the pruned convs clear ``min_dim`` (the 3-channel
stem and the 1x1 projections stay dense, as the paper leaves its stem
unpruned) while staying cheap enough for interpret-mode Pallas on CPU."""
from repro.configs.base import VisionConfig
from repro.core.pruning import SparsityConfig

CONFIG = VisionConfig(
    name="resnet-tiny",
    c_in=3,
    stem_channels=8,
    stage_channels=(16, 16),
    stage_blocks=(1, 1),
    stage_strides=(1, 2),
    image_hw=(16, 16),
    num_classes=10,
    strip_v=128,
    sparsity=SparsityConfig(sparsity=0.5, m=None, tile=8, min_dim=16,
                            format="compressed_pallas"),
    source="ResNet-18 basic-block family, reduced for CPU smoke",
)
