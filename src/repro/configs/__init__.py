"""Config registry: the 10 assigned LM architectures + reduced smoke
variants, plus the vision configs that exercise the pruned-conv path."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeCell,
    VisionConfig,
)

_MODULES = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "smollm-360m": "repro.configs.smollm_360m",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "whisper-small": "repro.configs.whisper_small",
    "zamba2-7b": "repro.configs.zamba2_7b",
}


# Vision archs live in their own registry: they are VisionConfig (conv
# stacks), not ModelConfig, and the LM smoke/dry-run harnesses that iterate
# list_archs() cannot build them.
_VISION_MODULES = {
    "resnet-tiny": "repro.configs.resnet_tiny",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def list_vision_archs() -> List[str]:
    return list(_VISION_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_vision_config(name: str) -> VisionConfig:
    if name not in _VISION_MODULES:
        raise KeyError(
            f"unknown vision arch {name!r}; known: {list(_VISION_MODULES)}")
    return importlib.import_module(_VISION_MODULES[name]).CONFIG


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small widths, few
    layers/experts, tiny vocab — every family quirk preserved."""
    cfg = get_config(name)
    kv = max(1, min(cfg.n_kv_heads, 2))
    over = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=kv,
        d_ff=96 if cfg.d_ff else 0,
        vocab_size=503,  # deliberately not a multiple of 128 (tests padding)
        head_dim=16,
        max_seq_len=64,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
    if cfg.mrope:
        over["mrope_sections"] = (2, 3, 3)  # sums to head_dim/2 = 8
        over["vision_patches"] = 4
    if cfg.is_moe:
        over.update(n_experts=4, top_k=2)
    if cfg.block_pattern == "xlstm":
        over.update(n_layers=4, slstm_every=2, n_heads=2, n_kv_heads=2,
                    ssm_chunk=8, expand=2)
    if cfg.block_pattern == "mamba_shared_attn":
        over.update(n_layers=5, shared_attn_every=2, ssm_head_dim=16,
                    ssm_state=8, ssm_chunk=8, n_heads=4, n_kv_heads=kv)
    if cfg.is_encoder_decoder:
        over.update(encoder_layers=2, encoder_seq=24)
    return cfg.with_(**over)
