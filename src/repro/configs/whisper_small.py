"""Whisper-small: encoder-decoder, conv frontend STUB (input_specs supplies
frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    is_encoder_decoder=True,
    n_layers=12,           # decoder layers
    encoder_layers=12,
    encoder_seq=1500,      # natural frame count; shape cells may override
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,      # padded to 51968 internally for TP divisibility
    use_rope=False,        # absolute sinusoidal positions
    mlp_act="gelu",
    norm="layernorm",
    source="arXiv:2212.04356 (unverified tier)",
)
