"""SparseLinear: one linear-layer abstraction with pluggable execution format.

Formats
-------
  dense            : y = x @ w
  masked           : y = x @ (w * mask)            — training / mask refresh
  compressed_xla   : tiled gather + dense einsum   — pjit-friendly, shards the
                     tile axis over the tensor-parallel mesh axis
  compressed_pallas: the Algorithm-1 micro-kernel  — gather fused in VMEM

Every weight in the model zoo is created through ``linear_init`` and applied
through ``linear_apply`` so the paper's technique is a config switch, not a
code path per model.

Params are returned as ``Boxed(value, logical_spec)`` leaves; ``unbox_tree``
splits them into a value tree and a logical-sharding tree (single source of
truth for distribution).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.core.pruning import SparsityConfig, colwise_nm_mask, rowwise_nm_mask


# ---------------------------------------------------------------------------
# Boxed params: value + logical sharding spec in one tree
# ---------------------------------------------------------------------------


class Boxed:
    """A parameter leaf annotated with logical axis names (not a pytree)."""

    __slots__ = ("value", "spec")

    def __init__(self, value, spec: Tuple[Optional[str], ...]):
        self.value = value
        self.spec = spec

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Boxed(shape={shape}, spec={self.spec})"


def _is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox_tree(tree):
    """Split a Boxed tree into (values, logical_specs)."""
    values = jax.tree_util.tree_map(lambda b: b.value, tree, is_leaf=_is_boxed)
    specs = jax.tree_util.tree_map(lambda b: b.spec, tree, is_leaf=_is_boxed)
    return values, specs


def box_map(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_boxed)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _dense_init(key, d_in, d_out, dtype, scale):
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)


def linear_init(
    key: jax.Array,
    d_in: int,
    d_out: int,
    cfg: SparsityConfig,
    *,
    dtype=jnp.float32,
    use_bias: bool = False,
    in_ax: Optional[str] = "embed",
    out_ax: Optional[str] = "ffn",
    scale: Optional[float] = None,
    mode: str = "concat",
):
    """Create a (possibly pruned) linear layer's params as a Boxed dict.

    mode="reduce" marks layers whose reduction dim is TP-sharded; when the
    SparsityConfig enables shard_local_reduce they get the group-local
    compressed format (values_r/idx_r).
    """
    prune = cfg.applies_to(d_in, d_out)
    params: dict[str, Any] = {}
    if (prune and mode == "reduce" and cfg.shard_local_reduce
            and cfg.format in ("compressed_xla", "compressed_pallas")):
        from repro.core.pruning import choose_group, kept_per_group

        g = choose_group(d_in, cfg.reduce_groups or 4)
        m = d_in // g
        n_per = kept_per_group(m, cfg.sparsity)
        values, idx = formats.init_compressed_reduce(
            key, d_in, d_out, g, n_per, dtype, scale)
        params["values_r"] = Boxed(values, ("reduce_group", None, out_ax))
        params["idx_r"] = Boxed(idx, ("reduce_group", None))
    elif prune and cfg.format in ("compressed_xla", "compressed_pallas"):
        values, idx = formats.init_compressed(key, d_in, d_out, cfg, dtype, scale)
        params["values"] = Boxed(values, ("tile", "kept", None))
        params["idx"] = Boxed(idx, ("tile", None))
    elif prune and cfg.format == "masked":
        w = _dense_init(key, d_in, d_out, dtype, scale)
        if cfg.scheme == "rowwise":
            mask = rowwise_nm_mask(w, cfg.sparsity, m=cfg.m)
        else:
            mask = colwise_nm_mask(w, cfg.sparsity, m=cfg.m, tile=cfg.tile)
        params["w"] = Boxed(w * mask.astype(dtype), (in_ax, out_ax))
        params["mask"] = Boxed(mask, (in_ax, out_ax))
    else:
        params["w"] = Boxed(_dense_init(key, d_in, d_out, dtype, scale), (in_ax, out_ax))
    if use_bias:
        params["b"] = Boxed(jnp.zeros((d_out,), dtype), (out_ax,))
    return params


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def forward_compressed_xla(x: jax.Array, values: jax.Array, idx: jax.Array) -> jax.Array:
    """Tiled gather + dense einsum (the distribution-friendly path).

    x: [..., d_in]; values: [n_tiles, k, T]; idx: [n_tiles, k].
    Per tile t:  y[..., tT:(t+1)T] = x[..., idx[t]] @ values[t]
    With the tile axis sharded over the TP mesh axis every chip gathers its
    own [..., k] operand once and runs a dense local matmul — the paper's
    data-reuse argument lifted to chip granularity.
    """
    n_tiles, k, tile = values.shape
    xg = jnp.take(x, idx, axis=-1)  # [..., n_tiles, k]
    y = jnp.einsum("...tk,tkf->...tf", xg, values)
    return y.reshape(*x.shape[:-1], n_tiles * tile)


def forward_compressed_reduce(x: jax.Array, values: jax.Array, idx: jax.Array) -> jax.Array:
    """Shard-local REDUCE-mode path for layers whose *reduction* dim is
    tensor-parallel-sharded (down-proj, o-proj).

    values: [G, n, d_out]; idx: [G, n] group-local.  x is reshaped to
    [..., G, M] so the gather is a *batched* take_along_axis over the last
    dim — the group (shard) dim stays a batch dim, so GSPMD keeps the gather
    local to each shard and the only collective is the partial-sum
    all-reduce of the small [tokens, d_out] output (exactly the dense
    Megatron down-proj pattern; the dry-run showed the concat-mode gather
    instead all-reduced the full [tokens, k_kept] hidden).
    """
    g, n, d_out = values.shape
    lead = x.shape[:-1]
    m = x.shape[-1] // g
    xg = x.reshape(*lead, g, m)
    from repro.sharding import shd

    xg = shd(xg, *(("act_batch",) + (None,) * (len(lead) - 1) + ("act_ffn", None)))
    idx_b = jnp.broadcast_to(idx, (*lead, g, n))
    sel = jnp.take_along_axis(xg, idx_b, axis=-1)  # [..., G, n] shard-local
    return jnp.einsum("...gn,gnf->...f", sel, values)


def forward_masked(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    return x @ (w * mask.astype(w.dtype))


def linear_apply(params, x: jax.Array, *, prefer_pallas: bool = False,
                 impl: Optional[str] = None) -> jax.Array:
    """Apply a layer created by ``linear_init`` (unboxed params).

    Compressed layers route through ``repro.dispatch``: the implementation
    (gather-einsum XLA vs. fused Pallas micro-kernel) is chosen per operator
    shape from the profile DB / platform heuristic.  ``impl=`` (or the legacy
    ``prefer_pallas`` flag) forces a specific candidate, and
    ``REPRO_DISPATCH=off`` restores the pre-dispatch fixed routing.
    """
    if "values_r" in params:
        y = forward_compressed_reduce(x, params["values_r"], params["idx_r"])
        if "b" in params:
            y = y + params["b"]
        return y
    if "values" in params:
        from repro import dispatch as _dispatch

        if impl is None and prefer_pallas:
            impl = "compressed_pallas"
        key = _dispatch.linear_key_from(
            x.shape, params["values"].shape, x.dtype,
            phase=_dispatch.current_phase())
        spec = _dispatch.best_impl(key, param_keys=("values", "idx"),
                                   force=impl)
        # execution guard: a candidate that fails to run (trace-time kernel
        # crash or injected fault) is quarantined and the key re-resolves
        # down the ladder instead of killing the forward
        y = _dispatch.run_guarded(key, spec, lambda s: s.apply(params, x),
                                  param_keys=("values", "idx"))
    elif "mask" in params:
        y = forward_masked(x, params["w"], params["mask"])
    else:
        y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ---------------------------------------------------------------------------
# Conversions (prune a trained dense layer -> compressed)
# ---------------------------------------------------------------------------


def compress_layer(params, cfg: SparsityConfig):
    """Convert a dense/masked layer param dict into compressed format.

    Scan-stacked weights ([L, ..., d_in, d_out]) are packed per layer via
    vmap — the stacked (values, idx) feed straight back into the layer scan.
    """
    w = params["w"]
    w = w.value if isinstance(w, Boxed) else w
    lead = w.shape[:-2]
    d_in, d_out = w.shape[-2:]
    meta = formats.meta_for(d_in, d_out, cfg)
    mask = params.get("mask")
    if mask is not None and isinstance(mask, Boxed):
        mask = mask.value

    def pack2d(w2, m2):
        if m2 is None:
            if cfg.scheme == "rowwise":
                m2 = rowwise_nm_mask(w2, cfg.sparsity, m=cfg.m)
            else:
                m2 = colwise_nm_mask(w2, cfg.sparsity, m=cfg.m, tile=meta.tile)
        return formats.pack_colwise(w2, m2, meta)

    if lead:
        wf = w.reshape((-1,) + w.shape[-2:])
        mf = mask.reshape((-1,) + w.shape[-2:]) if mask is not None else None
        if mf is None:
            values, idx = jax.vmap(lambda a: pack2d(a, None))(wf)
        else:
            values, idx = jax.vmap(pack2d)(wf, mf)
        values = values.reshape(lead + values.shape[1:])
        idx = idx.reshape(lead + idx.shape[1:])
    else:
        values, idx = pack2d(w, mask)
    out = {"values": values, "idx": idx}
    if "b" in params:
        b = params["b"]
        out["b"] = b.value if isinstance(b, Boxed) else b
    return out


def flops_dense(batch: int, d_in: int, d_out: int) -> int:
    return 2 * batch * d_in * d_out


def flops_compressed(batch: int, meta: formats.ColwiseMeta) -> int:
    return 2 * batch * meta.k_kept * meta.d_out
