"""Compressed storage for column-wise N:M pruned weights.

Layout (per linear layer of shape [d_in, d_out], tile T, k_kept kept indices):

  values : [n_tiles, k_kept, T]   float — the retained weights, tile-major
  idx    : [n_tiles, k_kept]      int32 — absolute d_in index of each kept row

The kept indices of a tile are sorted ascending, so a gather of the activation
matrix ``x[:, idx[t]]`` walks memory monotonically (good for both RVV strided
loads in the paper's setting and TPU VMEM gathers here).

The paper stores "compressed weight format and an index array" (Fig. 1); this
is the same structure generalized to tile-shared indices.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import SparsityConfig, resolve_dims


class ColwiseMeta(NamedTuple):
    """Static metadata of a compressed layer (hashable, not traced)."""

    d_in: int
    d_out: int
    tile: int
    m: int
    n: int

    @property
    def n_tiles(self) -> int:
        return self.d_out // self.tile

    @property
    def k_kept(self) -> int:
        return (self.d_in // self.m) * self.n

    @property
    def density(self) -> float:
        return self.k_kept / self.d_in


def meta_for(d_in: int, d_out: int, cfg: SparsityConfig) -> ColwiseMeta:
    tile, m, n, _, _, _ = resolve_dims(d_in, d_out, cfg)
    return ColwiseMeta(d_in=d_in, d_out=d_out, tile=tile, m=m, n=n)


def keep_matrix_from_mask(mask: jax.Array, tile: int) -> jax.Array:
    """[d_in, d_out] column-wise mask -> [n_tiles, d_in] per-tile keep flags."""
    d_in, d_out = mask.shape
    n_tiles = d_out // tile
    return mask.reshape(d_in, n_tiles, tile)[:, :, 0].T  # [n_tiles, d_in]


def indices_from_keep(keep: jax.Array, k_kept: int) -> jax.Array:
    """Per-tile ascending indices of kept d_in positions.

    keep: [n_tiles, d_in] bool with exactly k_kept True per row.
    Returns [n_tiles, k_kept] int32.
    """
    n_tiles, d_in = keep.shape
    iota = jnp.arange(d_in, dtype=jnp.int32)
    # Kept positions keep their index; dropped ones are pushed past d_in so a
    # full sort puts kept indices (ascending) first.
    key = jnp.where(keep, iota[None, :], d_in + iota[None, :])
    order = jnp.sort(key, axis=-1)[:, :k_kept]
    return order.astype(jnp.int32)


def pack_colwise(
    w: jax.Array, mask: jax.Array, meta: ColwiseMeta
) -> Tuple[jax.Array, jax.Array]:
    """Compress a dense [d_in, d_out] weight under a column-wise mask.

    Returns (values [n_tiles, k_kept, tile], idx [n_tiles, k_kept]).
    """
    keep = keep_matrix_from_mask(mask, meta.tile)
    idx = indices_from_keep(keep, meta.k_kept)  # [n_tiles, k]
    # w tiled: [d_in, n_tiles, tile]
    wt = w.reshape(meta.d_in, meta.n_tiles, meta.tile)
    # values[t, j, :] = wt[idx[t, j], t, :]
    values = jax.vmap(lambda ids, t: wt[ids, t], in_axes=(0, 0))(
        idx, jnp.arange(meta.n_tiles)
    )
    return values, idx


def unpack_colwise(values: jax.Array, idx: jax.Array, meta: ColwiseMeta) -> jax.Array:
    """Decompress back to a dense (masked) [d_in, d_out] weight."""
    n_tiles, k, tile = values.shape
    assert (n_tiles, tile) == (meta.n_tiles, meta.tile), (values.shape, meta)

    def one_tile(vals, ids):
        w_t = jnp.zeros((meta.d_in, tile), vals.dtype)
        return w_t.at[ids].set(vals)

    wt = jax.vmap(one_tile)(values, idx)  # [n_tiles, d_in, tile]
    return wt.transpose(1, 0, 2).reshape(meta.d_in, meta.d_out)


def pack_reduce(
    w: jax.Array, mask: jax.Array, groups: int
) -> Tuple[jax.Array, jax.Array]:
    """Compress for REDUCE-mode execution: the prune unit spans the full
    output dim (tile = d_out) and the N:M groups along d_in align with the
    tensor-parallel shards, so the activation gather is shard-local.

    Returns (values [G, n_per, d_out], idx_within [G, n_per]) where
    idx_within are group-LOCAL indices in [0, d_in/G).
    """
    d_in, d_out = w.shape
    assert d_in % groups == 0, (d_in, groups)
    m = d_in // groups
    keep = mask[:, 0]  # colwise mask with tile=d_out: same for all outputs
    keep_g = keep.reshape(groups, m)
    n_per = int(keep_g.sum(axis=1)[0]) if hasattr(keep_g, "tolist") else 0
    counts = jnp.asarray(keep_g.sum(axis=1))
    # equal counts per group are required (N:M with M = d_in/G guarantees it)
    n_per = int(counts[0])
    iota = jnp.arange(m, dtype=jnp.int32)
    key = jnp.where(keep_g, iota[None, :], m + iota[None, :])
    idx_within = jnp.sort(key, axis=-1)[:, :n_per].astype(jnp.int32)
    w_g = w.reshape(groups, m, d_out)
    values = jax.vmap(lambda wg, ids: wg[ids])(w_g, idx_within)  # [G, n, d_out]
    return values, idx_within


def unpack_reduce(values: jax.Array, idx: jax.Array, d_in: int) -> jax.Array:
    g, n, d_out = values.shape
    m = d_in // g

    def one(vals, ids):
        return jnp.zeros((m, d_out), vals.dtype).at[ids].set(vals)

    return jax.vmap(one)(values, idx).reshape(d_in, d_out)


def init_compressed_reduce(
    key: jax.Array,
    d_in: int,
    d_out: int,
    groups: int,
    n_per: int,
    dtype=jnp.float32,
    scale: Optional[float] = None,
):
    m = d_in // groups
    if scale is None:
        scale = 1.0 / np.sqrt(max(groups * n_per, 1))
    values = jax.random.normal(key, (groups, n_per, d_out), dtype)
    values = values * jnp.asarray(scale, dtype)
    stride = max(m // n_per, 1)
    idx = jnp.broadcast_to(
        ((jnp.arange(n_per, dtype=jnp.int32) * stride) % m)[None, :], (groups, n_per)
    )
    return values, jnp.asarray(idx, jnp.int32)


def init_compressed(
    key: jax.Array,
    d_in: int,
    d_out: int,
    cfg: SparsityConfig,
    dtype=jnp.float32,
    scale: Optional[float] = None,
):
    """Directly initialize a compressed layer (no dense materialization).

    Used when a model is *born* sparse (e.g. the 72B dry-run configs): kept
    indices are evenly strided per group — the actual support would come from
    pruning a trained model; for shape/dry-run purposes the strided support is
    representative.
    """
    meta = meta_for(d_in, d_out, cfg)
    if scale is None:
        scale = 1.0 / np.sqrt(max(meta.k_kept, 1))
    values = jax.random.normal(key, (meta.n_tiles, meta.k_kept, meta.tile), dtype)
    values = values * jnp.asarray(scale, dtype)
    n_groups = d_in // meta.m
    stride = max(meta.m // meta.n, 1)
    within = (jnp.arange(meta.n, dtype=jnp.int32) * stride) % meta.m
    base = jnp.arange(n_groups, dtype=jnp.int32) * meta.m
    idx1 = (base[:, None] + within[None, :]).reshape(-1)  # [k_kept]
    idx = jnp.broadcast_to(idx1[None, :], (meta.n_tiles, meta.k_kept))
    return values, jnp.asarray(idx, jnp.int32)
