"""Mask construction for column-wise N:M pruning (the paper's core idea) and the
baselines it compares against (row-wise N:M, unstructured magnitude).

Conventions
-----------
A linear layer computes ``y = x @ w`` with ``w`` of shape ``[d_in, d_out]``.
The *reduction* (contraction) dimension is ``d_in``; this corresponds to the
"columns" of the paper's weight matrix ``W[out, in]`` (the paper draws the
transposed orientation).  "Column-wise" pruning therefore groups, for every
*output-feature tile* of size ``T``, whole d_in-positions as prune/keep units:
all ``T`` outputs of a tile share the same kept d_in indices.

N:M grouping happens along ``d_in``: out of every ``M`` consecutive positions,
``N`` are kept.  ``M = d_in`` (one group spanning the whole reduction dim) is
the paper's "adaptive M" configuration, which approximates unstructured
pruning while staying executable as a gather + dense matmul.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Sparsity configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Configuration of the column-wise N:M pruning feature.

    Attributes:
      sparsity: fraction of weights removed, in [0, 1). 0 disables pruning.
      m: N:M group size along d_in. ``None`` means the full reduction
        dimension (the paper's adaptive-M mode).
      tile: output-feature tile size T sharing one set of kept indices.
        ``None`` lets the layer pick ``d_out // (tp * tiles_per_shard)`` so the
        tile axis shards exactly over the tensor-parallel mesh axis.
      tiles_per_shard: number of tiles per tensor-parallel shard when
        ``tile is None``.
      format: execution format — ``dense`` | ``masked`` | ``compressed_xla`` |
        ``compressed_pallas``.
      min_dim: layers with ``min(d_in, d_out) < min_dim`` are left dense (the
        paper similarly skips the 3-channel stem conv).
      scheme: ``colwise`` (the paper's technique) or ``rowwise`` (the
        conventional N:M baseline the paper compares against).
    """

    sparsity: float = 0.0
    m: Optional[int] = None
    tile: Optional[int] = None
    tiles_per_shard: int = 1
    format: str = "dense"
    min_dim: int = 128
    scheme: str = "colwise"
    # beyond-paper: shard-local REDUCE-mode compression for layers whose
    # reduction dim is TP-sharded (down/o-proj) — groups align with shards
    shard_local_reduce: bool = False
    reduce_groups: int = 0

    @property
    def enabled(self) -> bool:
        return self.sparsity > 0.0 and self.format != "dense"

    def applies_to(self, d_in: int, d_out: int) -> bool:
        return self.enabled and min(d_in, d_out) >= self.min_dim

    def with_(self, **kw) -> "SparsityConfig":
        return dataclasses.replace(self, **kw)


DENSE = SparsityConfig()


# ---------------------------------------------------------------------------
# Shape helpers
# ---------------------------------------------------------------------------


def choose_tile(d_out: int, requested: Optional[int]) -> int:
    """Largest divisor of d_out that is <= requested (defaults to d_out)."""
    if requested is None or requested >= d_out:
        return d_out
    t = min(requested, d_out)
    while d_out % t != 0:
        t -= 1
    return max(t, 1)


def choose_group(d_in: int, requested: Optional[int]) -> int:
    """Largest divisor of d_in that is <= requested (defaults to d_in)."""
    if requested is None or requested >= d_in:
        return d_in
    m = min(requested, d_in)
    while d_in % m != 0:
        m -= 1
    return max(m, 1)


def kept_per_group(m: int, sparsity: float) -> int:
    """N = number of kept elements per group of M at the given sparsity."""
    n = int(round(m * (1.0 - sparsity)))
    return int(np.clip(n, 1, m))


def resolve_dims(d_in: int, d_out: int, cfg: SparsityConfig):
    """Resolve (tile T, group M, kept-per-group N, n_tiles, n_groups, k_kept)."""
    tile = choose_tile(d_out, cfg.tile)
    m = choose_group(d_in, cfg.m)
    n = kept_per_group(m, cfg.sparsity)
    n_tiles = d_out // tile
    n_groups = d_in // m
    k_kept = n_groups * n
    return tile, m, n, n_tiles, n_groups, k_kept


# ---------------------------------------------------------------------------
# Importance + masks
# ---------------------------------------------------------------------------


def colwise_importance(w: jax.Array, tile: int) -> jax.Array:
    """L1 importance of each (tile, d_in) column group.

    Returns [n_tiles, d_in]: score of keeping d_in-position i for tile t is the
    L1 norm of w[i, t*T:(t+1)*T]  (paper §3.1: "we use the L1 norm to evaluate
    the importance of each column group").
    """
    d_in, d_out = w.shape
    n_tiles = d_out // tile
    wt = jnp.abs(w).reshape(d_in, n_tiles, tile)
    return wt.sum(axis=-1).T  # [n_tiles, d_in]


def _topn_mask_lastdim(scores: jax.Array, n: int) -> jax.Array:
    """Boolean mask keeping exactly the top-n entries of the last dim.

    Ties are broken by position (earlier index wins) so exactly n entries are
    kept — argsort is stable on the negated scores.
    """
    m = scores.shape[-1]
    order = jnp.argsort(-scores, axis=-1)  # descending, stable
    ranks = jnp.argsort(order, axis=-1)
    return ranks < n


def colwise_nm_mask(
    w: jax.Array,
    sparsity: float,
    m: Optional[int] = None,
    tile: Optional[int] = None,
) -> jax.Array:
    """Column-wise N:M mask (the paper's technique).

    For every output tile of size T and every group of M consecutive d_in
    positions, keep the N = (1-sparsity)*M positions with the largest L1 norm
    over the tile. Returns a boolean mask of w's shape where every kept d_in
    position is kept for the *entire* tile.
    """
    d_in, d_out = w.shape
    cfg = SparsityConfig(sparsity=sparsity, m=m, tile=tile, format="masked")
    tile, m, n, n_tiles, n_groups, _ = resolve_dims(d_in, d_out, cfg)
    scores = colwise_importance(w, tile)  # [n_tiles, d_in]
    scores = scores.reshape(n_tiles, n_groups, m)
    keep = _topn_mask_lastdim(scores, n)  # [n_tiles, n_groups, m]
    keep = keep.reshape(n_tiles, d_in)  # [n_tiles, d_in]
    # expand across the tile: [d_in, n_tiles, tile] -> [d_in, d_out]
    mask = jnp.repeat(keep.T[:, :, None], tile, axis=2).reshape(d_in, d_out)
    return mask


def conv_colwise_nm_mask(
    w_ohwi: jax.Array,
    sparsity: float,
    m: Optional[int] = None,
    tile: Optional[int] = None,
) -> jax.Array:
    """Column-wise N:M mask for an OHWI conv kernel.

    Pruning is column-wise over the conv's GEMM view [Kh*Kw*C, O]: the
    prune/keep unit is a whole (kh, kw, c) tap shared by an output-channel
    tile — exactly the unit the compressed conv kernels gather.  Returns a
    boolean mask in the kernel's own OHWI layout, so masked training keeps
    the weight and its mask in one layout.
    """
    o, kh, kw, c = w_ohwi.shape
    wmat = w_ohwi.reshape(o, kh * kw * c).T  # GEMM view [K, O]
    mask = colwise_nm_mask(wmat, sparsity, m=m, tile=tile)
    return mask.T.reshape(o, kh, kw, c)


def rowwise_nm_mask(
    w: jax.Array, sparsity: float, m: Optional[int] = None
) -> jax.Array:
    """Conventional (row-based) N:M pruning baseline.

    Every output feature independently keeps N of every M consecutive d_in
    positions by magnitude. Equivalent to the paper's column-wise scheme with
    tile T=1 (paper §4.5, configuration 1).
    """
    return colwise_nm_mask(w, sparsity, m=m, tile=1)


def unstructured_mask(w: jax.Array, sparsity: float) -> jax.Array:
    """Global magnitude pruning (upper bound on flexibility)."""
    k = int(round(w.size * (1.0 - sparsity)))
    k = max(k, 1)
    flat = jnp.abs(w).reshape(-1)
    mask = _topn_mask_lastdim(flat, k)
    return mask.reshape(w.shape)


# ---------------------------------------------------------------------------
# Mask invariants (used by tests and by pack())
# ---------------------------------------------------------------------------


def mask_is_colwise(mask: np.ndarray, tile: int) -> bool:
    """Check that within each output tile all columns share the keep pattern."""
    d_in, d_out = mask.shape
    n_tiles = d_out // tile
    m = np.asarray(mask).reshape(d_in, n_tiles, tile)
    return bool(np.all(m.all(axis=2) == m.any(axis=2)))


def mask_nm_counts(mask: np.ndarray, m_group: int) -> np.ndarray:
    """Per-(group, column) kept counts along d_in — for N:M verification."""
    d_in, d_out = mask.shape
    g = d_in // m_group
    return np.asarray(mask).reshape(g, m_group, d_out).sum(axis=1)


# ---------------------------------------------------------------------------
# One-shot pruning over a parameter tree
# ---------------------------------------------------------------------------


def _mask_nd(w: jax.Array, mask_fn):
    """Apply a 2-D mask function over the trailing two dims of an N-D weight
    (scan-stacked layers are [L, ..., d_in, d_out])."""
    if w.ndim == 2:
        return mask_fn(w)
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    masks = jax.vmap(mask_fn)(flat)
    return masks.reshape(lead + w.shape[-2:])


def prune_tree(params, cfg: SparsityConfig, is_weight=None):
    """One-shot prune every >=2-D weight in a pytree (magnitude/L1, the
    paper's one-shot recipe); stacked layer weights ([L, d_in, d_out]) are
    masked per layer via vmap. Returns (masked_params, masks) with masks a
    matching tree containing None for untouched leaves.

    is_weight: optional predicate (path, leaf) -> bool to select leaves.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    new_leaves, mask_leaves = [], []
    for path, leaf in flat:
        take = (
            hasattr(leaf, "ndim")
            and leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and cfg.applies_to(leaf.shape[-2], leaf.shape[-1])
        )
        if take and is_weight is not None:
            take = is_weight(path, leaf)
        if take:
            if cfg.scheme == "rowwise":
                fn = lambda w: rowwise_nm_mask(w, cfg.sparsity, m=cfg.m)
            else:
                fn = lambda w: colwise_nm_mask(w, cfg.sparsity, m=cfg.m, tile=cfg.tile)
            mask = _mask_nd(leaf, fn)
            new_leaves.append(leaf * mask.astype(leaf.dtype))
            mask_leaves.append(mask)
        else:
            new_leaves.append(leaf)
            mask_leaves.append(None)
    return (
        jax.tree_util.tree_unflatten(treedef, new_leaves),
        jax.tree_util.tree_unflatten(treedef, mask_leaves),
    )


def mask_project_tree(params):
    """Re-apply every masked layer's stored ``mask`` to its ``w``.

    The per-step projection of masked finetuning (paper §4.1.2: the support
    is held fixed while the kept weights train): run it after each optimizer
    update so momentum/weight-decay cannot resurrect pruned positions.
    Works on any params tree whose layer dicts carry both ``w`` and ``mask``
    — linear ([d_in, d_out]) and conv (OHWI) layers alike, ``Boxed`` or raw
    leaves; everything else passes through untouched.
    """
    from repro.core.sparse_conv import apply_conv_mask

    def _walk(t):
        if isinstance(t, dict):
            # apply_conv_mask holds the single copy of the w*mask projection
            # (Boxed-aware, no-op without a mask); it is layout-agnostic, so
            # linear [d_in, d_out] layers project through it too
            return apply_conv_mask({k: _walk(v) for k, v in t.items()})
        if isinstance(t, list):
            return [_walk(v) for v in t]
        if isinstance(t, tuple):
            return tuple(_walk(v) for v in t)
        return t

    return _walk(params)
