"""Auto-tuning framework (paper §3.3, AITemplate-analog).

The paper parameterizes its XNNPACK micro-kernels by tile size T and LMUL,
profiles every candidate on the target, and bakes the fastest into the
executable.  Here:

  candidates = tile width T (accumulator footprint) x block widths
               (block_b, block_k — the LMUL analog)

  measurement = - wall-clock of the jitted XLA candidate on the host
                  (a real profile, like AITemplate), and
                - an analytic TPU VMEM-roofline score for the Pallas kernel
                  geometry (the dry-run has no TPU to time)

Selections are cached in a JSON keyed by (d_in, d_out, batch, sparsity) so a
model build can ask for the tuned tile per layer shape
(``tuned_tile(...)``) exactly the way AITemplate consults its profile DB.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import meta_for, pack_colwise
from repro.core.pruning import SparsityConfig, colwise_nm_mask

VMEM_BYTES = 16 * 2 ** 20  # ~16 MB usable per core


@dataclasses.dataclass
class Candidate:
    tile: int
    block_b: int
    block_k: int
    wall_us: Optional[float] = None
    vmem_bytes: int = 0
    feasible: bool = True
    score: float = 0.0


def _pallas_vmem(block_b: int, block_k: int, d_in: int, tile: int, itemsize=2) -> int:
    from repro.kernels.colwise_nm.kernel import vmem_bytes

    return vmem_bytes(block_b, block_k, d_in, tile, itemsize)


def _time_xla_candidate(batch, d_in, d_out, sparsity, tile, iters=5) -> float:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (batch, d_in))
    w = jax.random.normal(jax.random.PRNGKey(1), (d_in, d_out)) / (d_in ** 0.5)
    cfg = SparsityConfig(sparsity, m=None, tile=tile, format="compressed_xla")
    meta = meta_for(d_in, d_out, cfg)
    mask = colwise_nm_mask(w, sparsity, tile=meta.tile)
    values, idx = pack_colwise(w, mask, meta)

    @jax.jit
    def f(x):
        xg = jnp.take(x, idx, axis=-1)
        return jnp.einsum("btk,tkf->btf", xg, values)

    f(x).block_until_ready()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def enumerate_candidates(d_in: int, d_out: int) -> List[Candidate]:
    tiles = sorted({t for t in (32, 64, 128, 256, 512, d_out) if d_out % t == 0})
    blocks = [(128, 128), (256, 128), (128, 256), (512, 128)]
    out = []
    for t in tiles:
        for bb, bk in blocks:
            vm = _pallas_vmem(bb, bk, d_in, min(t, 512))
            out.append(Candidate(tile=t, block_b=bb, block_k=bk,
                                 vmem_bytes=vm, feasible=vm <= VMEM_BYTES))
    return out


class Tuner:
    def __init__(self, cache_path: str = "artifacts/tuning_cache.json"):
        self.path = Path(cache_path)
        self.cache: Dict[str, Dict] = {}
        if self.path.exists():
            self.cache = json.loads(self.path.read_text())

    def _key(self, batch, d_in, d_out, sparsity) -> str:
        return f"b{batch}_i{d_in}_o{d_out}_s{int(sparsity*100)}"

    def tune(self, batch: int, d_in: int, d_out: int, sparsity: float = 0.5,
             profile: bool = True) -> Dict:
        """Profile candidates; returns the winning config (cached)."""
        key = self._key(batch, d_in, d_out, sparsity)
        if key in self.cache:
            return self.cache[key]
        cands = enumerate_candidates(d_in, d_out)
        best = None
        tried_tiles = set()
        for c in cands:
            if not c.feasible:
                continue
            if profile and c.tile not in tried_tiles:
                # wall time depends on the tile (XLA path); block geometry is
                # scored analytically (VMEM pressure => prefer bigger blocks
                # while they fit, like the paper prefers higher LMUL)
                c.wall_us = _time_xla_candidate(batch, d_in, d_out, sparsity, c.tile)
                tried_tiles.add(c.tile)
            wall = c.wall_us or next(
                (o.wall_us for o in cands if o.tile == c.tile and o.wall_us), 1e9
            )
            c.score = wall * (1.0 + c.vmem_bytes / VMEM_BYTES * 0.1)
            if best is None or c.score < best.score:
                best = c
        result = {
            "tile": best.tile, "block_b": best.block_b, "block_k": best.block_k,
            "wall_us": best.wall_us, "vmem_bytes": best.vmem_bytes,
        }
        self.cache[key] = result
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(json.dumps(self.cache, indent=1))
        return result

    def tuned_tile(self, batch: int, d_in: int, d_out: int, sparsity: float = 0.5) -> int:
        return int(self.tune(batch, d_in, d_out, sparsity)["tile"])
