"""Backwards-compat shim — the auto-tuner now lives in ``repro.dispatch``.

The seed's ad-hoc ``Tuner`` grew into the operator dispatch & profiling
subsystem (``repro.dispatch``), and its block-geometry tier has since been
absorbed into the dispatch *candidate space*: each Pallas kernel registers
one geometry-pinned candidate per point of ``dispatch.LINEAR_GEOMETRY`` /
``dispatch.FUSED_CONV_GEOMETRY``, so a single ``profile_op`` pass selects
implementation and (tile, block_b, block_k) geometry jointly — there is no
separate tuning pass anymore.  ``Tuner`` is a deprecated shim whose block
grid is derived from the same registry geometry; import from
``repro.dispatch`` in new code.
"""
from repro.dispatch.profiler import (  # noqa: F401
    Candidate,
    Tuner,
    TuningError,
    enumerate_candidates,
)
from repro.dispatch.registry import VMEM_BYTES  # noqa: F401
