"""Backwards-compat shim — the auto-tuner now lives in ``repro.dispatch``.

The seed's ad-hoc ``Tuner`` grew into the operator dispatch & profiling
subsystem (``repro.dispatch``): an operator registry of candidate
implementations, a profiler harness, and a versioned, environment-
fingerprinted profile DB.  Import from ``repro.dispatch`` in new code; this
module only re-exports the original names so existing imports keep working.
"""
from repro.dispatch.profiler import (  # noqa: F401
    Candidate,
    Tuner,
    TuningError,
    enumerate_candidates,
)
from repro.dispatch.registry import VMEM_BYTES  # noqa: F401
