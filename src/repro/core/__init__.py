# The paper's primary contribution: column-wise N:M pruning as a composable
# JAX feature — mask construction, compressed format, and the SparseLinear
# layer abstraction all models in the zoo are built from.
from repro.core.pruning import (  # noqa: F401
    DENSE,
    SparsityConfig,
    colwise_importance,
    colwise_nm_mask,
    conv_colwise_nm_mask,
    mask_project_tree,
    prune_tree,
    resolve_dims,
    rowwise_nm_mask,
    unstructured_mask,
)
from repro.core.formats import (  # noqa: F401
    ColwiseMeta,
    init_compressed,
    meta_for,
    pack_colwise,
    unpack_colwise,
)
from repro.core.sparse_conv import (  # noqa: F401
    apply_conv_mask,
    compress_conv_layer,
    compress_conv_tree,
    conv_apply,
    conv_init,
    prune_conv_tree,
    refresh_conv_mask,
)
from repro.core.sparse_linear import (  # noqa: F401
    Boxed,
    box_map,
    compress_layer,
    forward_compressed_xla,
    forward_masked,
    linear_apply,
    linear_init,
    unbox_tree,
)
