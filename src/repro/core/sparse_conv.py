"""SparseConv: the conv-layer abstraction mirroring ``sparse_linear``.

Vision models build conv weights through ``conv_init`` and apply them through
``conv_apply`` so the paper's column-wise N:M technique — and the profiled
execution plan behind it (fused megakernel / two-kernel strip-major / XLA
reference, see ``repro.kernels.conv_gemm``) — is a config switch, not a code
path per model.  Compressed layers route through ``repro.dispatch.best_impl``
with real params, exactly like ``linear_apply``; the ambient
``dispatch.phase_scope`` tag is honoured, so a conv traced inside a serving
phase resolves a phase-tagged profile entry.

The GEMM view of a conv is [O, Kh*Kw*C]: pruning is column-wise over the
flattened (kh, kw, c) reduction dim, and the compressed params are the same
``{"values": [n_tiles, k_kept, T], "idx": [n_tiles, k_kept]}`` pair the
linear layers use (Boxed with the same logical axes, so sharding rules carry
over unchanged).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.core.pruning import SparsityConfig
from repro.core.sparse_linear import Boxed


def conv_init(
    key: jax.Array,
    c_in: int,
    c_out: int,
    kh: int,
    kw: int,
    cfg: SparsityConfig,
    *,
    dtype=jnp.float32,
    use_bias: bool = False,
    scale: Optional[float] = None,
):
    """Create a (possibly pruned) conv layer's params as a Boxed dict.

    Compressed formats store the GEMM-view compressed pair (values, idx)
    over the [Kh*Kw*C, O] weight matrix; ``masked`` stores the OHWI kernel
    with the column-wise mask applied plus the mask itself (training / mask
    refresh, mirroring ``linear_init``); dense stores an OHWI kernel ``w``.
    ``conv_apply`` needs the same (kh, kw) statics back.
    """
    d_in = kh * kw * c_in
    prune = cfg.applies_to(d_in, c_out)
    params: dict[str, Any] = {}
    if prune and cfg.format in ("compressed_xla", "compressed_pallas"):
        values, idx = formats.init_compressed(key, d_in, c_out, cfg, dtype, scale)
        params["values"] = Boxed(values, ("tile", "kept", None))
        params["idx"] = Boxed(idx, ("tile", None))
        # op discriminator: a compressed conv layer's (values, idx) pair is
        # shape-indistinguishable from a linear layer's, so the build-time
        # params scan (dispatch.plan_params) needs this marker to pre-profile
        # it under a conv_key instead of misfiling it as a linear op.  It is
        # a replicated int leaf (jit/sharding-safe); apply/compress ignore it.
        params["conv_geom"] = Boxed(
            jnp.asarray([kh, kw, c_in], jnp.int32), (None,))
    else:
        if scale is None:
            scale = 1.0 / np.sqrt(d_in)
        w = jax.random.normal(key, (c_out, kh, kw, c_in), dtype)
        w = w * jnp.asarray(scale, dtype)
        if prune and cfg.format == "masked":
            from repro.core.pruning import colwise_nm_mask

            wmat = w.reshape(c_out, d_in).T  # GEMM view [K, O]
            meta = formats.meta_for(d_in, c_out, cfg)
            mask = colwise_nm_mask(wmat, cfg.sparsity, m=cfg.m,
                                   tile=meta.tile)
            w = ((wmat * mask).T.reshape(c_out, kh, kw, c_in)).astype(dtype)
            params["mask"] = Boxed(
                mask.T.reshape(c_out, kh, kw, c_in),
                (None, None, None, "embed"))
        elif prune:
            raise ValueError(
                f"conv_init does not support pruning format {cfg.format!r}")
        params["w"] = Boxed(w, (None, None, None, "embed"))
    if use_bias:
        params["b"] = Boxed(jnp.zeros((c_out,), dtype), (None,))
    return params


def conv_apply(
    params,
    x_cnhw: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    impl: Optional[str] = None,
) -> jax.Array:
    """Apply a layer created by ``conv_init`` (unboxed params) to a CNHW map.

    Compressed layers route through ``repro.dispatch``: the execution plan
    (fused megakernel geometry variant, two-kernel strip-major, XLA
    reference) is chosen per conv shape from the profile DB / platform
    heuristic; ``impl=`` forces a specific candidate.  Dense layers run the
    lax reference conv.  Returns CNHW output [O, B, Ho, Wo].
    """
    if "values" in params:
        from repro import dispatch as _dispatch

        values, idx = params["values"], params["idx"]
        c, b, h, w = x_cnhw.shape
        n_tiles, k_kept, tile = (int(s) for s in values.shape)
        key = _dispatch.conv_key(
            c, h, w, n_tiles * tile, kh, kw, stride, pad, k_kept, tile,
            v=v, dtype=x_cnhw.dtype, batch=b, phase=_dispatch.current_phase())
        spec = _dispatch.best_impl(key, param_keys=("values", "idx"),
                                   force=impl)
        y = spec.apply({"values": values, "idx": idx}, x_cnhw,
                       kh=kh, kw=kw, stride=stride, pad=pad, v=v)
    else:
        from repro.kernels.conv_gemm.ref import conv2d_cnhw_ref

        w = params["w"]
        if "mask" in params:
            w = w * params["mask"].astype(w.dtype)
        y = conv2d_cnhw_ref(x_cnhw, w, stride=stride, pad=pad)
    if "b" in params:
        y = y + params["b"][:, None, None, None]
    return y


def compress_conv_layer(params, kh: int, kw: int, cfg: SparsityConfig):
    """Convert a dense conv layer (OHWI ``w``) into compressed GEMM format."""
    from repro.kernels.conv_gemm.ops import compress_conv_weights

    w = params["w"]
    w = w.value if isinstance(w, Boxed) else w
    values, idx, _meta = compress_conv_weights(w, cfg)
    out = {"values": values, "idx": idx,
           "conv_geom": jnp.asarray([kh, kw, w.shape[3]], jnp.int32)}
    if "b" in params:
        b = params["b"]
        out["b"] = b.value if isinstance(b, Boxed) else b
    return out
