"""SparseConv: the conv-layer abstraction mirroring ``sparse_linear``.

Vision models build conv weights through ``conv_init`` and apply them through
``conv_apply`` so the paper's column-wise N:M technique — and the profiled
execution plan behind it (fused megakernel / two-kernel strip-major / XLA
reference, see ``repro.kernels.conv_gemm``) — is a config switch, not a code
path per model.  Compressed layers route through ``repro.dispatch.best_impl``
with real params, exactly like ``linear_apply``; the ambient
``dispatch.phase_scope`` tag is honoured, so a conv traced inside a serving
phase resolves a phase-tagged profile entry.

The GEMM view of a conv is [O, Kh*Kw*C]: pruning is column-wise over the
flattened (kh, kw, c) reduction dim, and the compressed params are the same
``{"values": [n_tiles, k_kept, T], "idx": [n_tiles, k_kept]}`` pair the
linear layers use (Boxed with the same logical axes, so sharding rules carry
over unchanged).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.core.pruning import SparsityConfig, conv_colwise_nm_mask
from repro.core.sparse_linear import Boxed


def conv_init(
    key: jax.Array,
    c_in: int,
    c_out: int,
    kh: int,
    kw: int,
    cfg: SparsityConfig,
    *,
    dtype=jnp.float32,
    use_bias: bool = False,
    scale: Optional[float] = None,
):
    """Create a (possibly pruned) conv layer's params as a Boxed dict.

    Compressed formats store the GEMM-view compressed pair (values, idx)
    over the [Kh*Kw*C, O] weight matrix; ``masked`` stores the OHWI kernel
    with the column-wise mask applied plus the mask itself (training / mask
    refresh, mirroring ``linear_init``); dense stores an OHWI kernel ``w``.
    ``conv_apply`` needs the same (kh, kw) statics back.
    """
    d_in = kh * kw * c_in
    prune = cfg.applies_to(d_in, c_out)
    params: dict[str, Any] = {}
    if prune and cfg.format in ("compressed_xla", "compressed_pallas"):
        values, idx = formats.init_compressed(key, d_in, c_out, cfg, dtype, scale)
        params["values"] = Boxed(values, ("tile", "kept", None))
        params["idx"] = Boxed(idx, ("tile", None))
        # op discriminator: a compressed conv layer's (values, idx) pair is
        # shape-indistinguishable from a linear layer's, so the build-time
        # params scan (dispatch.plan_params) needs this marker to pre-profile
        # it under a conv_key instead of misfiling it as a linear op.  It is
        # a replicated int leaf (jit/sharding-safe); apply/compress ignore it.
        params["conv_geom"] = Boxed(
            jnp.asarray([kh, kw, c_in], jnp.int32), (None,))
    else:
        if scale is None:
            scale = 1.0 / np.sqrt(d_in)
        w = jax.random.normal(key, (c_out, kh, kw, c_in), dtype)
        w = w * jnp.asarray(scale, dtype)
        if prune and cfg.format == "masked":
            meta = formats.meta_for(d_in, c_out, cfg)
            mask = conv_colwise_nm_mask(w, cfg.sparsity, m=cfg.m,
                                        tile=meta.tile)
            w = (w * mask).astype(dtype)
            params["mask"] = Boxed(mask, (None, None, None, "embed"))
        elif prune:
            raise ValueError(
                f"conv_init does not support pruning format {cfg.format!r}")
        params["w"] = Boxed(w, (None, None, None, "embed"))
    if use_bias:
        params["b"] = Boxed(jnp.zeros((c_out,), dtype), (None,))
    return params


def conv_apply(
    params,
    x_cnhw: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    impl: Optional[str] = None,
) -> jax.Array:
    """Apply a layer created by ``conv_init`` (unboxed params) to a CNHW map.

    Compressed layers route through ``repro.dispatch`` via the
    ``conv2d_sparse`` custom-VJP wrapper: the execution plan (fused
    megakernel geometry variant, banded, two-kernel pipelined, XLA
    reference) is chosen per conv shape from the profile DB / platform
    heuristic, and the layer is differentiable — ``jax.grad`` through it
    yields the transposed-conv ``dx`` and packed ``dvalues`` gradients
    whatever rung the forward ran on.  ``impl=`` forces a specific
    candidate.  Masked and dense layers run the lax reference conv (also
    differentiable; the mask factor confines ``w``'s gradient support at
    the first backward step, and ``apply_conv_mask`` re-projects after
    optimizer updates).  Returns CNHW output [O, B, Ho, Wo].
    """
    if "values" in params:
        from repro.kernels.conv_gemm.ops import conv2d_sparse

        y = conv2d_sparse(x_cnhw, params["values"], params["idx"], kh=kh,
                          kw=kw, stride=stride, pad=pad, v=v, impl=impl)
    else:
        from repro.kernels.conv_gemm.ref import conv2d_cnhw_ref

        w = params["w"]
        if "mask" in params:
            w = w * params["mask"].astype(w.dtype)
        y = conv2d_cnhw_ref(x_cnhw, w, stride=stride, pad=pad)
    if "b" in params:
        y = y + params["b"][:, None, None, None]
    return y


def compress_conv_layer(params, kh: int, kw: int, cfg: SparsityConfig):
    """Convert a dense/masked conv layer (OHWI ``w``) into compressed GEMM
    format.

    A stored ``mask`` (masked finetuning) pins the kept support exactly —
    the packed layer reproduces the finetuned masked forward bit-for-bit;
    without one the column-wise mask is recomputed from ``|w|`` (one-shot).
    Leaves are ``Boxed`` with the same logical axes as ``conv_init`` emits,
    so a post-hoc-compressed tree is structurally identical to a born-sparse
    one: sharding rules and ``dispatch.plan_params`` (which keys off the
    boxed ``conv_geom`` discriminator) see no difference.
    """
    from repro.kernels.conv_gemm.ops import compress_conv_weights

    w = params["w"]
    w = w.value if isinstance(w, Boxed) else w
    mask = params.get("mask")
    if mask is not None:
        mask = mask.value if isinstance(mask, Boxed) else mask
        o, _kh, _kw, c_in = w.shape
        d_in = _kh * _kw * c_in
        meta = formats.meta_for(d_in, o, cfg)
        values, idx = formats.pack_colwise(
            w.reshape(o, d_in).T, mask.reshape(o, d_in).T, meta)
    else:
        values, idx, _meta = compress_conv_weights(w, cfg)
    out = {"values": Boxed(values, ("tile", "kept", None)),
           "idx": Boxed(idx, ("tile", None)),
           "conv_geom": Boxed(
               jnp.asarray([kh, kw, w.shape[3]], jnp.int32), (None,))}
    if "b" in params:
        b = params["b"]
        b = b.value if isinstance(b, Boxed) else b
        out["b"] = Boxed(b, (None,))
    return out


# ---------------------------------------------------------------------------
# Masked-finetune hooks: projection + mask refresh (the conv training story)
# ---------------------------------------------------------------------------


def apply_conv_mask(params):
    """Project a masked conv layer's ``w`` onto its stored ``mask``.

    The per-step projection of masked finetuning, mirroring the linear
    layers' training story: the optimizer updates every position, then the
    projection zeroes the pruned ones so the support stays fixed.  Boxed or
    raw leaves; layers without a mask pass through unchanged.
    """
    if "mask" not in params or "w" not in params:
        return params
    w, m = params["w"], params["mask"]
    wv = w.value if isinstance(w, Boxed) else w
    mv = m.value if isinstance(m, Boxed) else m
    new = wv * mv.astype(wv.dtype)
    if isinstance(w, Boxed):
        new = Boxed(new, w.spec)
    return {**params, "w": new}


def refresh_conv_mask(params, cfg: SparsityConfig):
    """Recompute a masked conv layer's column-wise mask from its *current*
    weights and re-apply it.

    The mask-refresh hook of masked finetuning: periodically re-selecting
    the kept (kh, kw, c) taps by importance lets the support track the
    finetuned weights (the iterative variant of the paper's one-shot
    recipe), after which the projection holds the new support fixed.
    Layers without a mask pass through unchanged.
    """
    if "mask" not in params or "w" not in params:
        return params
    w, m = params["w"], params["mask"]
    wv = w.value if isinstance(w, Boxed) else w
    o, _kh, _kw, c_in = wv.shape
    meta = formats.meta_for(_kh * _kw * c_in, o, cfg)
    mask = conv_colwise_nm_mask(wv, cfg.sparsity, m=cfg.m, tile=meta.tile)
    new_w = (wv * mask).astype(wv.dtype)
    if isinstance(w, Boxed):
        return {**params, "w": Boxed(new_w, w.spec),
                "mask": Boxed(mask, m.spec)}
    return {**params, "w": new_w, "mask": mask}


def compress_conv_tree(params, cfg: SparsityConfig):
    """Compress every masked conv layer in a params tree to the packed
    deployment format — the last step of the conv accuracy protocol
    (``prune_conv_tree`` -> masked finetune -> ``compress_conv_tree`` ->
    compressed inference).

    Conv layer dicts carrying a ``mask`` (4-D OHWI ``w``) go through
    :func:`compress_conv_layer`, so the stored mask pins the packed support
    exactly; dense convs and linear layers pass through untouched.  Boxing
    mirrors the input: a raw-leaf (unboxed training) tree comes back with
    raw leaves, a ``Boxed`` tree stays ``Boxed``.
    """
    from repro.core.sparse_linear import unbox_tree

    def _walk(t):
        if isinstance(t, dict):
            w = t.get("w")
            wv = w.value if isinstance(w, Boxed) else w
            if w is not None and "mask" in t and getattr(wv, "ndim", 0) == 4:
                comp = compress_conv_layer(
                    t, int(wv.shape[1]), int(wv.shape[2]), cfg)
                if not isinstance(w, Boxed):
                    comp, _ = unbox_tree(comp)
                return comp
            return {k: _walk(v) for k, v in t.items()}
        if isinstance(t, list):
            return [_walk(v) for v in t]
        if isinstance(t, tuple):
            return tuple(_walk(v) for v in t)
        return t

    return _walk(params)


def prune_conv_tree(params, cfg: SparsityConfig):
    """One-shot column-wise prune a vision params tree into masked format.

    Walks the tree for conv layer dicts (4-D OHWI ``w``) and linear layer
    dicts (2-D ``w``) whose GEMM dims clear ``cfg.min_dim``, and adds a
    ``mask`` + masks ``w`` in place — the tree then has exactly the
    structure ``conv_init``/``linear_init`` emit for ``format="masked"``,
    ready for masked finetuning (``models.vision.train_step``) and for
    ``compress_conv_layer``/``compress_layer`` afterwards.  Boxed or raw
    leaves.
    """
    from repro.core.pruning import colwise_nm_mask

    def _prune_layer(layer):
        w = layer["w"]
        wv = w.value if isinstance(w, Boxed) else w
        if wv.ndim == 4:
            o, _kh, _kw, c_in = wv.shape
            d_in, d_out = _kh * _kw * c_in, o
        elif wv.ndim == 2:
            d_in, d_out = wv.shape
        else:
            return layer
        if not cfg.applies_to(d_in, d_out):
            return layer
        meta = formats.meta_for(d_in, d_out, cfg)
        if wv.ndim == 4:
            mask = conv_colwise_nm_mask(wv, cfg.sparsity, m=cfg.m,
                                        tile=meta.tile)
            mask_spec = (None, None, None, "embed")
        else:
            mask = colwise_nm_mask(wv, cfg.sparsity, m=cfg.m, tile=meta.tile)
            mask_spec = ("embed", None)
        new_w = (wv * mask).astype(wv.dtype)
        if isinstance(w, Boxed):
            return {**layer, "w": Boxed(new_w, w.spec),
                    "mask": Boxed(mask, mask_spec)}
        return {**layer, "w": new_w, "mask": mask}

    def _walk(t):
        if isinstance(t, dict):
            out = {k: _walk(v) for k, v in t.items()}
            if "w" in t and "mask" not in t:
                out = _prune_layer(out)
            return out
        if isinstance(t, list):
            return [_walk(v) for v in t]
        if isinstance(t, tuple):
            return tuple(_walk(v) for v in t)
        return t

    return _walk(params)
