"""SparseTrainer: crash-safe driver for the paper's masked/compressed
finetune loop (§4 protocol, reproduced in the conv accuracy cell).

The LM :class:`repro.train.Trainer` drives the AdamW language-model path;
this is its sparse-vision twin — the loop that matters for every pruned
deployment, because the paper's accuracy story (dense 0.953 -> one-shot
0.750 -> finetuned 0.953) puts a finetune run between pruning and serving
for every sparsity/bit-width config.  Those runs die to preemption at fleet
scale, so the whole loop is built around one contract:

    **Resume determinism.**  Kill the process at any step k, restart it with
    the same config, and the final params are *bitwise identical* to the
    uninterrupted run — the training twin of the serve scheduler's
    preempt-restore token-identity guarantee.

Everything the contract needs is checkpointed or derivable:

  * params AND momentum round-trip exactly through the integrity-verified
    :class:`~repro.train.checkpoint.CheckpointManager` (crc-manifested npz;
    int ``idx`` / ``conv_geom`` discriminator leaves and bool masks keep
    their dtypes; bf16 survives the void-dtype npz round trip);
  * data is a pure function of (seed, step) — ``vision.batch_for_step`` —
    so the pipeline "state" in the checkpoint metadata is just the step
    counter plus the seed it must match;
  * the step function is a fixed jit program (``vision.train_step`` + mask
    projection), so replaying steps k..N from a restored state is the same
    computation the uninterrupted run performed.

Fault sites: ``train.step`` probes at the top of every step (chaos harness:
``scripts/train_chaos_smoke.py`` kills and restarts a real subprocess),
``data.batch`` inside the batch fetch, ``ckpt.write``/``ckpt.rename`` inside
the checkpoint writer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import VisionConfig
from repro.obs import metrics as _om
from repro.obs import trace as _ot
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import PreemptionGuard, StepWatchdog, StragglerMonitor

_C_STEPS = _om.counter("train.steps")
_G_LOSS = _om.gauge("train.loss")


@dataclasses.dataclass
class SparseTrainConfig:
    steps: int = 8              # TOTAL budget, restored progress included
    batch: int = 4
    lr: float = 0.05
    momentum: float = 0.9
    data_seed: int = 0          # batch_for_step stream; pinned in metadata
    init_seed: int = 0
    arch: str = "resnet-tiny"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0         # 0 = only the final checkpoint
    keep: int = 3
    log_every: int = 1
    watchdog_timeout_s: float = 3600.0


class SparseTrainer:
    """Drives ``vision.train_step`` (SGD/momentum + per-step mask
    projection) over any layer format ``vision_init``/``prune_conv_tree``
    produce — masked and compressed convs both backpropagate through the
    ``conv2d_sparse`` custom VJP."""

    def __init__(self, train_cfg: SparseTrainConfig = SparseTrainConfig(), *,
                 cfg: Optional[VisionConfig] = None, params=None):
        from repro.configs import get_vision_config
        from repro.core.sparse_linear import unbox_tree
        from repro.models import vision

        self.train_cfg = train_cfg
        self.cfg = cfg if cfg is not None else get_vision_config(train_cfg.arch)
        if params is None:
            params, _ = unbox_tree(
                vision.vision_init(self.cfg, jax.random.PRNGKey(train_cfg.init_seed)))
        self.params = params
        self.mom = vision.sgd_init(params)
        self.step_fn = jax.jit(
            lambda p, m, x, y: vision.train_step(
                p, m, self.cfg, x, y, lr=train_cfg.lr,
                momentum=train_cfg.momentum))
        self.start_step = 0
        self.ckpt = (CheckpointManager(train_cfg.ckpt_dir, keep=train_cfg.keep)
                     if train_cfg.ckpt_dir else None)
        self.history: list = []
        self.straggler = StragglerMonitor()
        self.preempt = PreemptionGuard()
        self.watchdog: Optional[StepWatchdog] = None

    # ------------------------------------------------------------------
    def batch_at(self, step: int):
        from repro.models import vision

        return vision.batch_for_step(self.cfg, self.train_cfg.data_seed, step,
                                     self.train_cfg.batch)

    def maybe_restore(self) -> int:
        """Restore the newest *valid* checkpoint (torn/corrupt ones are
        skipped by the manager).  Raises if the checkpointed data seed does
        not match this trainer's — resuming onto a different batch stream
        would silently break the determinism contract."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return 0
        trees, meta = self.ckpt.restore(
            None, {"params": self.params, "mom": self.mom})
        data = meta.get("data", {})
        if "seed" in data and int(data["seed"]) != self.train_cfg.data_seed:
            raise ValueError(
                f"checkpoint was trained on data seed {data['seed']}, this "
                f"trainer is configured with {self.train_cfg.data_seed}")
        self.params = jax.tree_util.tree_map(jnp.asarray, trees["params"])
        self.mom = jax.tree_util.tree_map(jnp.asarray, trees["mom"])
        self.start_step = int(meta["step"])
        return self.start_step

    def save(self, step: int, blocking: bool = True):
        if self.ckpt is None:
            return
        self.ckpt.save(
            step,
            {"params": self.params, "mom": self.mom},
            metadata={"step": step,
                      "data": {"seed": self.train_cfg.data_seed, "step": step},
                      "arch": self.cfg.name},
            blocking=blocking,
        )

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        """Train to a TOTAL budget of ``steps`` (default: config), restoring
        any checkpointed progress first — same budget semantics as
        :meth:`Trainer.run`."""
        from repro import fault as _fault

        tc = self.train_cfg
        end = steps or tc.steps
        self.preempt.install()
        self.watchdog = StepWatchdog(tc.watchdog_timeout_s).start()
        step = self.maybe_restore()
        preempted = False
        loss = float("nan")
        try:
            while step < end:
                t0 = time.perf_counter()
                _fault.maybe_fail("train.step", step=step)
                with _ot.span("train.step", step=step):
                    x, y = self.batch_at(step)
                    self.params, self.mom, loss = self.step_fn(
                        self.params, self.mom, x, y)
                _C_STEPS.inc()
                dur = time.perf_counter() - t0
                if (step % tc.log_every == 0) or step == end - 1:
                    loss = float(loss)
                    _G_LOSS.set(loss)
                    self.history.append(
                        {"step": step, "loss": loss, "sec_per_step": dur})
                self.watchdog.beat()
                self.straggler.record(step, dur)
                step += 1
                if self.ckpt and tc.ckpt_every and step % tc.ckpt_every == 0:
                    self.save(step, blocking=False)
                if self.preempt.requested:
                    preempted = True
                    break
            # final (preemption-safe) checkpoint; save() waits on any async
            # writer first, so a failed background save surfaces here.  A
            # crash mid-loop propagates WITHOUT this save — exactly a kill.
            if self.ckpt:
                self.save(step, blocking=True)
        finally:
            self.watchdog.stop()
            self.preempt.uninstall()
        return {
            "final_step": step,
            "start_step": self.start_step,
            "preempted": preempted,
            "watchdog_fired": self.watchdog.fired,
            "history": self.history,
            "loss": float(loss) if loss == loss else loss,
        }
