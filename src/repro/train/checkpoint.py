"""Mesh-agnostic, atomic, async, *integrity-verified* checkpointing.

Checkpoints store *logical* (unsharded) arrays keyed by tree path, plus a
JSON metadata blob (step, data-pipeline state, config provenance).  A restart
may therefore use a different device topology (elastic scaling): arrays are
resharded by the in_shardings of the next jit call.

Write protocol (all inside ``<dir>/tmp.<step>.<pid>``, then one atomic
rename to ``<dir>/step_<k>``):

    1. ``arrays.npz``     the payload.  numpy degrades non-native dtypes
                          (bf16) to raw void records; the bytes are exact and
                          the manifest records the logical dtype for restore.
    2. ``meta.json``      caller metadata + step + wall time.
    3. ``manifest.json``  written LAST: per-array crc32 + dtype + shape and
                          the byte size of ``arrays.npz``.  Its presence is
                          the commit marker — a directory without a parseable
                          manifest (torn write, preempted writer, truncated
                          copy) is *invalid* and restore skips it.

A preempted writer can therefore never corrupt the latest checkpoint, and a
corrupted directory (bit rot, partial rsync) is detected rather than
restored: :meth:`CheckpointManager.restore` falls back to the newest *valid*
step, and :meth:`latest_step` reports only valid directories.

Failure handling: saves may run on a daemon thread (``blocking=False``); an
exception there (disk full, injected ``ckpt.write``/``ckpt.rename`` fault)
is captured and re-raised from :meth:`wait` or the next :meth:`save` instead
of vanishing with the thread.  Orphaned ``tmp.*`` directories from writers
that died mid-save are GC'd at startup and after every successful save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zipfile
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import fault as _fault
from repro.obs import metrics as _om
from repro.obs import trace as _ot

ARRAYS = "arrays.npz"
META = "meta.json"
MANIFEST = "manifest.json"
MANIFEST_FORMAT = 1

_C_SAVED = _om.counter("ckpt.saved")
_C_INVALID = _om.counter("ckpt.invalid_skipped")
_C_TMP_GC = _om.counter("ckpt.tmp_gc")


class CheckpointError(RuntimeError):
    """A checkpoint directory failed integrity validation."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(proto, arrays: Dict[str, np.ndarray], *, cast: bool = False):
    """Rebuild ``proto``'s structure from ``arrays``, validating shape AND
    dtype per leaf.  A checkpoint whose dtype differs from the proto (bf16
    checkpoint into an f32 model or vice versa) raises unless ``cast=True``
    explicitly opts into the conversion."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(proto)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {want}")
        want_dt = getattr(leaf, "dtype", None)
        if want_dt is not None and arr.dtype != np.dtype(want_dt):
            if not cast:
                raise ValueError(
                    f"dtype mismatch for {key}: ckpt {arr.dtype} vs model "
                    f"{np.dtype(want_dt)} (pass cast=True to opt into the "
                    f"conversion)")
            arr = arr.astype(want_dt)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _array_entry(v: np.ndarray) -> Dict[str, Any]:
    return {
        "dtype": str(v.dtype),
        "shape": list(v.shape),
        "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
    }


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # any tmp.* at startup is an orphan from a writer that died mid-save
        self._gc_tmp()

    # -- save -----------------------------------------------------------
    def save(self, step: int, trees: Dict[str, Any], metadata: Optional[Dict] = None,
             blocking: bool = True):
        """trees: name -> pytree (e.g. {'params': ..., 'opt': ...}).

        Serializes against any in-flight async save first, which also
        re-raises a previous async failure — a dying writer is never silent.
        """
        self.wait()
        payload = {}
        for name, tree in trees.items():
            for k, v in _flatten(tree).items():
                payload[f"{name}|{k}"] = v
        meta = dict(metadata or {}, step=step, time=time.time())

        def write():
            _fault.maybe_fail("ckpt.write", step=step)
            tmp = self.dir / f"tmp.{step}.{os.getpid()}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / ARRAYS, **payload)
            (tmp / META).write_text(json.dumps(meta))
            # manifest last: its presence commits the directory as complete
            manifest = {
                "format": MANIFEST_FORMAT,
                "step": step,
                "arrays_bytes": (tmp / ARRAYS).stat().st_size,
                "arrays": {k: _array_entry(v) for k, v in payload.items()},
            }
            (tmp / MANIFEST).write_text(json.dumps(manifest))
            _fault.maybe_fail("ckpt.rename", step=step)
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic on POSIX
            _C_SAVED.inc()
            _ot.instant("ckpt.save", step=step, arrays=len(payload),
                        bytes=manifest["arrays_bytes"])
            self._gc()

        if blocking:
            write()
        else:
            def guarded():
                try:
                    write()
                except BaseException as e:  # noqa: BLE001 - surfaced on wait
                    self._error = e

            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()

    def wait(self):
        """Join any in-flight async save; re-raise its failure if it died."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc_tmp(self):
        """Remove orphaned ``tmp.*`` write directories.  Safe whenever no
        writer is in flight (saves serialize through :meth:`wait`)."""
        for t in self.dir.glob("tmp.*"):
            shutil.rmtree(t, ignore_errors=True)
            _C_TMP_GC.inc()

    def _gc(self):
        self._gc_tmp()
        # keep the newest `keep` VALID checkpoints: invalid (torn/corrupt)
        # directories neither count against the budget nor shield a valid
        # one from staying restorable
        valid = [d for d in sorted(self.dir.glob("step_*")) if self.validate(d) is None]
        for old in valid[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # -- validation -------------------------------------------------------
    def validate(self, d: Path, deep: bool = False) -> Optional[str]:
        """Why ``d`` is not a restorable checkpoint, or None if it is.

        Shallow (default): manifest parses, files exist, ``arrays.npz`` has
        the committed byte size — catches torn writes and truncation without
        reading array data.  ``deep=True`` additionally re-reads every array
        and checks crc32/dtype/shape against the manifest (the restore path).
        """
        try:
            manifest = json.loads((d / MANIFEST).read_text())
        except (OSError, ValueError):
            return "missing or unparseable manifest.json"
        if not isinstance(manifest.get("arrays"), dict):
            return "manifest has no arrays table"
        if not (d / META).is_file():
            return "missing meta.json"
        try:
            size = (d / ARRAYS).stat().st_size
        except OSError:
            return "missing arrays.npz"
        if size != manifest.get("arrays_bytes"):
            return (f"arrays.npz is {size} bytes, manifest committed "
                    f"{manifest.get('arrays_bytes')}")
        if not deep:
            return None
        try:
            self._load_arrays(d, manifest)
        except (CheckpointError, OSError, ValueError) as e:
            return str(e)
        return None

    def _load_arrays(self, d: Path, manifest: Dict) -> Dict[str, np.ndarray]:
        """Load + integrity-check every array against the manifest."""
        try:
            with np.load(d / ARRAYS, allow_pickle=False) as z:
                arrays = {k: z[k] for k in z.files}
        except (OSError, ValueError, zlib.error, zipfile.BadZipFile) as e:
            raise CheckpointError(f"unreadable arrays.npz in {d.name}: {e}")
        for key, ent in manifest["arrays"].items():
            if key not in arrays:
                raise CheckpointError(f"{d.name}: array {key!r} missing")
            v = arrays[key]
            want_dt = np.dtype(ent["dtype"])
            if v.dtype != want_dt:
                # npz round-trips non-native dtypes (bf16) as raw void
                # records of the same width; view restores the logical dtype
                if v.dtype.kind == "V" and v.dtype.itemsize == want_dt.itemsize:
                    v = v.view(want_dt)
                else:
                    raise CheckpointError(
                        f"{d.name}: {key!r} stored as {v.dtype}, manifest "
                        f"says {want_dt}")
            if tuple(v.shape) != tuple(ent["shape"]):
                raise CheckpointError(
                    f"{d.name}: {key!r} shape {v.shape} vs manifest "
                    f"{tuple(ent['shape'])}")
            if zlib.crc32(np.ascontiguousarray(v).tobytes()) != ent["crc32"]:
                raise CheckpointError(f"{d.name}: {key!r} checksum mismatch")
            arrays[key] = v
        return arrays

    # -- restore ----------------------------------------------------------
    def _step_dirs(self) -> List[Path]:
        return sorted(self.dir.glob("step_*"), reverse=True)

    def valid_steps(self) -> List[int]:
        """Steps of every (shallow-)valid checkpoint, newest first."""
        return [int(d.name.split("_")[1]) for d in self._step_dirs()
                if self.validate(d) is None]

    def latest_step(self) -> Optional[int]:
        """Newest *valid* step (torn/corrupt directories are skipped)."""
        steps = self.valid_steps()
        return steps[0] if steps else None

    def restore(self, step: Optional[int], protos: Dict[str, Any], *,
                cast: bool = False) -> Tuple[Dict[str, Any], Dict]:
        """protos: name -> pytree of arrays or ShapeDtypeStructs (structure +
        shape/dtype source). Returns (trees, metadata).

        ``step=None`` restores the newest checkpoint that passes deep
        integrity validation, skipping (and reporting via obs) any torn or
        corrupted newer directory; an explicit ``step`` that fails validation
        raises :class:`CheckpointError`.  ``cast=True`` opts into dtype
        conversion when the checkpoint and proto dtypes differ.
        """
        if step is not None:
            d = self.dir / f"step_{step:08d}"
            if not d.is_dir():
                raise FileNotFoundError(f"no checkpoint for step {step} in {self.dir}")
            reason = self.validate(d)
            if reason is not None:
                raise CheckpointError(f"checkpoint {d.name} invalid: {reason}")
            manifest = json.loads((d / MANIFEST).read_text())
            arrays = self._load_arrays(d, manifest)
            return self._build(d, arrays, protos, cast)
        tried = []
        for d in self._step_dirs():
            reason = self.validate(d)
            if reason is None:
                try:
                    manifest = json.loads((d / MANIFEST).read_text())
                    arrays = self._load_arrays(d, manifest)
                    return self._build(d, arrays, protos, cast)
                except CheckpointError as e:
                    reason = str(e)
            tried.append(f"{d.name}: {reason}")
            _C_INVALID.inc()
            _ot.instant("ckpt.invalid", dir=d.name, reason=reason[:200])
        if tried:
            raise CheckpointError(
                f"no valid checkpoint in {self.dir}; skipped: {tried}")
        raise FileNotFoundError(f"no checkpoints in {self.dir}")

    def _build(self, d: Path, arrays: Dict[str, np.ndarray],
               protos: Dict[str, Any], cast: bool) -> Tuple[Dict[str, Any], Dict]:
        meta = json.loads((d / META).read_text())
        out = {}
        for name, proto in protos.items():
            sub = {
                k.split("|", 1)[1]: v for k, v in arrays.items() if k.startswith(name + "|")
            }
            out[name] = _unflatten_like(proto, sub, cast=cast)
        return out, meta
