"""Mesh-agnostic, atomic, async checkpointing.

Checkpoints store *logical* (unsharded) arrays keyed by tree path, plus a
JSON metadata blob (step, data-pipeline state, config provenance).  A restart
may therefore use a different device topology (elastic scaling): arrays are
resharded by the in_shardings of the next jit call.

Write protocol: serialize to ``<dir>/tmp.<step>``, fsync, atomic rename to
``<dir>/step_<k>`` — a preempted writer can never corrupt the latest
checkpoint.  Saves run on a daemon thread (async) with a join on exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(proto, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(proto)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, trees: Dict[str, Any], metadata: Optional[Dict] = None,
             blocking: bool = True):
        """trees: name -> pytree (e.g. {'params': ..., 'opt': ...})."""
        payload = {}
        for name, tree in trees.items():
            for k, v in _flatten(tree).items():
                payload[f"{name}|{k}"] = v
        meta = dict(metadata or {}, step=step, time=time.time())

        def write():
            tmp = self.dir / f"tmp.{step}.{os.getpid()}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / "arrays.npz", **payload)
            (tmp / "meta.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic on POSIX
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*"))
        for old in ckpts[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("step_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, step: Optional[int], protos: Dict[str, Any]) -> Tuple[Dict[str, Any], Dict]:
        """protos: name -> pytree of arrays or ShapeDtypeStructs (structure +
        shape source). Returns (trees, metadata)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        with np.load(d / "arrays.npz", allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        meta = json.loads((d / "meta.json").read_text())
        out = {}
        for name, proto in protos.items():
            sub = {
                k.split("|", 1)[1]: v for k, v in arrays.items() if k.startswith(name + "|")
            }
            out[name] = _unflatten_like(proto, sub)
        return out, meta
