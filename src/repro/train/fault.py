"""Fault-tolerance machinery: preemption handling, step watchdog, straggler
log.

At 1000+ nodes the relevant failure modes are (a) preemption (SIGTERM with a
grace window), (b) hung collectives / dead hosts (steps stop completing),
(c) stragglers (steps complete but slowly on some hosts).  The trainer wires
these as:
  - PreemptionGuard: SIGTERM/SIGINT -> request a final checkpoint + clean exit
  - StepWatchdog: a daemon thread that aborts the process (so the cluster
    scheduler restarts it from the last checkpoint) if no step completes
    within `timeout_s` — the restart-from-checkpoint path IS the recovery
    mechanism for hung collectives
  - StragglerMonitor: per-step durations; steps slower than `factor` x the
    rolling median are logged (on real fleets this feeds host-quarantine)
"""
from __future__ import annotations

import os
import signal
import threading
import time
from collections import deque
from typing import Callable, Deque, List, Optional


class PreemptionGuard:
    def __init__(self):
        self.requested = False
        self._orig = {}

    def install(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig[sig] = signal.signal(sig, self._handler)
            except ValueError:
                pass  # non-main thread (tests)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


class StepWatchdog:
    """Aborts the process if no heartbeat arrives within timeout_s.

    The default abort is not a bare ``os._exit``: it first emits a
    ``fault.watchdog`` obs instant and, when a trace sink is armed
    (``REPRO_OBS_TRACE``), dumps the trace ring — ``os._exit`` skips atexit
    handlers, so without the explicit dump a hung run's trace (the one
    artifact that says *where* it hung) would be lost.
    """

    def __init__(self, timeout_s: float = 1800.0, abort: Optional[Callable] = None):
        self.timeout_s = timeout_s
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._abort = abort or self._default_abort
        self._thread: Optional[threading.Thread] = None
        self.fired = False

    def _default_abort(self):
        from repro import env as _env
        from repro.obs import trace as _ot

        _ot.instant("fault.watchdog", timeout_s=self.timeout_s)
        path = _env.get("REPRO_OBS_TRACE")
        if path:
            try:
                _ot.dump_chrome_trace(path)
            except OSError:
                pass  # aborting anyway; never mask the exit
        os._exit(42)

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    def stop(self):
        self._stop.set()

    def _run(self):
        while not self._stop.wait(min(self.timeout_s / 4, 5.0)):
            if time.monotonic() - self._last > self.timeout_s:
                self.fired = True
                self._abort()
                return


class StragglerMonitor:
    def __init__(self, window: int = 50, factor: float = 2.0):
        self.durations: Deque[float] = deque(maxlen=window)
        self.factor = factor
        self.events: List[dict] = []

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step was a straggler."""
        is_straggler = False
        if len(self.durations) >= 8:
            med = sorted(self.durations)[len(self.durations) // 2]
            if duration_s > self.factor * med:
                is_straggler = True
                self.events.append(
                    {"step": step, "duration_s": duration_s, "median_s": med}
                )
        self.durations.append(duration_s)
        return is_straggler

    @property
    def median(self) -> float:
        if not self.durations:
            return 0.0
        return sorted(self.durations)[len(self.durations) // 2]
