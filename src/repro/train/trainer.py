"""Trainer: jitted step, deterministic data, async checkpoints, preemption /
watchdog / straggler instrumentation, elastic restart.

Runs unchanged from 1 CPU device (tests, examples) to the production mesh
(the launcher installs the ShardingCtx + shardings; the step builder is the
same one the dry-run compiles for 512 chips).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import registry as reg
from repro.optim import AdamWConfig, adamw_init
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import PreemptionGuard, StepWatchdog, StragglerMonitor


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    watchdog_timeout_s: float = 3600.0
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        data_cfg: DataConfig,
        opt_cfg: AdamWConfig = AdamWConfig(),
        train_cfg: TrainConfig = TrainConfig(),
        params=None,
    ):
        self.cfg = cfg
        self.train_cfg = train_cfg
        self.data = SyntheticLM(data_cfg)
        if params is None:
            params, _ = reg.init_params(cfg, jax.random.PRNGKey(train_cfg.seed))
        self.params = params
        self.opt_state = adamw_init(params)
        self.step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=train_cfg.microbatches),
            donate_argnums=(0, 1),
        )
        self.start_step = 0
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir) if train_cfg.ckpt_dir else None
        self.history: list[Dict[str, float]] = []
        self.straggler = StragglerMonitor()
        self.preempt = PreemptionGuard()
        self.watchdog: Optional[StepWatchdog] = None

    # ------------------------------------------------------------------
    def maybe_restore(self) -> int:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return 0
        trees, meta = self.ckpt.restore(
            None, {"params": self.params, "opt": self.opt_state}
        )
        self.params = jax.tree_util.tree_map(jax.numpy.asarray, trees["params"])
        self.opt_state = jax.tree_util.tree_map(jax.numpy.asarray, trees["opt"])
        self.start_step = int(meta["step"])
        return self.start_step

    def save(self, step: int, blocking: bool = True):
        if self.ckpt is None:
            return
        self.ckpt.save(
            step,
            {"params": self.params, "opt": self.opt_state},
            metadata={"step": step, "data": self.data.state_dict(step),
                      "arch": self.cfg.name},
            blocking=blocking,
        )

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        """Train to a TOTAL budget of ``steps``.

        ``steps`` counts from step 0 including restored progress: a run
        killed at step k and restarted with the same budget completes the
        original schedule (trains ``steps - k`` more), it does not train
        ``steps`` *additional* steps.  A restore at or past the budget
        trains nothing and returns immediately after the final checkpoint.
        """
        from repro import fault as _fault

        steps = steps or self.train_cfg.steps
        self.preempt.install()
        self.watchdog = StepWatchdog(self.train_cfg.watchdog_timeout_s).start()
        step = self.maybe_restore()
        end = steps
        preempted = False
        try:
            while step < end:
                t0 = time.perf_counter()
                _fault.maybe_fail("train.step", step=step)
                batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch_at(step).items()}
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch
                )
                if (step % self.train_cfg.log_every == 0) or step == end - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    dur = time.perf_counter() - t0
                    m.update(step=step, sec_per_step=dur)
                    self.history.append(m)
                self.watchdog.beat()
                self.straggler.record(step, time.perf_counter() - t0)
                step += 1
                if self.ckpt and step % self.train_cfg.ckpt_every == 0:
                    self.save(step, blocking=False)
                if self.preempt.requested:
                    preempted = True
                    break
            # final (preemption-safe) checkpoint; save() drains the async
            # writer first, so a failed background save surfaces here.  A
            # crash mid-loop propagates WITHOUT this save — exactly a kill.
            if self.ckpt:
                self.save(step, blocking=True)
        finally:
            self.watchdog.stop()
            self.preempt.uninstall()
        return {
            "final_step": step,
            "start_step": self.start_step,
            "preempted": preempted,
            "watchdog_fired": self.watchdog.fired,
            "history": self.history,
            "stragglers": self.straggler.events,
        }
