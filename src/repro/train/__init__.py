from repro.train.checkpoint import CheckpointError, CheckpointManager  # noqa: F401
from repro.train.fault import PreemptionGuard, StepWatchdog, StragglerMonitor  # noqa: F401
from repro.train.sparse import SparseTrainConfig, SparseTrainer  # noqa: F401
from repro.train.trainer import TrainConfig, Trainer  # noqa: F401
