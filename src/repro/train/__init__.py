from repro.train.checkpoint import CheckpointManager  # noqa: F401
from repro.train.fault import PreemptionGuard, StepWatchdog, StragglerMonitor  # noqa: F401
from repro.train.trainer import TrainConfig, Trainer  # noqa: F401
