"""Gradient compression for the slow cross-pod links: int8 quantization with
error feedback.

At (2, 16, 16) the data-parallel reduction crosses the inter-pod DCN/ICI
boundary, which is far slower per byte than in-pod ICI.  The standard trick:
reduce in full precision *within* a pod, quantize to int8 for the *cross-pod*
leg, and carry the quantization error into the next step (error feedback
keeps SGD unbiased in the long run — Karimireddy et al., 2019).

Used by the trainer as a drop-in around the pod-axis psum inside shard_map;
the quantizer itself is pure and unit-tested on CPU.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: jax.Array, error: jax.Array):
    """(grad + carried error) -> (int8 payload, scale, new error)."""
    g = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale)
    return q, scale, g - deq


def crosspod_psum_compressed(grad: jax.Array, error: jax.Array, axis: str = "pod"):
    """Inside shard_map: error-feedback int8 all-reduce over `axis`.

    Returns (reduced_grad fp32, new_error). The int8 payload crosses the
    slow link; scales are reduced at negligible cost.
    """
    q, scale, new_error = compress_with_feedback(grad, error)
    # each pod contributes q*scale; sum of dequantized terms == psum of
    # per-pod dequantized gradients
    part = dequantize_int8(q, scale)
    reduced = jax.lax.psum(part, axis)
    return reduced, new_error


def wire_bytes_saved(shape, dtype=jnp.float32) -> Tuple[int, int]:
    """(bytes_uncompressed, bytes_compressed) per hop for reporting."""
    n = 1
    for d in shape:
        n *= d
    return n * jnp.dtype(dtype).itemsize, n * 1 + 4
