"""AdamW from scratch (no optax in this environment).

- skips integer leaves (the compressed format's ``idx`` arrays ride along in
  the param tree but are not trained),
- keeps an fp32 master copy when params are stored in a lower precision
  (mixed-precision training),
- m/v/master inherit the params' logical sharding specs; the trainer adds the
  ZeRO 'data' axis via the normal FSDP rules (they shard like params).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def _is_float(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params, keep_master: Optional[bool] = None) -> Dict[str, Any]:
    if keep_master is None:
        keep_master = any(
            _is_float(l) and l.dtype != jnp.float32 for l in jax.tree_util.tree_leaves(params)
        )
    # int leaves (compressed idx arrays) get same-shape zero slots so the
    # optimizer-state tree shares the params' sharding-spec tree exactly.
    # Every array is freshly allocated — m/v/master must never alias params
    # or each other (argument donation would otherwise donate a buffer twice).
    def zeros_for(p):
        return jnp.zeros(p.shape, jnp.float32 if _is_float(p) else jnp.int8)

    state = {
        "m": jax.tree_util.tree_map(zeros_for, params),
        "v": jax.tree_util.tree_map(zeros_for, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if keep_master:
        state["master"] = jax.tree_util.tree_map(
            lambda p: jnp.array(p, dtype=jnp.float32 if _is_float(p) else p.dtype,
                                copy=True),
            params,
        )
    return state


def global_norm(tree) -> jax.Array:
    leaves = [l for l in jax.tree_util.tree_leaves(tree) if _is_float(l)]
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return (
        jax.tree_util.tree_map(
            lambda g: g * scale.astype(g.dtype) if _is_float(g) else g, grads
        ),
        norm,
    )


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    has_master = "master" in state
    ref = state["master"] if has_master else params

    def upd(p, g, m, v, mp):
        if not _is_float(p):
            return p, m, v, mp
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        base = mp if has_master else p.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * base)
        return new.astype(p.dtype), m2, v2, (new if has_master else mp)

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"], ref)
    # unzip the 4-tuples
    treedef = jax.tree_util.tree_structure(params)
    flat = treedef.flatten_up_to(out)
    new_p = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])
    new_state = {"m": new_m, "v": new_v, "step": step}
    if has_master:
        new_state["master"] = treedef.unflatten([t[3] for t in flat])
    return new_p, new_state, gnorm


def opt_state_specs(param_specs):
    """Logical specs for the optimizer state mirroring the params."""
    zero_spec = ()

    def f(spec):
        return spec

    m_specs = jax.tree_util.tree_map(f, param_specs, is_leaf=lambda s: isinstance(s, tuple))
    return {"m": m_specs, "v": m_specs, "step": (), "master": m_specs}
