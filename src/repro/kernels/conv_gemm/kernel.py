"""Pallas conv megakernel: fused im2col + pack + column-wise N:M sparse GEMM.

The paper's two building blocks (Algorithm 2's fused im2col+packing and
Algorithm 1's column-wise sparse micro-kernel) are here collapsed into ONE
kernel: each packed strip tile is *produced in VMEM* — (kh, kw, c) rows
gathered straight from the CNHW feature map with the same index arithmetic as
``im2col_pack/kernel.py`` — and immediately consumed by the in-VMEM
kept-column gather + dense MXU matmul of ``colwise_nm/kernel.py``.  The patch
matrix / packed strips never exist in HBM, and because only the *kept* rows of
each strip are ever materialized, the gather itself is the sparse compression:

  two-kernel path   HBM traffic:  write strips, read strips (transposed
                    relayout!), write GEMM output          — 3 round-trips
  this megakernel   HBM traffic:  read feature map, write output — 0 extra

Grid: (n_strips, n_tiles, k_chunks).  Step (s, t, kc) gathers the block_k
kept rows of chunk kc for output tile t, restricted to strip s's V output
positions, multiplies by the [block_k, T] compressed weight chunk, and
accumulates into a float32 [T, V] VMEM scratch.  The output is written
directly in [O, P] layout (P padded to n_strips*V), so the caller's final
``y.T`` relayout disappears as well.  Ragged final strips and out-of-map
(kh, kw) taps are handled with iota-compare masks exactly as in the
standalone pack kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import COMPILER_PARAMS as _COMPILER_PARAMS
from repro.kernels.pltpu_compat import ceil_to, dot_f32

from repro.kernels.im2col_pack.kernel import strip_tap_coords
from repro.kernels.im2col_pack.ref import out_size


def _kernel(
    x_ref,
    idx_ref,
    v_ref,
    o_ref,
    acc_ref,
    *,
    kh: int,
    kw: int,
    stride: int,
    pad: int,
    v: int,
    c: int,
    b: int,
    h: int,
    w: int,
    ho: int,
    wo: int,
    n_kc: int,
    out_dtype,
    interpret: bool,
):
    s = pl.program_id(0)
    kc = pl.program_id(2)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = idx_ref[0]  # [block_k] kept (kh, kw, c) row ids for this chunk
    k_of = ids // c  # kernel-tap index ikh*kw + ikw
    c_of = ids % c
    # [block_k, v] source coordinates: row j of the strip tile reads input
    # channel c_of[j] at tap (ikh[j], ikw[j]) of every position in the strip
    # (shared im2col index arithmetic — see im2col_pack.kernel)
    valid, bc, ihc, iwc = strip_tap_coords(
        s, v=v, ikh=(k_of // kw)[:, None], ikw=(k_of % kw)[:, None],
        stride=stride, pad=pad, b=b, h=h, w=w, ho=ho, wo=wo)
    # flat gather from the VMEM-resident feature map — the packed strip tile
    # is born here and never touches HBM
    flat = x_ref[...].reshape(c * b * h * w)
    fidx = ((c_of[:, None] * b + bc[None, :]) * h + ihc) * w + iwc
    patch = jnp.where(valid, jnp.take(flat, fidx), 0)  # [block_k, v]

    acc_ref[...] += dot_f32(v_ref[0].T, patch, interpret)  # [tile, v]

    @pl.when(kc == n_kc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def conv2d_fused_pallas(
    x: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused conv: CNHW map -> [O, n_strips*V] sparse-GEMM output.

    x: [C, B, H, W]; values: [n_tiles, k_kept, T]; idx: [n_tiles, k_kept]
    with kept rows indexed in the (kh, kw, c)-flattened reduction dim.
    Columns past B*Ho*Wo are strip padding (zeros); the ops wrapper slices
    them off and reshapes to CNHW.
    """
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    n_pos = b * ho * wo
    n_strips = -(-n_pos // v)
    n_tiles, k_kept, tile = values.shape
    assert idx.shape == (n_tiles, k_kept), (idx.shape, values.shape)

    block_k = min(block_k, ceil_to(k_kept, 8))
    k_pad = ceil_to(k_kept, block_k)
    if k_pad != k_kept:
        # zero-valued padding rows gather row 0 but multiply by 0 weights
        values = jnp.pad(values, ((0, 0), (0, k_pad - k_kept), (0, 0)))
        idx = jnp.pad(idx, ((0, 0), (0, k_pad - k_kept)))
    n_kc = k_pad // block_k

    grid = (n_strips, n_tiles, n_kc)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, stride=stride, pad=pad, v=v,
            c=c, b=b, h=h, w=w, ho=ho, wo=wo, n_kc=n_kc,
            out_dtype=x.dtype, interpret=interpret,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, b, h, w), lambda s, t, kc: (0, 0, 0, 0)),
            pl.BlockSpec((1, block_k), lambda s, t, kc: (t, kc)),
            pl.BlockSpec((1, block_k, tile), lambda s, t, kc: (t, kc, 0)),
        ],
        out_specs=pl.BlockSpec((tile, v), lambda s, t, kc: (t, s)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile, n_strips * v), x.dtype),
        scratch_shapes=[pltpu.VMEM((tile, v), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, idx, values)
    return out


def fused_vmem_bytes(c: int, b: int, h: int, w: int, v: int, block_k: int,
                     tile: int, in_bytes: int = 2) -> int:
    """Analytic VMEM footprint of one megakernel grid step: the whole CNHW
    feature map stays resident (it is the only input the kernel reads), plus
    the gathered strip tile, weight chunk, accumulator and output tile."""
    fmap = c * b * h * w * in_bytes
    patch = block_k * v * in_bytes
    v_blk = block_k * tile * in_bytes
    acc = tile * v * 4
    out = tile * v * in_bytes
    return fmap + patch + v_blk + acc + out
