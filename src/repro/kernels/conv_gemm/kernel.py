"""Pallas conv megakernel: fused im2col + pack + column-wise N:M sparse GEMM.

The paper's two building blocks (Algorithm 2's fused im2col+packing and
Algorithm 1's column-wise sparse micro-kernel) are here collapsed into ONE
kernel: each packed strip tile is *produced in VMEM* — (kh, kw, c) rows
gathered straight from the CNHW feature map with the same index arithmetic as
``im2col_pack/kernel.py`` — and immediately consumed by the in-VMEM
kept-column gather + dense MXU matmul of ``colwise_nm/kernel.py``.  The patch
matrix / packed strips never exist in HBM, and because only the *kept* rows of
each strip are ever materialized, the gather itself is the sparse compression:

  two-kernel path   HBM traffic:  write strips, read strips (transposed
                    relayout!), write GEMM output          — 3 round-trips
  this megakernel   HBM traffic:  read feature map, write output — 0 extra

Grid: (n_strips, n_tiles, k_chunks).  Step (s, t, kc) gathers the block_k
kept rows of chunk kc for output tile t, restricted to strip s's V output
positions, multiplies by the [block_k, T] compressed weight chunk, and
accumulates into a float32 [T, V] VMEM scratch.  The output is written
directly in [O, P] layout (P padded to n_strips*V), so the caller's final
``y.T`` relayout disappears as well.  Ragged final strips and out-of-map
(kh, kw) taps are handled with iota-compare masks exactly as in the
standalone pack kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import COMPILER_PARAMS as _COMPILER_PARAMS
from repro.kernels.pltpu_compat import (
    MEM_ANY,
    ceil_to,
    dma_semaphores,
    dot_f32,
    double_buffer_rotate,
    make_async_copy,
)

from repro.kernels.im2col_pack.kernel import strip_tap_coords
from repro.kernels.im2col_pack.ref import out_size


def _kernel(
    x_ref,
    idx_ref,
    v_ref,
    o_ref,
    acc_ref,
    *,
    kh: int,
    kw: int,
    stride: int,
    pad: int,
    v: int,
    c: int,
    b: int,
    h: int,
    w: int,
    ho: int,
    wo: int,
    n_kc: int,
    out_dtype,
    interpret: bool,
):
    s = pl.program_id(0)
    kc = pl.program_id(2)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = idx_ref[0]  # [block_k] kept (kh, kw, c) row ids for this chunk
    k_of = ids // c  # kernel-tap index ikh*kw + ikw
    c_of = ids % c
    # [block_k, v] source coordinates: row j of the strip tile reads input
    # channel c_of[j] at tap (ikh[j], ikw[j]) of every position in the strip
    # (shared im2col index arithmetic — see im2col_pack.kernel)
    valid, bc, ihc, iwc = strip_tap_coords(
        s, v=v, ikh=(k_of // kw)[:, None], ikw=(k_of % kw)[:, None],
        stride=stride, pad=pad, b=b, h=h, w=w, ho=ho, wo=wo)
    # flat gather from the VMEM-resident feature map — the packed strip tile
    # is born here and never touches HBM
    flat = x_ref[...].reshape(c * b * h * w)
    fidx = ((c_of[:, None] * b + bc[None, :]) * h + ihc) * w + iwc
    patch = jnp.where(valid, jnp.take(flat, fidx), 0)  # [block_k, v]

    acc_ref[...] += dot_f32(v_ref[0].T, patch, interpret)  # [tile, v]

    @pl.when(kc == n_kc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def conv2d_fused_pallas(
    x: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused conv: CNHW map -> [O, n_strips*V] sparse-GEMM output.

    x: [C, B, H, W]; values: [n_tiles, k_kept, T]; idx: [n_tiles, k_kept]
    with kept rows indexed in the (kh, kw, c)-flattened reduction dim.
    Columns past B*Ho*Wo are strip padding (zeros); the ops wrapper slices
    them off and reshapes to CNHW.
    """
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    n_pos = b * ho * wo
    n_strips = -(-n_pos // v)
    n_tiles, k_kept, tile = values.shape
    assert idx.shape == (n_tiles, k_kept), (idx.shape, values.shape)

    block_k = min(block_k, ceil_to(k_kept, 8))
    k_pad = ceil_to(k_kept, block_k)
    if k_pad != k_kept:
        # zero-valued padding rows gather row 0 but multiply by 0 weights
        values = jnp.pad(values, ((0, 0), (0, k_pad - k_kept), (0, 0)))
        idx = jnp.pad(idx, ((0, 0), (0, k_pad - k_kept)))
    n_kc = k_pad // block_k

    grid = (n_strips, n_tiles, n_kc)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, stride=stride, pad=pad, v=v,
            c=c, b=b, h=h, w=w, ho=ho, wo=wo, n_kc=n_kc,
            out_dtype=x.dtype, interpret=interpret,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, b, h, w), lambda s, t, kc: (0, 0, 0, 0)),
            pl.BlockSpec((1, block_k), lambda s, t, kc: (t, kc)),
            pl.BlockSpec((1, block_k, tile), lambda s, t, kc: (t, kc, 0)),
        ],
        out_specs=pl.BlockSpec((tile, v), lambda s, t, kc: (t, s)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile, n_strips * v), x.dtype),
        scratch_shapes=[pltpu.VMEM((tile, v), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, idx, values)
    return out


def fused_vmem_bytes(c: int, b: int, h: int, w: int, v: int, block_k: int,
                     tile: int, in_bytes: int = 2) -> int:
    """Analytic VMEM footprint of one megakernel grid step: the whole CNHW
    feature map stays resident (it is the only input the kernel reads), plus
    the gathered strip tile, weight chunk, accumulator and output tile."""
    fmap = c * b * h * w * in_bytes
    patch = block_k * v * in_bytes
    v_blk = block_k * tile * in_bytes
    acc = tile * v * 4
    out = tile * v * in_bytes
    return fmap + patch + v_blk + acc + out


# ---------------------------------------------------------------------------
# Banded megakernel: H-tiled variant — only a row band of the map is resident
# ---------------------------------------------------------------------------


def band_plan(*, b: int, h: int, kh: int, stride: int, pad: int, ho: int,
              wo: int, v: int, hb: int):
    """Static band geometry for the banded megakernel.

    A *band* groups ``hb`` consecutive strips (``hb*v`` output positions).
    In the flattened ``(batch*h)`` input-row space the rows a band's strips
    read are contiguous (consecutive output positions advance monotonically
    through ``bb*h + oh*stride``, including across batch boundaries), so each
    band needs one contiguous row window of roughly
    ``stride * ceil(hb*v / wo) + kh - 1`` rows (the strip rows plus the
    kh-1 halo).  Returns ``(n_bands, band_rows)`` with ``band_rows`` the
    exact maximum over bands (ragged final band included), clamped to the
    full ``b*h`` — the static size of the double-buffered VMEM scratch.
    """
    n_pos = b * ho * wo
    n_strips = -(-n_pos // v)
    hb = max(min(hb, n_strips), 1)
    n_bands = -(-n_strips // hb)
    bh = b * h

    def first_row(p):  # top input row touched by output position p (tap 0)
        bb, rem = divmod(p, ho * wo)
        return bb * h + (rem // wo) * stride - pad

    rows = 1
    for g in range(n_bands):
        p0 = g * hb * v
        p1 = min((g + 1) * hb * v, n_pos) - 1
        r0 = max(first_row(p0), 0)
        r1 = min(first_row(p1) + kh - 1, bh - 1)
        rows = max(rows, r1 - r0 + 1)
    return n_bands, min(rows, bh)


def _band_origin(g, *, hb, v, h, ho, wo, pad, stride, bh, band_rows):
    """First flattened (batch*h) input row of band ``g``'s scratch window —
    the traced twin of ``band_plan``'s ``first_row``/clamp arithmetic (the
    kernel recomputes it per band; the DMA start and wait descriptors must
    agree exactly)."""
    p0 = g * (hb * v)
    bb0 = p0 // (ho * wo)
    oh0 = (p0 % (ho * wo)) // wo
    r0 = jnp.maximum(bb0 * h + oh0 * stride - pad, 0)
    # clamp so the fixed-size window never reads past the map's last row; the
    # window then starts *earlier* than needed, which only widens coverage
    return jnp.minimum(r0, bh - band_rows)


def _banded_kernel(
    x_ref,        # [C, B*H, W] feature map, NOT block-mapped (HBM / ANY)
    idx_ref,
    v_ref,
    o_ref,
    band_ref,     # [2, C, band_rows, W] double-buffered row-band scratch
    sem_ref,      # [2] DMA completion semaphores
    acc_ref,
    *,
    kh: int,
    kw: int,
    stride: int,
    pad: int,
    v: int,
    hb: int,
    band_rows: int,
    n_bands: int,
    c: int,
    b: int,
    h: int,
    w: int,
    ho: int,
    wo: int,
    n_kc: int,
    out_dtype,
    interpret: bool,
):
    s = pl.program_id(0)
    t = pl.program_id(1)
    kc = pl.program_id(2)
    g = s // hb
    bh = b * h

    def origin(gi):
        return _band_origin(gi, hb=hb, v=v, h=h, ho=ho, wo=wo, pad=pad,
                            stride=stride, bh=bh, band_rows=band_rows)

    def band_dma(slot, gi):
        return make_async_copy(
            x_ref.at[:, pl.ds(origin(gi), band_rows), :],
            band_ref.at[slot],
            sem_ref.at[slot],
        )

    # Double buffering: at the first grid step of band g, kick off the DMA
    # for band g+1, THEN block on band g's copy — band g+1's rows stream in
    # while the (n_tiles * n_kc * hb-strip) GEMM steps of band g run.
    double_buffer_rotate(band_dma, g, n_bands,
                         gate=(s % hb == 0) & (t == 0) & (kc == 0))

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = idx_ref[0]  # [block_k] kept (kh, kw, c) row ids for this chunk
    k_of = ids // c
    c_of = ids % c
    # band-local im2col coordinates: same index arithmetic as the resident
    # megakernel, with rows rebased to this band's scratch window
    org = origin(g)
    valid, rowc, iwc = strip_tap_coords(
        s, v=v, ikh=(k_of // kw)[:, None], ikw=(k_of % kw)[:, None],
        stride=stride, pad=pad, b=b, h=h, w=w, ho=ho, wo=wo,
        band_origin=org, band_rows=band_rows)
    flat = band_ref[g % 2].reshape(c * band_rows * w)
    fidx = (c_of[:, None] * band_rows + rowc) * w + iwc
    patch = jnp.where(valid, jnp.take(flat, fidx), 0)  # [block_k, v]

    acc_ref[...] += dot_f32(v_ref[0].T, patch, interpret)  # [tile, v]

    @pl.when(kc == n_kc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def conv2d_fused_banded_pallas(
    x: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    block_k: int = 128,
    hb: int = 2,
    interpret: bool = False,
) -> jax.Array:
    """H-tiled fused conv: like :func:`conv2d_fused_pallas`, but the feature
    map stays in HBM and only a double-buffered row band is VMEM-resident.

    The map is viewed as [C, B*H, W]; each band (``hb`` strips) DMAs its
    ``band_rows`` contiguous input rows (strip rows + kh-1 halo) into one of
    two scratch slots with ``make_async_copy`` while the previous band's
    gather + Algorithm-1 MXU loop runs.  Output layout and semantics are
    identical to the resident megakernel — [O, n_strips*V], strip padding
    sliced off by the ops wrapper.
    """
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    n_pos = b * ho * wo
    n_strips = -(-n_pos // v)
    n_tiles, k_kept, tile = values.shape
    assert idx.shape == (n_tiles, k_kept), (idx.shape, values.shape)

    hb = max(min(hb, n_strips), 1)
    n_bands, band_rows = band_plan(b=b, h=h, kh=kh, stride=stride, pad=pad,
                                   ho=ho, wo=wo, v=v, hb=hb)

    block_k = min(block_k, ceil_to(k_kept, 8))
    k_pad = ceil_to(k_kept, block_k)
    if k_pad != k_kept:
        values = jnp.pad(values, ((0, 0), (0, k_pad - k_kept), (0, 0)))
        idx = jnp.pad(idx, ((0, 0), (0, k_pad - k_kept)))
    n_kc = k_pad // block_k

    grid = (n_strips, n_tiles, n_kc)
    out = pl.pallas_call(
        functools.partial(
            _banded_kernel, kh=kh, kw=kw, stride=stride, pad=pad, v=v,
            hb=hb, band_rows=band_rows, n_bands=n_bands,
            c=c, b=b, h=h, w=w, ho=ho, wo=wo, n_kc=n_kc,
            out_dtype=x.dtype, interpret=interpret,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=MEM_ANY),  # map stays in HBM
            pl.BlockSpec((1, block_k), lambda s, t, kc: (t, kc)),
            pl.BlockSpec((1, block_k, tile), lambda s, t, kc: (t, kc, 0)),
        ],
        out_specs=pl.BlockSpec((tile, v), lambda s, t, kc: (t, s)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile, n_strips * v), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, c, band_rows, w), x.dtype),
            dma_semaphores(2),
            pltpu.VMEM((tile, v), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            # strips advance sequentially: the double-buffer rotation assumes
            # band g's steps complete before band g+1's begin
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x.reshape(c, b * h, w), idx, values)
    return out


def banded_vmem_bytes(c: int, w: int, band_rows: int, v: int, block_k: int,
                      tile: int, in_bytes: int = 2) -> int:
    """Analytic VMEM footprint of one banded-megakernel grid step: TWO row
    bands (double buffer) instead of the whole map, plus the same gathered
    strip tile, weight chunk, accumulator and output tile as the resident
    kernel."""
    bands = 2 * c * band_rows * w * in_bytes
    patch = block_k * v * in_bytes
    v_blk = block_k * tile * in_bytes
    acc = tile * v * 4
    out = tile * v * in_bytes
    return bands + patch + v_blk + acc + out
