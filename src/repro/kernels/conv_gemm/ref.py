"""Oracle for GEMM-based convolution in the paper's CNHW/OHWI layouts,
implemented with jax.lax.conv_general_dilated (completely independent of the
im2col/packing/sparse kernels it validates)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_cnhw_ref(
    x: jax.Array, w_ohwi: jax.Array, stride: int = 1, pad: int = 0
) -> jax.Array:
    """x: [C, B, H, W]; w: [O, Kh, Kw, C]. Returns CNHW output [O, B, Ho, Wo]."""
    out = jax.lax.conv_general_dilated(
        x,
        w_ohwi,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("CNHW", "OHWI", "CNHW"),
    )
    return out
