"""GEMM-based convolution assembled from the paper's two kernels:

  conv = fused-im2col+pack  ∘  column-wise-N:M sparse GEMM

This is the end-to-end convolution path the paper ships inside XNNPACK:
the feature map is packed into V-wide strips in one pass, then each strip is
multiplied by the (compressed) weight matrix with the Algorithm-1 micro-kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formats import ColwiseMeta, meta_for, pack_colwise
from repro.core.pruning import SparsityConfig, colwise_nm_mask
from repro.kernels.colwise_nm.ops import colwise_nm_matmul
from repro.kernels.colwise_nm.ref import colwise_nm_matmul_ref
from repro.kernels.im2col_pack.ops import im2col_pack
from repro.kernels.im2col_pack.ref import out_size


def compress_conv_weights(w_ohwi: jax.Array, cfg: SparsityConfig):
    """Prune+compress an OHWI conv kernel column-wise over (kh, kw, c).

    The GEMM weight matrix is [O, Kh*Kw*C]; tiles of T output channels share
    kept (kh, kw, c) positions. Returns (values, idx, meta) for the sparse
    GEMM where the *reduction* dim is Kh*Kw*C.
    """
    o, kh, kw, c = w_ohwi.shape
    wmat = w_ohwi.reshape(o, kh * kw * c).T  # [K, O] = [d_in, d_out]
    meta = meta_for(kh * kw * c, o, cfg)
    mask = colwise_nm_mask(wmat, cfg.sparsity, m=cfg.m, tile=meta.tile)
    values, idx = pack_colwise(wmat, mask, meta)
    return values, idx, meta


def conv2d_colwise_sparse(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """Sparse convolution: fused im2col+pack, then column-wise sparse GEMM.

    ``use_pallas=None`` (the default) consults ``repro.dispatch`` for the
    GEMM backend — profiled winner if the profile DB has this conv shape,
    platform heuristic otherwise.  Pass True/False to force a backend.
    Returns CNHW output [O, B, Ho, Wo].
    """
    c, b, h, w = x_cnhw.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    n_pos = b * ho * wo
    n_tiles, k_kept, tile = values.shape
    o = n_tiles * tile

    if use_pallas is None:
        from repro import dispatch as _dispatch

        key = _dispatch.conv_key(c, h, w, o, kh, kw, stride, pad,
                                 k_kept, tile, v=v, dtype=x_cnhw.dtype,
                                 batch=b)
        spec = _dispatch.best_impl(key, param_keys=("values", "idx"))
        use_pallas = spec.backend == "pallas"

    strips = im2col_pack(x_cnhw, kh=kh, kw=kw, stride=stride, pad=pad, v=v)
    # strips: [n_strips, K, V]; GEMM per strip on the transposed strip so the
    # kernel's batch dim is the V strip columns.
    xt = strips.transpose(0, 2, 1).reshape(-1, kh * kw * c)  # [n_strips*V, K]
    if use_pallas:
        y = colwise_nm_matmul(xt, values, idx)  # [n_strips*V, O]
    else:
        y = colwise_nm_matmul_ref(xt, values, idx)
    y = y[:n_pos]  # drop ragged strip padding
    return y.T.reshape(o, b, ho, wo)
