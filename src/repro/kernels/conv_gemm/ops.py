"""GEMM-based convolution in the paper's layouts — the conv plan ladder:

  fused megakernel : im2col + pack + sparse GEMM in ONE Pallas kernel; the
                     packed strips are produced and consumed in VMEM and
                     never exist in HBM (``conv2d_fused``); needs the whole
                     CNHW map VMEM-resident
  banded megakernel: the H-tiled variant (``conv2d_fused_banded``) — only a
                     double-buffered row band of the map is resident, DMA'd
                     per band while the previous band's GEMM runs; covers
                     stem-scale maps and batch > 1
  two-kernel       : fused im2col+pack kernel, then the strip-major sparse
                     GEMM consuming [n_strips, K, V] directly — no transpose
                     relayout between the kernels; ``conv2d_two_kernel_
                     pipelined`` overlaps the GEMM's strip loads with its
                     compute via the same double-buffered DMA scheme
  XLA reference    : pack kernel + gather-einsum GEMM (distribution-friendly)

``conv2d_colwise_sparse`` keeps the historical entry point; with
``use_pallas=None`` (default) it routes through ``repro.dispatch`` and
executes whichever registered conv candidate (including the megakernels and
their geometry variants) the profile DB / heuristic picks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formats import ColwiseMeta, meta_for, pack_colwise
from repro.core.pruning import SparsityConfig, colwise_nm_mask
from repro.kernels.colwise_nm.ops import (
    colwise_nm_matmul_strips,
    colwise_nm_matmul_strips_pipelined,
    sparse_grad_dvalues,
    sparse_grad_dxg,
)
from repro.kernels.colwise_nm.ref import colwise_nm_matmul_ref
from repro.kernels.im2col_pack.kernel import tap_coords
from repro.kernels.conv_gemm.kernel import (
    band_plan,
    conv2d_fused_banded_pallas,
    conv2d_fused_pallas,
)
from repro.kernels.im2col_pack.ops import im2col_pack
from repro.kernels.im2col_pack.ref import out_size
from repro.kernels.pltpu_compat import should_interpret


def compress_conv_weights(w_ohwi: jax.Array, cfg: SparsityConfig):
    """Prune+compress an OHWI conv kernel column-wise over (kh, kw, c).

    The GEMM weight matrix is [O, Kh*Kw*C]; tiles of T output channels share
    kept (kh, kw, c) positions. Returns (values, idx, meta) for the sparse
    GEMM where the *reduction* dim is Kh*Kw*C.
    """
    o, kh, kw, c = w_ohwi.shape
    wmat = w_ohwi.reshape(o, kh * kw * c).T  # [K, O] = [d_in, d_out]
    meta = meta_for(kh * kw * c, o, cfg)
    mask = colwise_nm_mask(wmat, cfg.sparsity, m=cfg.m, tile=meta.tile)
    values, idx = pack_colwise(wmat, mask, meta)
    return values, idx, meta


@functools.partial(
    jax.jit, static_argnames=("kh", "kw", "stride", "pad", "v", "block_k"))
def conv2d_fused(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Single-megakernel sparse conv: im2col + pack + sparse GEMM fused.

    The packed strips live only in VMEM (zero intermediate HBM round-trips);
    the output is produced directly in [O, P] layout.  Returns CNHW output
    [O, B, Ho, Wo].
    """
    c, b, h, w = x_cnhw.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    y = conv2d_fused_pallas(
        x_cnhw, values, idx, kh=kh, kw=kw, stride=stride, pad=pad, v=v,
        block_k=block_k, interpret=should_interpret(),
    )  # [O, n_strips*v]
    o = y.shape[0]
    return y[:, : b * ho * wo].reshape(o, b, ho, wo)


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "pad", "v", "block_k", "hb"))
def conv2d_fused_banded(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    block_k: int = 128,
    hb: int = 2,
) -> jax.Array:
    """Banded megakernel conv: the H-tiled fused plan.  Only a double-buffered
    row band (``hb`` strips of input rows + halo) is VMEM-resident; band s+1
    is DMA'd while band s's gather+GEMM runs.  Same numerics/layout contract
    as :func:`conv2d_fused`.  Returns CNHW output [O, B, Ho, Wo]."""
    c, b, h, w = x_cnhw.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    y = conv2d_fused_banded_pallas(
        x_cnhw, values, idx, kh=kh, kw=kw, stride=stride, pad=pad, v=v,
        block_k=block_k, hb=hb, interpret=should_interpret(),
    )  # [O, n_strips*v]
    o = y.shape[0]
    return y[:, : b * ho * wo].reshape(o, b, ho, wo)


def conv2d_two_kernel(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Two-kernel Pallas plan: pack kernel, then strip-major sparse GEMM.

    The GEMM consumes the [n_strips, K, V] strips directly (strip dim as the
    Pallas batch grid dim) — the packed matrix is written and read once, with
    no transpose relayout in between.  Returns CNHW output [O, B, Ho, Wo].
    """
    c, b, h, w = x_cnhw.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    strips = im2col_pack(x_cnhw, kh=kh, kw=kw, stride=stride, pad=pad, v=v)
    y = colwise_nm_matmul_strips(strips, values, idx, block_k=block_k)
    o = y.shape[0]
    return y[:, : b * ho * wo].reshape(o, b, ho, wo)


def conv2d_two_kernel_pipelined(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    block_k: int = 128,
    hb: int = 2,
) -> jax.Array:
    """Two-kernel plan with an overlapped strip pipeline: the pack kernel
    writes [n_strips, K, V] strips to HBM, then the *pipelined* strip-major
    GEMM consumes them — chunks of ``hb`` strips are async-copied into a
    double-buffered VMEM scratch so strip s+1 streams in while strip s's
    GEMM runs, instead of the back-to-back block fetch + compute of the
    plain plan.  Returns CNHW output [O, B, Ho, Wo]."""
    c, b, h, w = x_cnhw.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    strips = im2col_pack(x_cnhw, kh=kh, kw=kw, stride=stride, pad=pad, v=v)
    y = colwise_nm_matmul_strips_pipelined(strips, values, idx,
                                           block_k=block_k, hb=hb)
    o = y.shape[0]
    return y[:, : b * ho * wo].reshape(o, b, ho, wo)


def banded_bytes_moved(c: int, b: int, h: int, w: int, kh: int, stride: int,
                       pad: int, ho: int, wo: int, v: int, hb: int,
                       o: int, itemsize: int) -> int:
    """Analytic HBM traffic of the banded megakernel at band depth ``hb``:
    every band DMAs its ``band_rows`` input-row window once (halo rows are
    re-read by adjacent bands — that is the price of banding), and the
    [O, P] output is written once.  Shallower bands re-read more halo;
    deeper bands amortize it at the cost of double-buffer VMEM."""
    n_bands, band_rows = band_plan(b=b, h=h, kh=kh, stride=stride, pad=pad,
                                   ho=ho, wo=wo, v=v, hb=hb)
    n_strips = -(-b * ho * wo // v)
    band_reads = n_bands * c * band_rows * w
    out_write = o * n_strips * v
    return (band_reads + out_write) * itemsize


def conv2d_xla_ref(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
) -> jax.Array:
    """XLA reference plan: pack kernel + gather-einsum GEMM (per-position
    rows, the layout the distribution-friendly linear path uses)."""
    c, b, h, w = x_cnhw.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    n_pos = b * ho * wo
    o = values.shape[0] * values.shape[2]
    strips = im2col_pack(x_cnhw, kh=kh, kw=kw, stride=stride, pad=pad, v=v)
    xt = strips.transpose(0, 2, 1).reshape(-1, kh * kw * c)  # [S*V, K]
    y = colwise_nm_matmul_ref(xt, values, idx)[:n_pos]
    return y.T.reshape(o, b, ho, wo)


# ---------------------------------------------------------------------------
# Differentiable dispatched sparse conv — the conv twin of colwise_nm's VJP
# ---------------------------------------------------------------------------


def _conv_plan_forward(x_cnhw, values, idx, kh, kw, stride, pad, v, impl):
    """Dispatch-resolved forward: exactly what ``conv_apply`` ran before the
    VJP existed — the profiled plan (fused / banded / two-kernel pipelined /
    XLA, any rung) for this conv shape, or the ``impl``-forced candidate."""
    from repro import dispatch as _dispatch

    c, b, h, w = x_cnhw.shape
    n_tiles, k_kept, tile = (int(s) for s in values.shape)
    key = _dispatch.conv_key(
        c, h, w, n_tiles * tile, kh, kw, stride, pad, k_kept, tile,
        v=v, dtype=x_cnhw.dtype, batch=b, phase=_dispatch.current_phase())
    spec = _dispatch.best_impl(key, param_keys=("values", "idx"), force=impl)
    # execution guard: a failing rung is quarantined and the plan re-resolves
    # down the ladder (ultimately the XLA reference) instead of crashing
    return _dispatch.run_guarded(
        key, spec,
        lambda s: s.apply({"values": values, "idx": idx}, x_cnhw,
                          kh=kh, kw=kw, stride=stride, pad=pad, v=v),
        param_keys=("values", "idx"))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _conv_sparse(x_cnhw, values, idx, kh, kw, stride, pad, v, impl):
    return _conv_plan_forward(x_cnhw, values, idx, kh, kw, stride, pad, v,
                              impl)


def _conv_fwd(x_cnhw, values, idx, kh, kw, stride, pad, v, impl):
    from repro import dispatch as _dispatch

    # grad tracing re-enters the call site through this rule; dispatch must
    # resolve from the DB / heuristic only — never wall-clock candidates from
    # inside a gradient trace (see dispatch.no_profile_scope)
    with _dispatch.no_profile_scope():
        y = _conv_plan_forward(x_cnhw, values, idx, kh, kw, stride, pad, v,
                               impl)
    return y, (x_cnhw, values, idx)


def _conv_bwd(kh, kw, stride, pad, v, impl, res, dy):
    """Backward of the GEMM-view conv ``y[t*T+f, p] = sum_j values[t, j, f] *
    X_im2col[idx[t, j], p]``, computed without ever materializing the im2col
    matrix: the same :func:`tap_coords` index arithmetic the forward kernels
    gather with is reused to

      * gather the kept im2col rows from the map (``xg``) for ``dvalues``
        (gathered-activation x dy einsum, f32 accumulation), and
      * scatter-add ``dx`` back through the kept (kh, kw, c) taps — the
        transposed-conv scatter, accumulated in f32 (output positions whose
        receptive fields overlap, and tiles sharing a kept row, collide).

    Runs as XLA gather/scatter: the forward is the latency-critical path the
    paper optimizes; this backward appears only in sparse finetuning.
    """
    x, values, idx = res
    c, b, h, w = x.shape
    o, _, ho, wo = dy.shape
    n_pos = b * ho * wo
    n_tiles, k_kept, tile = values.shape
    k_of = idx // c   # [n_tiles, k_kept] kernel-tap index ikh*kw + ikw
    c_of = idx % c    # [n_tiles, k_kept] input channel
    # coordinates with the flattened output position leading: [P, t, k]
    p = jnp.arange(n_pos, dtype=jnp.int32)[:, None, None]
    valid, bc, ihc, iwc = tap_coords(
        p, ikh=(k_of // kw)[None], ikw=(k_of % kw)[None], stride=stride,
        pad=pad, b=b, h=h, w=w, ho=ho, wo=wo)
    fidx = ((c_of[None] * b + bc) * h + ihc) * w + iwc  # [P, t, k] into CNHW
    dy_t = dy.reshape(o, n_pos).T.reshape(n_pos, n_tiles, tile)  # [P, t, f]

    xg = jnp.where(valid, jnp.take(x.reshape(-1), fidx), 0)  # [P, t, k]
    dvalues = sparse_grad_dvalues(xg, dy_t, values.dtype)

    dxg = sparse_grad_dxg(dy_t, values)  # [P, t, k] f32
    dx = (jnp.zeros((c * b * h * w,), jnp.float32)
          .at[fidx.reshape(-1)]
          .add(jnp.where(valid, dxg, 0).reshape(-1))
          .reshape(c, b, h, w).astype(x.dtype))
    return dx, dvalues, None


_conv_sparse.defvjp(_conv_fwd, _conv_bwd)


def conv2d_sparse(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    impl: Optional[str] = None,
) -> jax.Array:
    """Differentiable dispatched sparse conv (the conv twin of
    ``colwise_nm``'s custom VJP).

    Forward is the dispatch-resolved execution plan — whichever rung of the
    conv plan ladder the profile DB / heuristic picks for this shape (or the
    ``impl``-forced candidate).  Backward computes ``dx`` via the
    transposed-conv scatter over the kept (kh, kw, c) taps and ``dvalues``
    via the im2col-gather x dy einsum, both f32-accumulated; ``idx`` gets no
    cotangent.  Returns CNHW output [O, B, Ho, Wo].
    """
    return _conv_sparse(x_cnhw, values, idx, kh, kw, stride, pad, v, impl)


def conv2d_colwise_sparse(
    x_cnhw: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """Sparse convolution with dispatched execution plan.

    ``use_pallas=None`` (the default) consults ``repro.dispatch``: the
    registered conv candidates (fused megakernel geometry variants, two-kernel
    strip-major, XLA reference) are resolved per shape from the profile DB /
    platform heuristic, via the differentiable :func:`conv2d_sparse` wrapper.
    ``use_pallas=True`` forces the two-kernel Pallas plan, ``False`` the XLA
    reference plan.  Returns CNHW output [O, B, Ho, Wo].
    """
    if use_pallas is None:
        return conv2d_sparse(x_cnhw, values, idx, kh=kh, kw=kw, stride=stride,
                             pad=pad, v=v)
    if use_pallas:
        return conv2d_two_kernel(x_cnhw, values, idx, kh=kh, kw=kw,
                                 stride=stride, pad=pad, v=v)
    return conv2d_xla_ref(x_cnhw, values, idx, kh=kh, kw=kw,
                          stride=stride, pad=pad, v=v)
