from repro.kernels.conv_gemm.kernel import (  # noqa: F401
    band_plan,
    banded_vmem_bytes,
    conv2d_fused_banded_pallas,
    conv2d_fused_pallas,
    fused_vmem_bytes,
)
from repro.kernels.conv_gemm.ops import (  # noqa: F401
    banded_bytes_moved,
    compress_conv_weights,
    conv2d_colwise_sparse,
    conv2d_fused,
    conv2d_fused_banded,
    conv2d_sparse,
    conv2d_two_kernel,
    conv2d_two_kernel_pipelined,
    conv2d_xla_ref,
)
from repro.kernels.conv_gemm.ref import conv2d_cnhw_ref  # noqa: F401
