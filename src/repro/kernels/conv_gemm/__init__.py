from repro.kernels.conv_gemm.ops import (  # noqa: F401
    compress_conv_weights,
    conv2d_colwise_sparse,
)
from repro.kernels.conv_gemm.ref import conv2d_cnhw_ref  # noqa: F401
