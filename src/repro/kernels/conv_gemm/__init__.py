from repro.kernels.conv_gemm.kernel import (  # noqa: F401
    conv2d_fused_pallas,
    fused_vmem_bytes,
)
from repro.kernels.conv_gemm.ops import (  # noqa: F401
    compress_conv_weights,
    conv2d_colwise_sparse,
    conv2d_fused,
    conv2d_two_kernel,
    conv2d_xla_ref,
)
from repro.kernels.conv_gemm.ref import conv2d_cnhw_ref  # noqa: F401
