"""Shared Pallas-TPU API compatibility shims + helpers for the kernel modules.

jax renamed ``TPUCompilerParams`` -> ``CompilerParams`` across 0.4.x/0.5.x;
accept either so the kernels run on whatever toolchain the image bakes in.
The async-copy surface (``make_async_copy`` / ``SemaphoreType`` / the ANY
memory space) moved around the same releases; the banded/pipelined kernels go
through the shims below so a toolchain without manual DMA support degrades to
a clear "not available" signal (the dispatch predicates gate on it) instead
of an AttributeError mid-trace.
"""
import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# ---------------------------------------------------------------------------
# Async-copy (manual DMA) shims — used by the banded conv megakernel and the
# pipelined strip GEMM, which keep their big operand in HBM and double-buffer
# row bands / strip chunks into VMEM scratch.
# ---------------------------------------------------------------------------

# memory space that lets a pallas_call input stay un-blocked (HBM/compiler's
# choice) so the kernel can DMA slices of it manually
MEM_ANY = getattr(pltpu, "ANY", None)
if MEM_ANY is None:  # pre-rename spelling
    MEM_ANY = getattr(getattr(pltpu, "TPUMemorySpace", None), "ANY", None)

_MAKE_ASYNC_COPY = getattr(pltpu, "make_async_copy", None)
SEMAPHORE_TYPE = getattr(pltpu, "SemaphoreType", None)

HAS_ASYNC_COPY = (
    _MAKE_ASYNC_COPY is not None and SEMAPHORE_TYPE is not None
    and MEM_ANY is not None
)

# Scalar-prefetched grids (page tables / length vectors delivered to SMEM
# ahead of the kernel body) — required by the ragged paged-attention kernel,
# whose DMA source indices come from a runtime page table.
PREFETCH_GRID_SPEC = getattr(pltpu, "PrefetchScalarGridSpec", None)
HAS_SCALAR_PREFETCH = PREFETCH_GRID_SPEC is not None


def prefetch_grid_spec(*, num_scalar_prefetch, grid, in_specs, out_specs,
                       scratch_shapes):
    """Grid spec whose first ``num_scalar_prefetch`` operands are scalar
    arrays prefetched to SMEM (kernel sees them first; index maps receive
    them as trailing ref args)."""
    if PREFETCH_GRID_SPEC is None:
        raise NotImplementedError(
            "this jax/pallas build has no pltpu.PrefetchScalarGridSpec; the "
            "paged-attention kernel is unavailable (its dispatch predicate "
            "should have gated on pltpu_compat.HAS_SCALAR_PREFETCH)")
    return PREFETCH_GRID_SPEC(
        num_scalar_prefetch=num_scalar_prefetch, grid=grid,
        in_specs=in_specs, out_specs=out_specs,
        scratch_shapes=scratch_shapes)


def make_async_copy(src_ref, dst_ref, sem_ref):
    """Async copy descriptor (``.start()`` / ``.wait()``) between memory
    spaces, shared by every double-buffered kernel.  Interpret mode executes
    the same descriptor (jax simulates the semaphore), so the DMA path is
    testable on CPU."""
    if _MAKE_ASYNC_COPY is None:
        raise NotImplementedError(
            "this jax/pallas build has no pltpu.make_async_copy; the banded/"
            "pipelined conv plans are unavailable (their dispatch predicates "
            "should have gated on pltpu_compat.HAS_ASYNC_COPY)")
    return _MAKE_ASYNC_COPY(src_ref, dst_ref, sem_ref)


def dma_semaphores(n: int):
    """Scratch-shape entry for ``n`` DMA completion semaphores."""
    if SEMAPHORE_TYPE is None:
        raise NotImplementedError(
            "this jax/pallas build has no pltpu.SemaphoreType; manual-DMA "
            "kernels are unavailable")
    return SEMAPHORE_TYPE.DMA((n,))


def double_buffer_rotate(dma, g, n_chunks, *, gate):
    """THE two-slot DMA rotation protocol, shared by every double-buffered
    kernel (banded conv megakernel, pipelined strip GEMM) so the
    correctness-critical ordering lives in one place.

    Under ``gate`` (the predicate marking the first grid step of chunk
    ``g``): warm up chunk 0's copy, start the prefetch of chunk g+1 into the
    other slot, THEN block on chunk g — so chunk g+1 streams in behind chunk
    g's compute.  ``dma(slot, gi)`` must return the async-copy descriptor
    for chunk ``gi`` into scratch slot ``slot``; the descriptor a ``wait``
    reconstructs must be identical to the one ``start`` used.
    """
    from jax.experimental import pallas as pl

    @pl.when(gate)
    def _rotate():
        @pl.when(g == 0)
        def _warmup():
            dma(0, 0).start()

        @pl.when(g + 1 < n_chunks)
        def _prefetch():
            dma((g + 1) % 2, g + 1).start()

        dma(g % 2, g).wait()


def dot_f32(a, b, interpret: bool):
    """MXU dot with float32 accumulation, shared by every accumulate-flush
    kernel.  Interpret mode casts the operands up first — XLA:CPU has no
    bf16xbf16->f32 dot, while the TPU path feeds the MXU native operands."""
    if interpret:
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def should_interpret() -> bool:
    """One interpret-mode policy for every kernel wrapper: compiled Mosaic on
    TPU, interpret mode everywhere else."""
    return jax.default_backend() != "tpu"


def ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m
