"""Shared Pallas-TPU API compatibility shims for the kernel modules.

jax renamed ``TPUCompilerParams`` -> ``CompilerParams`` across 0.4.x/0.5.x;
accept either so the kernels run on whatever toolchain the image bakes in.
"""
from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
