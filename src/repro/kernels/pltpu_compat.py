"""Shared Pallas-TPU API compatibility shims + helpers for the kernel modules.

jax renamed ``TPUCompilerParams`` -> ``CompilerParams`` across 0.4.x/0.5.x;
accept either so the kernels run on whatever toolchain the image bakes in.
"""
import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def dot_f32(a, b, interpret: bool):
    """MXU dot with float32 accumulation, shared by every accumulate-flush
    kernel.  Interpret mode casts the operands up first — XLA:CPU has no
    bf16xbf16->f32 dot, while the TPU path feeds the MXU native operands."""
    if interpret:
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def should_interpret() -> bool:
    """One interpret-mode policy for every kernel wrapper: compiled Mosaic on
    TPU, interpret mode everywhere else."""
    return jax.default_backend() != "tpu"


def ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m
