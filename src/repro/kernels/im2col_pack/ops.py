"""Jitted wrappers for fused im2col+packing, plus the un-fused baseline."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.im2col_pack.kernel import im2col_pack_pallas
from repro.kernels.im2col_pack.ref import im2col_cnhw, im2col_pack_ref, pack_strips
from repro.kernels.pltpu_compat import should_interpret


@functools.partial(jax.jit, static_argnames=("kh", "kw", "stride", "pad", "v"))
def im2col_pack(x, *, kh, kw, stride=1, pad=0, v=128):
    """Fused single-pass im2col + packing (the paper's optimization)."""
    return im2col_pack_pallas(
        x, kh, kw, stride=stride, pad=pad, v=v, interpret=should_interpret()
    )


@functools.partial(jax.jit, static_argnames=("kh", "kw", "stride", "pad", "v"))
def im2col_then_pack(x, *, kh, kw, stride=1, pad=0, v=128):
    """Two-pass baseline: materialize the patch matrix, then pack.

    ``optimization_barrier`` pins the intermediate so XLA cannot silently fuse
    the two passes — this is the memory-overhead configuration the paper
    measures against (Fig. 6/8).
    """
    mat = im2col_cnhw(x, kh, kw, stride, pad)
    mat = jax.lax.optimization_barrier(mat)
    return pack_strips(mat, v)


@functools.partial(jax.jit, static_argnames=("kh", "kw", "stride", "pad"))
def im2col_only(x, *, kh, kw, stride=1, pad=0):
    """im2col without packing (paper Fig. 8a's 'no packing' configuration)."""
    return im2col_cnhw(x, kh, kw, stride, pad)


def bytes_moved_fused(c, b, h, w, kh, kw, ho, wo, v, itemsize) -> int:
    """Analytic data movement of the fused pass: each strip element is read
    once from the map and written once to the strip."""
    return 2 * kh * kw * c * (-(-b * ho * wo // v)) * v * itemsize


def bytes_moved_unfused(c, b, h, w, kh, kw, ho, wo, v, itemsize) -> int:
    """Two passes: im2col (read map, write matrix) + pack (read matrix,
    write strips) — double traffic on the patch matrix."""
    mat = kh * kw * c * b * ho * wo
    strips = kh * kw * c * (-(-b * ho * wo // v)) * v
    return (mat + mat + mat + strips) * itemsize
