"""Pure-jnp oracle for the fused im2col + data-packing kernel (paper Alg. 2).

The oracle runs the two steps *separately* — first im2col into the full patch
matrix, then packing into vector-aligned strips — i.e. the baseline the paper
fuses away.  The fused kernel must be bit-identical; only its data movement
differs.

Layouts follow the paper exactly:
  input feature map : CNHW  [C_in, B, H, W]  (W contiguous => vectorizable)
  patch matrix rows : (kh, kw, c) flattened, i.e. row = k * C_in + c
  patch matrix cols : (b, oh, ow) flattened output positions
  packed strips     : [n_strips, K_h*K_w*C_in, V]  — V-wide column strips
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def out_size(h: int, k: int, stride: int, pad: int) -> int:
    return (h + 2 * pad - k) // stride + 1


def im2col_cnhw(x: jax.Array, kh: int, kw: int, stride: int = 1, pad: int = 0) -> jax.Array:
    """im2col on a CNHW feature map -> [Kh*Kw*C, B*Ho*Wo] patch matrix."""
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    rows = []
    for ikh in range(kh):
        for ikw in range(kw):
            sl = jax.lax.slice(
                xp,
                (0, 0, ikh, ikw),
                (c, b, ikh + (ho - 1) * stride + 1, ikw + (wo - 1) * stride + 1),
                (1, 1, stride, stride),
            )  # [C, B, Ho, Wo]
            rows.append(sl.reshape(c, b * ho * wo))
    mat = jnp.stack(rows, axis=0)  # [KhKw, C, P]
    return mat.reshape(kh * kw * c, b * ho * wo)


def pack_strips(mat: jax.Array, v: int) -> jax.Array:
    """Pack a [R, P] matrix into V-wide strips [ceil(P/V), R, V] (paper Fig. 2)."""
    r, p = mat.shape
    n_strips = -(-p // v)
    mat = jnp.pad(mat, ((0, 0), (0, n_strips * v - p)))
    return mat.reshape(r, n_strips, v).transpose(1, 0, 2)


def im2col_pack_ref(
    x: jax.Array, kh: int, kw: int, stride: int = 1, pad: int = 0, v: int = 128
) -> jax.Array:
    """Two-pass baseline: im2col, then pack. Output [n_strips, KhKwC, V]."""
    return pack_strips(im2col_cnhw(x, kh, kw, stride, pad), v)
