from repro.kernels.im2col_pack.kernel import im2col_pack_pallas  # noqa: F401
from repro.kernels.im2col_pack.ops import (  # noqa: F401
    im2col_only,
    im2col_pack,
    im2col_then_pack,
)
from repro.kernels.im2col_pack.ref import (  # noqa: F401
    im2col_cnhw,
    im2col_pack_ref,
    out_size,
    pack_strips,
)
