from repro.kernels.im2col_pack.kernel import (  # noqa: F401
    im2col_pack_pallas,
    strip_tap_coords,
    tap_coords,
)
from repro.kernels.im2col_pack.ops import (  # noqa: F401
    im2col_only,
    im2col_pack,
    im2col_then_pack,
)
from repro.kernels.im2col_pack.ref import (  # noqa: F401
    im2col_cnhw,
    im2col_pack_ref,
    out_size,
    pack_strips,
)
