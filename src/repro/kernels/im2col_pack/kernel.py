"""Pallas kernel: fused im2col + data packing (paper Algorithm 2, TPU analog).

One pass moves each input element directly from the CNHW feature map into its
packed-strip position; the intermediate patch matrix never exists in HBM.

RVV -> TPU translation:
  - vector length V / LMUL     -> strip width V (lane multiples: 128..1024)
  - dynamic VL trim at the     -> iota-compare masks on the final/ragged strip
    feature-map boundary          (no zero-copy padding regions are touched)
  - scalar loop over (k, c)    -> grid dimensions (strip, k, c-block); each
    with vector strip copies      grid step emits a [c_block, V] strip tile

Grid: (n_strips, Kh*Kw, C_in / c_block).  The source coordinates of a strip
row depend on (kh, kw) but NOT on the channel, so a whole block of channels
shares one set of gather indices: step (s, k, cc) emits the strip tile
[s, k*C + cc*c_block : k*C + (cc+1)*c_block, :] with a single lane-dim
gather from the [c_block, B*H*W]-flattened feature-map block.  (The seed
kernel emitted one V-wide row per step — C_in times more grid steps for the
same data movement.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import COMPILER_PARAMS as _COMPILER_PARAMS

from repro.kernels.im2col_pack.ref import out_size


def tap_coords(p, *, ikh, ikw, stride, pad, b, h, w, ho, wo,
               band_origin=None, band_rows=None):
    """Source coordinates of flat output positions ``p`` at kernel tap
    (ikh, ikw) — THE im2col index arithmetic, shared by this pack kernel, the
    conv megakernels (``conv_gemm/kernel.py``) and the conv backward's
    transposed-conv scatter (``conv_gemm/ops.py``) so the stride/pad/boundary
    semantics cannot drift between forward and gradient.

    ``p`` is any int32 array of flattened ``(batch, oh, ow)`` output
    positions; ``ikh``/``ikw`` broadcast against it (scalars for one tap,
    or e.g. [block_k, 1] against a [v] strip of positions).  Returns
    ``(valid, bc, ihc, iwc)``: the out-of-map / past-the-end mask and
    clamped (always in-bounds) batch/row/col gather coordinates; ``bc``
    keeps ``p``'s shape (positions do not depend on the tap).

    Band mode (``band_origin``/``band_rows`` set): for kernels that keep only
    a row band of the feature map resident (the banded megakernel), the
    returned row coordinate is *band-local* in the flattened ``(batch*h)``
    row space — ``bb*h + ih - band_origin``, clamped to ``[0, band_rows)`` —
    and the batch coordinate is dropped (the flattened row subsumes it):
    returns ``(valid, rowc, iwc)``.  ``band_origin`` may be a traced scalar
    (it is derived from the grid position inside the kernel).
    """
    n_pos = b * ho * wo
    bb = p // (ho * wo)
    rem = p % (ho * wo)
    oh = rem // wo
    ow = rem % wo
    ih = oh * stride - pad + ikh
    iw = ow * stride - pad + ikw
    valid = (p < n_pos) & (ih >= 0) & (ih < h) & (iw >= 0) & (iw < w)
    # clamp so the gather itself is always in-bounds; masked after
    if band_origin is not None:
        g = bb * h + ih - band_origin  # band-local flattened (batch*h) row
        return (valid, jnp.clip(g, 0, band_rows - 1), jnp.clip(iw, 0, w - 1))
    return (valid, jnp.clip(bb, 0, b - 1), jnp.clip(ih, 0, h - 1),
            jnp.clip(iw, 0, w - 1))


def strip_tap_coords(s, *, v, ikh, ikw, stride, pad, b, h, w, ho, wo,
                     band_origin=None, band_rows=None):
    """Source coordinates of strip ``s``'s V output positions at kernel tap
    (ikh, ikw): :func:`tap_coords` over ``p = s*v + iota(v)`` — the strip
    view the Pallas kernels consume (one [v]-wide position vector per grid
    step).  See :func:`tap_coords` for the returned tuple and band mode.
    """
    p = s * v + jax.lax.iota(jnp.int32, v)  # flat output positions of strip
    return tap_coords(p, ikh=ikh, ikw=ikw, stride=stride, pad=pad, b=b, h=h,
                      w=w, ho=ho, wo=wo, band_origin=band_origin,
                      band_rows=band_rows)


def _kernel(
    x_ref,
    o_ref,
    *,
    kh: int,
    kw: int,
    stride: int,
    pad: int,
    v: int,
    c_block: int,
    b: int,
    h: int,
    w: int,
    ho: int,
    wo: int,
):
    s = pl.program_id(0)
    k = pl.program_id(1)
    valid, bc, ihc, iwc = strip_tap_coords(
        s, v=v, ikh=k // kw, ikw=k % kw, stride=stride, pad=pad,
        b=b, h=h, w=w, ho=ho, wo=wo)
    # every channel of the block shares the gather indices: one lane-dim
    # gather emits the whole [c_block, v] strip tile
    flat = x_ref[...].reshape(c_block, b * h * w)
    fidx = (bc * h + ihc) * w + iwc  # [v]
    vals = jnp.take(flat, fidx, axis=1)  # [c_block, v]
    o_ref[0] = jnp.where(valid[None, :], vals, 0).astype(o_ref.dtype)


def _choose_c_block(c: int, cap: int = 32) -> int:
    """Largest divisor of C no bigger than ``cap`` (grid-coarsening factor)."""
    for cb in range(min(c, cap), 0, -1):
        if c % cb == 0:
            return cb
    return 1


def im2col_pack_pallas(
    x: jax.Array,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused im2col+pack of a CNHW map -> [n_strips, KhKwC, V] strips."""
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    n_pos = b * ho * wo
    n_strips = -(-n_pos // v)
    c_block = _choose_c_block(c)
    n_cb = c // c_block

    grid = (n_strips, kh * kw, n_cb)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, stride=stride, pad=pad, v=v,
            c_block=c_block, b=b, h=h, w=w, ho=ho, wo=wo
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_block, b, h, w), lambda s, k, cc: (cc, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, c_block, v), lambda s, k, cc, _n=n_cb: (s, k * _n + cc, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n_strips, kh * kw * c, v), x.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x)
    return out
