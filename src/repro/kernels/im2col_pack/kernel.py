"""Pallas kernel: fused im2col + data packing (paper Algorithm 2, TPU analog).

One pass moves each input element directly from the CNHW feature map into its
packed-strip position; the intermediate patch matrix never exists in HBM.

RVV -> TPU translation:
  - vector length V / LMUL     -> strip width V (lane multiples: 128..1024)
  - dynamic VL trim at the     -> iota-compare masks on the final/ragged strip
    feature-map boundary          (no zero-copy padding regions are touched)
  - scalar loop over (k, c)    -> grid dimensions (strip, k, c); each grid
    with vector strip copies      step emits one V-wide strip row

Grid: (n_strips, Kh*Kw, C_in).  The output block for step (s, k, c) is the
single strip row [s, k*C+c, :].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import COMPILER_PARAMS as _COMPILER_PARAMS

from repro.kernels.im2col_pack.ref import out_size


def _kernel(
    x_ref,
    o_ref,
    *,
    kh: int,
    kw: int,
    stride: int,
    pad: int,
    v: int,
    b: int,
    h: int,
    w: int,
    ho: int,
    wo: int,
):
    s = pl.program_id(0)
    k = pl.program_id(1)
    ikh = k // kw
    ikw = k % kw

    p = s * v + jax.lax.iota(jnp.int32, v)  # flat output positions of strip
    n_pos = b * ho * wo
    bb = p // (ho * wo)
    rem = p % (ho * wo)
    oh = rem // wo
    ow = rem % wo
    ih = oh * stride - pad + ikh
    iw = ow * stride - pad + ikw
    valid = (p < n_pos) & (ih >= 0) & (ih < h) & (iw >= 0) & (iw < w)
    # clamp so the gather itself is always in-bounds; masked after
    bc = jnp.clip(bb, 0, b - 1)
    ihc = jnp.clip(ih, 0, h - 1)
    iwc = jnp.clip(iw, 0, w - 1)
    vals = x_ref[0, bc, ihc, iwc]  # [v] gather from the channel's B×H×W block
    o_ref[0, 0, :] = jnp.where(valid, vals, 0).astype(o_ref.dtype)


def im2col_pack_pallas(
    x: jax.Array,
    kh: int,
    kw: int,
    stride: int = 1,
    pad: int = 0,
    v: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused im2col+pack of a CNHW map -> [n_strips, KhKwC, V] strips."""
    c, b, h, w = x.shape
    ho = out_size(h, kh, stride, pad)
    wo = out_size(w, kw, stride, pad)
    n_pos = b * ho * wo
    n_strips = -(-n_pos // v)

    grid = (n_strips, kh * kw, c)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, stride=stride, pad=pad, v=v, b=b, h=h, w=w, ho=ho, wo=wo
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b, h, w), lambda s, k, cc: (cc, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, v), lambda s, k, cc, _c=c: (s, k * _c + cc, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n_strips, kh * kw * c, v), x.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x)
    return out
