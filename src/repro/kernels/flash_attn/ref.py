"""Pure-jnp oracle for the flash-attention kernel: naive full-softmax
attention (materialized scores — exactly what the kernel avoids)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True) -> jax.Array:
    """q: [BH, Sq, D]; k/v: [BH, Sk, D]."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
