"""Ragged paged flash-attention Pallas kernel (serve.kv_pages backend).

The paged KV cache stores a sequence's rows scattered across fixed-size
physical pages; attention must gather them back. The XLA reference
(`paged_attention_ref`) materializes the gather in HBM — ``n_max * ps``
rows per sequence round-trip regardless of the actual length. This kernel
never materializes the gather: the page table is delivered by scalar
prefetch (SMEM), and one grid dimension walks a sequence's pages
sequentially, DMA-ing each page from HBM into a two-slot VMEM scratch via
``pltpu_compat.make_async_copy`` — page j+1 streams in behind page j's
online-softmax update (the same ``double_buffer_rotate`` protocol as the
banded conv megakernel). Rows past the sequence's length (ragged final
page, trash-page table padding) are masked with an explicit probability
zeroing, so a fully-masked page contributes exactly nothing.

The current step's not-yet-written K/V ("new" keys) are folded in at the
last page step — same no-write-in-scan contract as ``attn_decode``:
combine(cache rows < len) ++ new keys is identical math to
write-then-attend(len + Sq).

Grid: ``(B, Sq/block_q, n_pages)``; pages are the sequential ("arbitrary")
axis; m/l/acc persist in VMEM scratch across page steps, one lane per KV
head (GQA groups share their KV head's page DMA).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import (
    COMPILER_PARAMS as _COMPILER_PARAMS,
    HAS_ASYNC_COPY,
    HAS_SCALAR_PREFETCH,
    MEM_ANY,
    ceil_to,
    dma_semaphores,
    dot_f32,
    double_buffer_rotate,
    make_async_copy,
    prefetch_grid_spec,
    should_interpret,
)

NEG = -1e30

#: page_size x block_q geometry grid raced by profile_op (first = default)
DEFAULT_PAGE_SIZE = 16


def _flash_update(m_ref, l_ref, acc_ref, kvh, s, mask, v, interpret):
    """One masked online-softmax accumulation step for KV head ``kvh``.

    The probability matrix is multiplied by ``mask`` (not just score-masked
    with NEG): when every score so far is masked, m stays at NEG and
    ``exp(NEG - NEG) == 1`` would pollute l/acc of *valid* q rows — e.g. the
    page phase of a sequence whose cache is still empty.
    """
    s = jnp.where(mask, s, NEG)
    m_prev = m_ref[kvh]  # [bq*g, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[kvh] = alpha * l_ref[kvh] + p.sum(axis=-1, keepdims=True)
    acc_ref[kvh] = alpha * acc_ref[kvh] + dot_f32(p, v, interpret)
    m_ref[kvh] = m_new


def _kernel(tbl_ref, len_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref, o_ref,
            kscr, vscr, ksem, vsem, m_ref, l_ref, acc_ref, *,
            n_pages: int, page_size: int, block_q: int, sq: int, sn: int,
            kv: int, g: int, d: int, scale: float, interpret: bool):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Page DMA: physical page id comes from the scalar-prefetched table.
    # Padded entries name the trash page — a real, in-range page whose rows
    # the length mask below always kills.
    def dma_k(slot, ji):
        return make_async_copy(kp_ref.at[pl.ds(tbl_ref[b, ji], 1)],
                               kscr.at[slot], ksem.at[slot])

    def dma_v(slot, ji):
        return make_async_copy(vp_ref.at[pl.ds(tbl_ref[b, ji], 1)],
                               vscr.at[slot], vsem.at[slot])

    # Every page is its own grid step, so the rotation gate is always open;
    # the slot/semaphore pairing restarts cleanly at j == 0 of each (b, i).
    always = j >= 0
    double_buffer_rotate(dma_k, j, n_pages, gate=always)
    double_buffer_rotate(dma_v, j, n_pages, gate=always)

    slot = j % 2
    kbuf = kscr[slot, 0]  # [ps, KV, D]
    vbuf = vscr[slot, 0]
    q = q_ref[0]  # [bq, H, D]
    length = len_ref[b]
    if interpret:  # XLA:CPU has no bf16 dot
        q, kbuf, vbuf = (t.astype(jnp.float32) for t in (q, kbuf, vbuf))

    kvpos = j * page_size + jax.lax.iota(jnp.int32, page_size)[None, :]
    page_mask = kvpos < length  # [1, ps]; causal is implied: qpos >= length
    for h0 in range(kv):
        qh = q[:, h0 * g:(h0 + 1) * g, :].reshape(block_q * g, d)
        s = dot_f32(qh, kbuf[:, h0, :].T, interpret) * scale  # [bq*g, ps]
        _flash_update(m_ref, l_ref, acc_ref, h0, s, page_mask,
                      vbuf[:, h0, :], interpret)

    @pl.when(j == n_pages - 1)
    def _new_and_flush():
        kn = kn_ref[0]  # [sn_p, KV, D]
        vn = vn_ref[0]
        qn = q_ref[0]
        if interpret:
            kn, vn, qn = (t.astype(jnp.float32) for t in (kn, vn, qn))
        tpos = jax.lax.iota(jnp.int32, kn.shape[0])[None, :]  # [1, sn_p]
        qrow = i * block_q + jax.lax.iota(
            jnp.int32, block_q * g)[:, None] // g  # within-chunk q index
        new_mask = (tpos <= qrow) & (tpos < sn)
        for h0 in range(kv):
            qh = qn[:, h0 * g:(h0 + 1) * g, :].reshape(block_q * g, d)
            s = dot_f32(qh, kn[:, h0, :].T, interpret) * scale
            _flash_update(m_ref, l_ref, acc_ref, h0, s, new_mask,
                          vn[:, h0, :], interpret)
            out = acc_ref[h0] / jnp.maximum(l_ref[h0], 1e-30)
            o_ref[0, :, h0 * g:(h0 + 1) * g, :] = out.reshape(
                block_q, g, d).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array, k_new: jax.Array, v_new: jax.Array,
    k_pages: jax.Array, v_pages: jax.Array,
    tables: jax.Array, lengths: jax.Array, *,
    page_size: int, block_q: int = 8, interpret: bool = False,
) -> jax.Array:
    """Ragged paged attention; semantics == :func:`paged_attention_ref`.

    q [B, Sq, H, D]; k_new/v_new [B, Sq, KV, D] (this step's keys, not yet
    written); k_pages/v_pages [P, page_size, KV, D] physical pages; tables
    [B, n_max] int32 (entries past a sequence's mapping must name any
    in-range page — their rows are masked); lengths [B] int32 cache rows
    valid (the step's start position). Requires H % KV == 0.
    """
    b, sq, h, d = q.shape
    kv = k_pages.shape[2]
    if h % kv != 0:
        raise ValueError(f"paged kernel needs H % KV == 0, got {h} % {kv}")
    if k_pages.shape[1] != page_size:
        raise ValueError(
            f"page_size {page_size} != physical page rows {k_pages.shape[1]}")
    g = h // kv
    n_pages = tables.shape[1]
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, ceil_to(sq, 8))
    sq_p = ceil_to(sq, block_q)
    if sq_p != sq:
        pad = ((0, 0), (0, sq_p - sq), (0, 0), (0, 0))
        q = jnp.pad(q, pad)
    grid = (b, sq_p // block_q, n_pages)
    sn = k_new.shape[1]

    out = pl.pallas_call(
        functools.partial(
            _kernel, n_pages=n_pages, page_size=page_size, block_q=block_q,
            sq=sq, sn=sn, kv=kv, g=g, d=d, scale=scale, interpret=interpret,
        ),
        grid_spec=prefetch_grid_spec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, h, d),
                             lambda bb, ii, jj, *_: (bb, ii, 0, 0)),
                pl.BlockSpec((1, sn, kv, d),
                             lambda bb, ii, jj, *_: (bb, 0, 0, 0)),
                pl.BlockSpec((1, sn, kv, d),
                             lambda bb, ii, jj, *_: (bb, 0, 0, 0)),
                pl.BlockSpec(memory_space=MEM_ANY),
                pl.BlockSpec(memory_space=MEM_ANY),
            ],
            out_specs=pl.BlockSpec((1, block_q, h, d),
                                   lambda bb, ii, jj, *_: (bb, ii, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((2, 1, page_size, kv, d), k_pages.dtype),
                pltpu.VMEM((2, 1, page_size, kv, d), v_pages.dtype),
                dma_semaphores(2),
                dma_semaphores(2),
                pltpu.VMEM((kv, block_q * g, 1), jnp.float32),
                pltpu.VMEM((kv, block_q * g, 1), jnp.float32),
                pltpu.VMEM((kv, block_q * g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, sq_p, h, d), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q, k_new, v_new, k_pages, v_pages)
    return out[:, :sq]


def paged_attention_ref(q, k_new, v_new, k_pages, v_pages, tables,
                        lengths) -> jax.Array:
    """XLA reference: gather the pages, run the serve combine-attention.

    Materializes the gathered ``[B, n_max * ps, KV, D]`` cache view in HBM
    — correct everywhere (and the CPU/fallback dispatch candidate), but
    bytes-moved scales with the table width, not the actual lengths.
    """
    from repro.models.attention import _cached_attention

    ps = k_pages.shape[1]
    b, n_max = tables.shape
    kv, d = k_pages.shape[2], k_pages.shape[3]
    kc = k_pages[tables].reshape(b, n_max * ps, kv, d)
    vc = v_pages[tables].reshape(b, n_max * ps, kv, d)
    lengths = jnp.asarray(lengths, jnp.int32)
    return _cached_attention(q, k_new, v_new, kc, vc, limit=lengths,
                             causal=True)


def paged_vmem_bytes(page_size: int, kv: int, d: int, block_q: int, h: int,
                     sn: int, in_bytes: int) -> int:
    """Analytic VMEM footprint of one paged-attention grid step."""
    g = h // max(kv, 1)
    pages = 2 * 2 * page_size * kv * d * in_bytes  # k + v double buffers
    qblk = block_q * h * d * in_bytes
    new = 2 * sn * kv * d * in_bytes
    scr = kv * (block_q * g) * (d + 2) * 4  # m, l, acc in f32
    out = block_q * h * d * in_bytes
    return pages + qblk + new + scr + out


def paged_attention(q, k_new, v_new, k_pages, v_pages, tables, lengths, *,
                    page_size: int, impl: str = None) -> jax.Array:
    """Dispatch-resolved paged attention (the serve decode entry point).

    Builds the execution :func:`~repro.dispatch.paged_attn_key` (page size
    pinned — only matching-geometry pallas candidates are feasible) and
    routes to the winning implementation; the XLA gather reference is the
    universal fallback.
    """
    from repro import fault as _fault
    from repro.dispatch import best_impl, current_phase, paged_attn_key, run_guarded

    b, sq, h, d = q.shape
    kv = k_pages.shape[2]
    key = paged_attn_key(
        q_rows=b * sq, n_heads=h, kv_heads=kv, head_dim=d,
        kv_capacity=tables.shape[1] * page_size, page_size=page_size,
        dtype=q.dtype, phase=current_phase())
    spec = best_impl(key, force=impl)

    def _run(s):
        # kernel-specific fault site (probes at trace time, like the kernel
        # failures it stands in for); a hit quarantines the current rung and
        # run_guarded re-resolves — the XLA gather reference is the floor
        _fault.maybe_fail("kernel.paged_attn", impl=s.name, phase=key.phase)
        if s is not None and s.backend == "pallas":
            return paged_attention_pallas(
                q, k_new, v_new, k_pages, v_pages, tables, lengths,
                page_size=page_size, block_q=s.geom("bq", 8),
                interpret=should_interpret())
        return paged_attention_ref(q, k_new, v_new, k_pages, v_pages, tables,
                                   lengths)

    return run_guarded(key, spec, _run)


def paged_kernel_available() -> bool:
    """True when this jax/pallas build can run the paged kernel at all."""
    return HAS_ASYNC_COPY and HAS_SCALAR_PREFETCH
