"""Pallas TPU flash-attention kernel (beyond-paper; EXPERIMENTS §Perf).

The dry-run showed materialized attention scores are simultaneously the
dominant HBM traffic and the trigger for TB-scale involuntary all-gathers.
The XLA-level chunked attention fixes the collective side; this kernel is the
TPU-native end state: the online-softmax internals (scores, p, m, l, acc)
live entirely in VMEM — HBM traffic is exactly Q + K + V + O.

Grid: (batch*heads, Sq/block_q, Sk/block_k); the KV dimension is the
sequential ("arbitrary") accumulation axis; m/l/acc persist in VMEM scratch
across KV steps. Causal masking via block-offset iota compares.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import COMPILER_PARAMS as _COMPILER_PARAMS
from repro.kernels.pltpu_compat import ceil_to, dot_f32

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, block_q: int, block_k: int, sk: int, causal: bool,
            scale: float, interpret: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [bq, D]
    k = k_ref[0]  # [bk, D]
    v = v_ref[0]  # [bk, D]
    if interpret:  # XLA:CPU has no bf16 dot
        q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    s = dot_f32(q, k.T, interpret) * scale  # [bq, bk]

    i = pl.program_id(1)
    qpos = i * block_q + jax.lax.iota(jnp.int32, block_q)[:, None]
    kpos = j * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
    mask = kpos < sk  # padded tail
    if causal:
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]  # [bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)  # [bq, bk] f32
    alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    pv = dot_f32(p.astype(v.dtype), v, interpret)
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    block_q: int = 128, block_k: int = 128, interpret: bool = False,
) -> jax.Array:
    """q: [BH, Sq, D]; k/v: [BH, Sk, D] (GQA expansion handled by ops.py).
    Returns [BH, Sq, D]."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, ceil_to(sq, 8))
    block_k = min(block_k, ceil_to(sk, 8))
    sq_p, sk_p = ceil_to(sq, block_q), ceil_to(sk, block_k)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0)))
    grid = (bh, sq_p // block_q, sk_p // block_k)

    out = pl.pallas_call(
        functools.partial(
            _kernel, n_kv=grid[2], block_q=block_q, block_k=block_k, sk=sk,
            causal=causal, scale=scale, interpret=interpret,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
