"""Jitted public wrapper: GQA layout handling + CPU interpret fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.pltpu_compat import should_interpret


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """q: [B, Sq, H, D]; k/v: [B, Sk, KV, D] (GQA). Returns [B, Sq, H, D]."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    if h != kvh:
        mapping = (jnp.arange(h) * kvh) // h
        k = jnp.take(k, mapping, axis=2)
        v = jnp.take(v, mapping, axis=2)
    q2 = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    k2 = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    v2 = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    o = flash_attention_pallas(q2, k2, v2, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=should_interpret())
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
