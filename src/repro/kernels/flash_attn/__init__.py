from repro.kernels.flash_attn.kernel import flash_attention_pallas  # noqa: F401
from repro.kernels.flash_attn.ops import flash_attention  # noqa: F401
from repro.kernels.flash_attn.paged import (  # noqa: F401
    paged_attention,
    paged_attention_pallas,
    paged_attention_ref,
    paged_kernel_available,
    paged_vmem_bytes,
)
from repro.kernels.flash_attn.ref import flash_attention_ref  # noqa: F401
