from repro.kernels.colwise_nm.kernel import (  # noqa: F401
    colwise_nm_matmul_pallas,
    colwise_nm_matmul_strips_pallas,
    colwise_nm_matmul_strips_pipelined_pallas,
    pipelined_strips_vmem_bytes,
    strips_vmem_bytes,
    vmem_bytes,
)
from repro.kernels.colwise_nm.ops import (  # noqa: F401
    colwise_nm_matmul,
    colwise_nm_matmul_strips,
    colwise_nm_matmul_strips_pipelined,
    sparse_grad_dvalues,
    sparse_grad_dxg,
)
from repro.kernels.colwise_nm.ref import colwise_nm_matmul_ref  # noqa: F401
