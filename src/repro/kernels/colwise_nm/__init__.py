from repro.kernels.colwise_nm.kernel import colwise_nm_matmul_pallas, vmem_bytes  # noqa: F401
from repro.kernels.colwise_nm.ops import colwise_nm_matmul  # noqa: F401
from repro.kernels.colwise_nm.ref import colwise_nm_matmul_ref  # noqa: F401
