"""Jitted public wrapper for the column-wise N:M sparse matmul kernel.

Adds: leading-dim flattening, CPU interpret-mode auto-detection, and a
custom VJP so the kernel is usable inside training graphs (backward runs as
XLA gather/scatter — the forward is the latency-critical path the paper
optimizes; its backward appears only in sparse finetuning).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.colwise_nm.kernel import (
    colwise_nm_matmul_pallas,
    colwise_nm_matmul_strips_pallas,
    colwise_nm_matmul_strips_pipelined_pallas,
)
from repro.kernels.pltpu_compat import should_interpret


@functools.partial(jax.jit, static_argnames=("block_k",))
def colwise_nm_matmul_strips(strips, values, idx, *, block_k: int = 128):
    """Strip-major sparse GEMM: packed [n_strips, K, V] strips -> [O, S*V].

    Accepts ``im2col_pack`` output directly (strip dim = Pallas batch grid
    dim), so the two-kernel conv path skips the ``transpose(0,2,1).reshape``
    HBM relayout entirely.  Columns past the true position count are strip
    padding; the conv wrapper slices them off.
    """
    return colwise_nm_matmul_strips_pallas(
        strips, values, idx, block_k=block_k, interpret=should_interpret()
    )


@functools.partial(jax.jit, static_argnames=("block_k", "hb"))
def colwise_nm_matmul_strips_pipelined(strips, values, idx, *,
                                       block_k: int = 128, hb: int = 2):
    """Double-buffered strip-major sparse GEMM (same contract as
    :func:`colwise_nm_matmul_strips`): strips stay in HBM and chunks of
    ``hb`` strips are async-copied into VMEM while the previous chunk's GEMM
    runs — the overlapped half of the pipelined two-kernel conv plan."""
    return colwise_nm_matmul_strips_pipelined_pallas(
        strips, values, idx, block_k=block_k, hb=hb,
        interpret=should_interpret()
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _matmul(x, values, idx, block_b, block_k):
    return colwise_nm_matmul_pallas(
        x, values, idx, block_b=block_b, block_k=block_k, interpret=should_interpret()
    )


def _fwd(x, values, idx, block_b, block_k):
    y = _matmul(x, values, idx, block_b, block_k)
    return y, (x, values, idx)


def _bwd(block_b, block_k, res, dy):
    x, values, idx = res
    n_tiles, k_kept, tile = values.shape
    dy_t = dy.reshape(*dy.shape[:-1], n_tiles, tile)
    # dL/d(x_gathered) then scatter-add back to d_in positions
    dxg = jnp.einsum("...tf,tkf->...tk", dy_t, values)
    dx = jnp.zeros_like(x).at[..., idx].add(dxg)
    xg = jnp.take(x, idx, axis=-1)  # [..., n_tiles, k]
    dvalues = jnp.einsum("...tk,...tf->tkf", xg, dy_t).astype(values.dtype)
    return dx, dvalues, None


_matmul.defvjp(_fwd, _bwd)


def colwise_nm_matmul(
    x: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    block_b: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """y = colwise-N:M-sparse matmul, any leading batch dims on x."""
    n_tiles, k_kept, tile = values.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _matmul(x2, values, idx, block_b, block_k)
    return y.reshape(*lead, n_tiles * tile)
