"""Jitted public wrapper for the column-wise N:M sparse matmul kernel.

Adds: leading-dim flattening, CPU interpret-mode auto-detection, and a
custom VJP so the kernel is usable inside training graphs (backward runs as
XLA gather/scatter — the forward is the latency-critical path the paper
optimizes; its backward appears only in sparse finetuning).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.colwise_nm.kernel import (
    colwise_nm_matmul_pallas,
    colwise_nm_matmul_strips_pallas,
    colwise_nm_matmul_strips_pipelined_pallas,
)
from repro.kernels.pltpu_compat import should_interpret


@functools.partial(jax.jit, static_argnames=("block_k",))
def colwise_nm_matmul_strips(strips, values, idx, *, block_k: int = 128):
    """Strip-major sparse GEMM: packed [n_strips, K, V] strips -> [O, S*V].

    Accepts ``im2col_pack`` output directly (strip dim = Pallas batch grid
    dim), so the two-kernel conv path skips the ``transpose(0,2,1).reshape``
    HBM relayout entirely.  Columns past the true position count are strip
    padding; the conv wrapper slices them off.
    """
    return colwise_nm_matmul_strips_pallas(
        strips, values, idx, block_k=block_k, interpret=should_interpret()
    )


@functools.partial(jax.jit, static_argnames=("block_k", "hb"))
def colwise_nm_matmul_strips_pipelined(strips, values, idx, *,
                                       block_k: int = 128, hb: int = 2):
    """Double-buffered strip-major sparse GEMM (same contract as
    :func:`colwise_nm_matmul_strips`): strips stay in HBM and chunks of
    ``hb`` strips are async-copied into VMEM while the previous chunk's GEMM
    runs — the overlapped half of the pipelined two-kernel conv plan."""
    return colwise_nm_matmul_strips_pipelined_pallas(
        strips, values, idx, block_k=block_k, hb=hb,
        interpret=should_interpret()
    )


# ---------------------------------------------------------------------------
# Shared backward contractions — used by this linear VJP and by the conv twin
# (``conv_gemm/ops.py``), which sees the same [.., n_tiles, k]/[.., n_tiles,
# tile] layouts with its flattened output positions as the leading dim.  Both
# einsums accumulate in float32 (``preferred_element_type``): for bf16 params
# the gradient contraction would otherwise run entirely in bf16 and lose
# ~half the mantissa over the reduction.
# ---------------------------------------------------------------------------


def sparse_grad_dxg(dy_t, values):
    """dL/d(gathered activations) of ``y_t = xg @ values[t]``.

    dy_t: [..., n_tiles, tile]; values: [n_tiles, k, tile].
    Returns [..., n_tiles, k] in float32 (caller scatters, then casts).
    """
    return jnp.einsum("...tf,tkf->...tk", dy_t, values,
                      preferred_element_type=jnp.float32)


def sparse_grad_dvalues(xg, dy_t, dtype):
    """dL/dvalues of ``y_t = xg @ values[t]``: gathered-activation x dy
    contraction over the leading (row/position) dims, f32 accumulation.

    xg: [..., n_tiles, k]; dy_t: [..., n_tiles, tile].
    Returns [n_tiles, k, tile] cast to the param ``dtype``.
    """
    return jnp.einsum("...tk,...tf->tkf", xg, dy_t,
                      preferred_element_type=jnp.float32).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _matmul(x, values, idx, block_b, block_k):
    return colwise_nm_matmul_pallas(
        x, values, idx, block_b=block_b, block_k=block_k, interpret=should_interpret()
    )


def _fwd(x, values, idx, block_b, block_k):
    y = _matmul(x, values, idx, block_b, block_k)
    return y, (x, values, idx)


def _bwd(block_b, block_k, res, dy):
    x, values, idx = res
    n_tiles, k_kept, tile = values.shape
    dy_t = dy.reshape(*dy.shape[:-1], n_tiles, tile)
    # dL/d(x_gathered) then scatter-add back to d_in positions.  The scatter
    # accumulates in a float32 buffer: tiles sharing a kept d_in index (the
    # duplicate-scatter case) add their contributions there, and only the
    # final sum is cast back to x's dtype.
    dxg = sparse_grad_dxg(dy_t, values)  # [..., t, k] f32
    dx = (jnp.zeros(x.shape, jnp.float32).at[..., idx].add(dxg)
          .astype(x.dtype))
    xg = jnp.take(x, idx, axis=-1)  # [..., n_tiles, k]
    dvalues = sparse_grad_dvalues(xg, dy_t, values.dtype)
    return dx, dvalues, None


_matmul.defvjp(_fwd, _bwd)


def colwise_nm_matmul(
    x: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    block_b: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """y = colwise-N:M-sparse matmul, any leading batch dims on x."""
    n_tiles, k_kept, tile = values.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _matmul(x2, values, idx, block_b, block_k)
    return y.reshape(*lead, n_tiles * tile)
