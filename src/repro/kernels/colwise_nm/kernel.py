"""Pallas TPU kernel for column-wise N:M sparse matmul (paper Algorithm 1).

TPU adaptation of the RVV micro-kernel:

  RVV                         TPU (this kernel)
  -------------------------   ---------------------------------------------
  T vector-register           float32 VMEM scratch accumulator [block_b, T]
  accumulators
  scalar weight × data         dense [block_b, block_k] × [block_k, T] MXU
  vector vfmacc per kept       matmul per kept-column *chunk* (the gather of
  column                       block_k kept columns happens in VMEM first)
  indexed vector load of the   lane-dimension gather ``x_blk[:, ids]`` from
  data-matrix row              the VMEM-resident activation block
  LMUL / vector length         block_k, tile width T (lane multiples of 128)

The kept-column indices are shared by the whole T-wide output tile (the
paper's column-wise constraint), which is exactly what makes the inner step a
*dense* MXU matmul — sparsity is realized as a shorter contraction, not as
masked compute.

Grid: (B/block_b, n_tiles, k_kept/block_k); the last dimension is a sequential
("arbitrary") accumulation dimension, the first two are parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import COMPILER_PARAMS as _COMPILER_PARAMS
from repro.kernels.pltpu_compat import (
    MEM_ANY,
    ceil_to,
    dma_semaphores,
    dot_f32,
    double_buffer_rotate,
    make_async_copy,
)


def _kernel(x_ref, idx_ref, v_ref, o_ref, acc_ref, *, n_kc: int, out_dtype, interpret: bool):
    kc = pl.program_id(2)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = idx_ref[0]  # [block_k] int32 — kept d_in indices for this chunk
    x_blk = x_ref[...]  # [block_b, d_in] activation rows (VMEM resident)
    # In-VMEM gather of the kept columns: the fusion of "im2col/packing" style
    # data movement into the compute kernel — the gathered operand never
    # exists in HBM.  (Mosaic: lane-dim dynamic_gather; validated via
    # interpret mode on CPU.)
    x_sel = jnp.take(x_blk, ids, axis=1)  # [block_b, block_k]
    acc_ref[...] += dot_f32(x_sel, v_ref[0], interpret)

    @pl.when(kc == n_kc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def colwise_nm_matmul_pallas(
    x: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    block_b: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y[b, t*T:(t+1)*T] = x[b, idx[t]] @ values[t].

    x: [B, d_in]; values: [n_tiles, k_kept, T]; idx: [n_tiles, k_kept].
    Returns [B, n_tiles * T].
    """
    B, d_in = x.shape
    n_tiles, k_kept, tile = values.shape
    assert idx.shape == (n_tiles, k_kept), (idx.shape, values.shape)

    block_b = min(block_b, ceil_to(B, 8))
    block_k = min(block_k, ceil_to(k_kept, 8))

    b_pad = ceil_to(B, block_b)
    k_pad = ceil_to(k_kept, block_k)
    if b_pad != B:
        x = jnp.pad(x, ((0, b_pad - B), (0, 0)))
    if k_pad != k_kept:
        # zero-valued padding rows gather x[:, 0] but multiply by 0 weights
        values = jnp.pad(values, ((0, 0), (0, k_pad - k_kept), (0, 0)))
        idx = jnp.pad(idx, ((0, 0), (0, k_pad - k_kept)))

    n_b = b_pad // block_b
    n_kc = k_pad // block_k
    grid = (n_b, n_tiles, n_kc)

    out = pl.pallas_call(
        functools.partial(_kernel, n_kc=n_kc, out_dtype=x.dtype, interpret=interpret),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i, t, kc: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i, t, kc: (t, kc)),
            pl.BlockSpec((1, block_k, tile), lambda i, t, kc: (t, kc, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, tile), lambda i, t, kc: (i, t)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_tiles * tile), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, tile), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, idx, values)
    return out[:B]


# ---------------------------------------------------------------------------
# Strip-major entry: GEMM directly on packed [n_strips, K, V] strips
# ---------------------------------------------------------------------------


def _strips_kernel(x_ref, idx_ref, v_ref, o_ref, acc_ref, *, n_kc: int,
                   out_dtype, interpret: bool):
    kc = pl.program_id(2)

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = idx_ref[0]  # [block_k] kept reduction rows for this chunk
    x_blk = x_ref[0]  # [K, V] one packed strip, VMEM resident
    # sublane-dim gather of the kept strip *rows* — the strips already sit in
    # the paper's packed layout, so no transpose/relayout ever happens in HBM
    x_sel = jnp.take(x_blk, ids, axis=0)  # [block_k, V]
    acc_ref[...] += dot_f32(v_ref[0].T, x_sel, interpret)  # [tile, V]

    @pl.when(kc == n_kc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def colwise_nm_matmul_strips_pallas(
    strips: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Column-wise sparse GEMM on packed strips: [n_strips, K, V] -> [O, S*V].

    The strip dim is the Pallas batch grid dim, so the un-fused two-kernel
    conv path consumes ``im2col_pack`` output directly — no
    ``transpose(0, 2, 1).reshape`` HBM relayout between the two kernels.
    Output is [n_tiles*tile, n_strips*V] (the conv's [O, P] layout, P padded
    to whole strips); the caller slices off the ragged-strip padding.
    """
    n_strips, d_in, v = strips.shape
    n_tiles, k_kept, tile = values.shape
    assert idx.shape == (n_tiles, k_kept), (idx.shape, values.shape)

    block_k = min(block_k, ceil_to(k_kept, 8))
    k_pad = ceil_to(k_kept, block_k)
    if k_pad != k_kept:
        values = jnp.pad(values, ((0, 0), (0, k_pad - k_kept), (0, 0)))
        idx = jnp.pad(idx, ((0, 0), (0, k_pad - k_kept)))
    n_kc = k_pad // block_k

    grid = (n_strips, n_tiles, n_kc)
    out = pl.pallas_call(
        functools.partial(_strips_kernel, n_kc=n_kc, out_dtype=strips.dtype,
                          interpret=interpret),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d_in, v), lambda s, t, kc: (s, 0, 0)),
            pl.BlockSpec((1, block_k), lambda s, t, kc: (t, kc)),
            pl.BlockSpec((1, block_k, tile), lambda s, t, kc: (t, kc, 0)),
        ],
        out_specs=pl.BlockSpec((tile, v), lambda s, t, kc: (t, s)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile, n_strips * v),
                                       strips.dtype),
        scratch_shapes=[pltpu.VMEM((tile, v), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(strips, idx, values)
    return out


def strips_vmem_bytes(d_in: int, v: int, block_k: int, tile: int,
                      in_bytes: int = 2) -> int:
    """Analytic VMEM footprint of one strip-major grid step."""
    strip = d_in * v * in_bytes
    x_sel = block_k * v * in_bytes
    v_blk = block_k * tile * in_bytes
    acc = tile * v * 4
    out = tile * v * in_bytes
    return strip + x_sel + v_blk + acc + out


# ---------------------------------------------------------------------------
# Pipelined strip-major entry: strips stay in HBM, chunks of ``hb`` strips
# are double-buffered into VMEM scratch — the copy of chunk g+1 overlaps the
# GEMM of chunk g, removing the pack->GEMM back-to-back serialization of the
# two-kernel conv plan.
# ---------------------------------------------------------------------------


def _strips_pipelined_kernel(
    x_ref,        # [n_strips, K, V] packed strips, NOT block-mapped (HBM)
    idx_ref,
    v_ref,
    o_ref,
    buf_ref,      # [2*hb, K, V] double-buffered strip-chunk scratch
    sem_ref,      # [2] DMA completion semaphores
    acc_ref,
    *,
    hb: int,
    n_chunks: int,
    n_strips: int,
    n_kc: int,
    out_dtype,
    interpret: bool,
):
    s = pl.program_id(0)
    t = pl.program_id(1)
    kc = pl.program_id(2)
    g = s // hb

    def origin(gi):
        # fixed-size chunks: the final (ragged) chunk re-covers the tail of
        # the previous one instead of reading past the strip array
        return jnp.minimum(gi * hb, n_strips - hb)

    def chunk_dma(slot, gi):
        return make_async_copy(
            x_ref.at[pl.ds(origin(gi), hb)],
            buf_ref.at[pl.ds(slot * hb, hb)],
            sem_ref.at[slot],
        )

    double_buffer_rotate(chunk_dma, g, n_chunks,
                         gate=(s % hb == 0) & (t == 0) & (kc == 0))

    @pl.when(kc == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = idx_ref[0]
    x_blk = buf_ref[(g % 2) * hb + (s - origin(g))]  # [K, V], VMEM resident
    x_sel = jnp.take(x_blk, ids, axis=0)  # [block_k, V]
    acc_ref[...] += dot_f32(v_ref[0].T, x_sel, interpret)  # [tile, V]

    @pl.when(kc == n_kc - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def colwise_nm_matmul_strips_pipelined_pallas(
    strips: jax.Array,
    values: jax.Array,
    idx: jax.Array,
    *,
    block_k: int = 128,
    hb: int = 2,
    interpret: bool = False,
) -> jax.Array:
    """Double-buffered strip-major sparse GEMM: [n_strips, K, V] -> [O, S*V].

    Same contract as :func:`colwise_nm_matmul_strips_pallas`, but the strips
    array is NOT pipelined block-by-block by Pallas: it stays in HBM and the
    kernel DMAs chunks of ``hb`` strips into a two-slot VMEM scratch, always
    copying chunk g+1 while the GEMM consumes chunk g.
    """
    n_strips, d_in, v = strips.shape
    n_tiles, k_kept, tile = values.shape
    assert idx.shape == (n_tiles, k_kept), (idx.shape, values.shape)

    hb = max(min(hb, n_strips), 1)
    n_chunks = -(-n_strips // hb)

    block_k = min(block_k, ceil_to(k_kept, 8))
    k_pad = ceil_to(k_kept, block_k)
    if k_pad != k_kept:
        values = jnp.pad(values, ((0, 0), (0, k_pad - k_kept), (0, 0)))
        idx = jnp.pad(idx, ((0, 0), (0, k_pad - k_kept)))
    n_kc = k_pad // block_k

    grid = (n_strips, n_tiles, n_kc)
    out = pl.pallas_call(
        functools.partial(
            _strips_pipelined_kernel, hb=hb, n_chunks=n_chunks,
            n_strips=n_strips, n_kc=n_kc, out_dtype=strips.dtype,
            interpret=interpret),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=MEM_ANY),  # strips stay in HBM
            pl.BlockSpec((1, block_k), lambda s, t, kc: (t, kc)),
            pl.BlockSpec((1, block_k, tile), lambda s, t, kc: (t, kc, 0)),
        ],
        out_specs=pl.BlockSpec((tile, v), lambda s, t, kc: (t, s)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile, n_strips * v),
                                       strips.dtype),
        scratch_shapes=[
            pltpu.VMEM((2 * hb, d_in, v), strips.dtype),
            dma_semaphores(2),
            pltpu.VMEM((tile, v), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            # strips advance sequentially: the double-buffer rotation assumes
            # chunk g's steps complete before chunk g+1's begin
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(strips, idx, values)
    return out


def pipelined_strips_vmem_bytes(d_in: int, v: int, hb: int, block_k: int,
                                tile: int, in_bytes: int = 2) -> int:
    """Analytic VMEM footprint of one pipelined strip-GEMM grid step: TWO
    chunks of ``hb`` strips (double buffer) plus the gather/weight/acc/out
    tiles of the plain strip-major kernel."""
    chunks = 2 * hb * d_in * v * in_bytes
    x_sel = block_k * v * in_bytes
    v_blk = block_k * tile * in_bytes
    acc = tile * v * 4
    out = tile * v * in_bytes
    return chunks + x_sel + v_blk + acc + out


def vmem_bytes(block_b: int, block_k: int, d_in: int, tile: int, in_bytes: int = 2) -> int:
    """Analytic VMEM footprint of one grid step (for the auto-tuner)."""
    x_blk = block_b * d_in * in_bytes
    x_sel = block_b * block_k * in_bytes
    v_blk = block_k * tile * in_bytes
    acc = block_b * tile * 4
    out = block_b * tile * in_bytes
    return x_blk + x_sel + v_blk + acc + out
