"""Pure-jnp oracle for the column-wise N:M sparse matmul kernel.

Deliberately implemented by *decompressing to a dense masked weight* and
running a dense matmul, so it shares no code path with either the Pallas
kernel or the gather-based XLA fast path it validates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import ColwiseMeta, unpack_colwise


def colwise_nm_matmul_ref(x: jax.Array, values: jax.Array, idx: jax.Array, d_in=None) -> jax.Array:
    n_tiles, k_kept, tile = values.shape
    if d_in is None:
        d_in = x.shape[-1]
    # meta: m/n only matter for density bookkeeping, not for unpack
    meta = ColwiseMeta(d_in=d_in, d_out=n_tiles * tile, tile=tile, m=d_in, n=k_kept)
    w_dense = unpack_colwise(values, idx, meta)  # [d_in, d_out]
    return x @ w_dense
