"""Operator registry: the candidate implementations behind each logical op.

AITemplate keeps, per operator, a list of generated kernels plus a profiler
that races them on the target; TensorRT-LLM hides per-phase implementations
behind one operator facade.  This registry is the analogous single
registration point for this repo: a logical op (``linear``, ``conv``) maps to
a list of :class:`ImplSpec` candidates, each declaring

  * ``requires``   — which param-dict keys it can execute from (a compressed
    layer can only run compressed candidates; a dense layer only dense ones),
  * ``feasible``   — a static predicate over the :class:`OpKey` (VMEM budget,
    divisibility, backend availability) returning (ok, reason),
  * ``vmem_bytes`` — analytic footprint used for tie-breaks and fallbacks,
  * ``apply``      — how to execute the layer's params on an input,
  * ``make_bench`` — how to synthesize a self-contained benchmark closure for
    the profiler (operands built from the key alone, no real params needed),
  * ``geometry``   — the execution-geometry knobs (block sizes, strip width)
    this variant is pinned to.

Execution geometry lives IN the candidate space: a Pallas kernel registers
one candidate per point of its geometry grid (``compressed_pallas`` plus
``compressed_pallas@bb256_bk128`` …, ``fused_sparse_pallas`` plus
``fused_sparse_pallas@v256_bk128`` …), each with its own VMEM predicate, so a
single ``profile_op`` pass picks implementation AND geometry jointly and
bakes both into one profile-DB record.  This replaced the seed's separate
``Tuner`` tier (tile × block_b × block_k), which survives only as a
deprecated compatibility shim.

New kernels/backends register here once and every call site that consults
``repro.dispatch.best_impl`` picks them up — no per-call-site if/else chains.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

VMEM_BYTES = 16 * 2 ** 20  # ~16 MB usable per TPU core (paper §3.3 analog)


def bucket_batch(n: int) -> int:
    """Round a leading-dim size up to a power of two (min 8) so the profile
    DB is keyed by a bounded family of batch buckets, not every exact size."""
    b = 8
    while b < n:
        b *= 2
    return b


def bucket_dim(n: int) -> int:
    """Power-of-two bucket for the reduction dim of linear keys.  Both the
    trace-time call site (which knows the exact d_in from the activation) and
    the build-time params scan (which can only bound d_in by max kept index)
    land in the same bucket, so their DB tokens agree."""
    return bucket_batch(n)


@dataclasses.dataclass(frozen=True)
class OpKey:
    """Hashable identity of one operator instance (static shapes only)."""

    op: str          # "linear" | "conv"
    batch: int       # bucketed leading-dim rows (GEMM) / output positions (conv)
    d_in: int        # reduction dim (linear) / kh*kw*c (conv)
    d_out: int
    k_kept: int      # kept reduction indices per tile (== d_in when dense)
    tile: int        # output-feature tile width sharing one index set
    dtype: str = "f32"
    extra: Tuple[Tuple[str, int], ...] = ()
    # serving-phase tag ("prefill" | "decode"); "" = phase-agnostic.  The same
    # layer weights see [B*S]-row operands during prefill and [B]-row operands
    # during decode, and the profiled winner differs between the two shapes
    # (TensorRT-LLM-style per-phase operator specialization), so phase-tagged
    # keys get distinct profile-DB entries.  Untagged keys keep the exact
    # pre-phase token format, so existing DBs stay valid.
    phase: str = ""

    @property
    def token(self) -> str:
        """Stable string key for the profile DB."""
        base = (f"{self.op}|b{self.batch}|i{self.d_in}|o{self.d_out}"
                f"|k{self.k_kept}|t{self.tile}|{self.dtype}")
        for k, v in self.extra:
            base += f"|{k}{v}"
        if self.phase:
            base += f"|ph:{self.phase}"
        return base

    def get(self, name: str, default: int = 0) -> int:
        for k, v in self.extra:
            if k == name:
                return v
        return default


def _dtype_tag(dtype) -> str:
    import numpy as np

    try:
        name = np.dtype(dtype).name  # accepts instances, classes, strings
    except TypeError:
        name = str(dtype)
    return {"float32": "f32", "bfloat16": "bf16", "float16": "f16"}.get(
        name, name)


def linear_key(batch: int, d_in: int, d_out: int, k_kept: int, tile: int,
               dtype="float32", phase: str = "") -> OpKey:
    return OpKey(op="linear", batch=bucket_batch(batch), d_in=bucket_dim(d_in),
                 d_out=d_out, k_kept=k_kept, tile=tile, dtype=_dtype_tag(dtype),
                 phase=phase)


def linear_key_from(x_shape: Sequence[int], values_shape: Sequence[int],
                    dtype="float32", phase: str = "") -> OpKey:
    """OpKey from an activation shape and a compressed values shape.

    ``values_shape`` may carry scan/stacked leading dims; only the trailing
    [n_tiles, k_kept, tile] matter for dispatch.
    """
    n_tiles, k_kept, tile = values_shape[-3:]
    rows = 1
    for s in x_shape[:-1]:
        rows *= int(s)
    return linear_key(max(rows, 1), int(x_shape[-1]), int(n_tiles * tile),
                      int(k_kept), int(tile), dtype, phase=phase)


def conv_key(c: int, h: int, w: int, o: int, kh: int, kw: int, stride: int,
             pad: int, k_kept: int, tile: int, v: int = 128,
             dtype="float32", batch: int = 1, phase: str = "") -> OpKey:
    """OpKey for a conv operator instance.  ``phase`` mirrors ``linear_key``:
    a conv traced inside ``dispatch.phase_scope`` gets a phase-tagged token
    (and hence its own profile-DB entry) instead of silently profiling
    phase-agnostic."""
    n_pos_h = (h + 2 * pad - kh) // stride + 1
    n_pos_w = (w + 2 * pad - kw) // stride + 1
    return OpKey(
        op="conv", batch=bucket_batch(max(batch * n_pos_h * n_pos_w, 1)),
        d_in=kh * kw * c, d_out=o, k_kept=k_kept, tile=tile,
        dtype=_dtype_tag(dtype),
        extra=(("b", batch), ("c", c), ("h", h), ("w", w), ("kh", kh),
               ("kw", kw), ("s", stride), ("p", pad), ("v", v)),
        phase=phase,
    )


@dataclasses.dataclass(frozen=True)
class ImplSpec:
    """One candidate implementation of a logical op.

    A Pallas kernel family registers one ImplSpec per execution-geometry
    point (``geometry`` carries the block sizes / strip width the variant is
    pinned to; the default-geometry variant keeps the bare family name, the
    rest get an ``@k1v1_k2v2`` suffix via :func:`geometry_name`), so the
    profiler selects implementation and geometry in one pass.
    """

    name: str
    op: str
    backend: str                       # "xla" | "pallas"
    requires: frozenset                # param keys it executes from
    priority: int                      # heuristic rank (lower preferred)
    feasible: Callable[[OpKey], Tuple[bool, str]]
    vmem_bytes: Callable[[OpKey], int]
    apply: Optional[Callable] = None   # (params, x, **op_args) -> y
    make_bench: Optional[Callable] = None  # key -> zero-arg timed closure
    geometry: Tuple[Tuple[str, int], ...] = ()

    def geom(self, name: str, default: int = 0) -> int:
        for k, v in self.geometry:
            if k == name:
                return v
        return default

    def __repr__(self):
        return f"ImplSpec({self.op}:{self.name}, backend={self.backend})"


def geometry_name(base: str, geometry: Tuple[Tuple[str, int], ...],
                  default: Tuple[Tuple[str, int], ...]) -> str:
    """Candidate name for one geometry point: the default geometry keeps the
    bare family name (profile-DB/force back-compat), others get a suffix like
    ``base@bb256_bk128``."""
    if geometry == default:
        return base
    return base + "@" + "_".join(f"{k}{v}" for k, v in geometry)


class OperatorRegistry:
    def __init__(self):
        self._impls: Dict[str, Dict[str, ImplSpec]] = {}
        self.generation = 0  # bumped on register(); invalidates dispatch memos

    def register(self, spec: ImplSpec) -> ImplSpec:
        self._impls.setdefault(spec.op, {})[spec.name] = spec
        self.generation += 1
        return spec

    def ops(self) -> List[str]:
        return sorted(self._impls)

    def get(self, op: str, name: str) -> ImplSpec:
        try:
            return self._impls[op][name]
        except KeyError:
            known = sorted(self._impls.get(op, {}))
            raise KeyError(
                f"no impl {name!r} registered for op {op!r}; known: {known}"
            ) from None

    def candidates(self, op: str, *, param_keys=None) -> List[ImplSpec]:
        """All candidates for an op, optionally filtered to those executable
        from a given param-dict key set.

        Only *most-specific* matches are kept: a candidate whose ``requires``
        is a strict subset of another executable candidate's is dropped, so
        e.g. ``dense`` (requires {w}) can never be selected for a masked
        layer ({w, mask}) and silently ignore the mask.
        """
        specs = list(self._impls.get(op, {}).values())
        if param_keys is not None:
            pk = frozenset(param_keys)
            specs = [s for s in specs if s.requires <= pk]
            specs = [s for s in specs
                     if not any(s.requires < o.requires for o in specs)]
        return specs

    def feasible(self, key: OpKey, *, param_keys=None) -> List[ImplSpec]:
        return [s for s in self.candidates(key.op, param_keys=param_keys)
                if s.feasible(key)[0]]


REGISTRY = OperatorRegistry()


# ---------------------------------------------------------------------------
# Built-in linear candidates
# ---------------------------------------------------------------------------


def _always(key: OpKey) -> Tuple[bool, str]:
    return True, "ok"


def _no_vmem(key: OpKey) -> int:
    return 0


# Per-op geometry grids.  Each point becomes one registered candidate; the
# first entry is the default geometry and keeps the bare family name.
LINEAR_GEOMETRY = (
    (("bb", 128), ("bk", 128)),
    (("bb", 256), ("bk", 128)),
    (("bb", 128), ("bk", 64)),
)
FUSED_CONV_GEOMETRY = (
    (("v", 128), ("bk", 128)),
    (("v", 256), ("bk", 128)),
    (("v", 128), ("bk", 64)),
)
# Banded/pipelined conv plans: strip width x block_k x band depth ``hb``
# (strips per double-buffered DMA — row band for the banded megakernel,
# strip chunk for the pipelined two-kernel GEMM).  Shallow bands minimize
# VMEM, deep bands amortize DMA issue overhead; the profiler picks.
BANDED_CONV_GEOMETRY = (
    (("v", 128), ("bk", 128), ("hb", 2)),
    (("v", 256), ("bk", 128), ("hb", 2)),
    (("v", 128), ("bk", 128), ("hb", 4)),
    (("v", 128), ("bk", 64), ("hb", 1)),
)


def _key_itemsize(key: OpKey) -> int:
    """Operand byte width from the key's dtype tag (f32 maps under-count VMEM
    2x if assumed bf16 — load-bearing for the whole-map-resident megakernel)."""
    return 4 if key.dtype == "f32" else 2


def _tile_ok(key: OpKey) -> Tuple[bool, str]:
    if key.d_out % key.tile != 0:
        return False, f"d_out={key.d_out} not divisible by tile={key.tile}"
    if key.tile % 8 != 0:
        return False, f"tile={key.tile} not a multiple of 8 (sublane)"
    return True, "ok"


def _pallas_vmem_for(block_b: int, block_k: int):
    def vm(key: OpKey) -> int:
        from repro.kernels.colwise_nm.kernel import vmem_bytes

        return vmem_bytes(min(block_b, key.batch), min(block_k, key.k_kept),
                          key.d_in, min(key.tile, 512),
                          in_bytes=_key_itemsize(key))

    return vm


def _pallas_feasible_for(block_b: int, block_k: int):
    vm_fn = _pallas_vmem_for(block_b, block_k)

    def feasible(key: OpKey) -> Tuple[bool, str]:
        ok, reason = _tile_ok(key)
        if not ok:
            return ok, reason
        vm = vm_fn(key)
        if vm > VMEM_BYTES:
            return False, f"VMEM {vm} > budget {VMEM_BYTES}"
        return True, "ok"

    return feasible


# default-geometry predicates (shared by the strip-major conv candidate)
_pallas_feasible = _pallas_feasible_for(128, 128)
_pallas_vmem = _pallas_vmem_for(128, 128)


def _jnp_dtype(tag: str):
    import jax.numpy as jnp

    return {"f32": jnp.float32, "bf16": jnp.bfloat16,
            "f16": jnp.float16}.get(tag, jnp.float32)


def _rand(shape, seed=0, dtype_tag: str = "f32"):
    import jax

    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    return x.astype(_jnp_dtype(dtype_tag))


def _synth_compressed(key: OpKey):
    """Strided synthetic (values, idx) matching the key's geometry/dtype."""
    import jax.numpy as jnp

    n_tiles = key.d_out // key.tile
    values = _rand((n_tiles, key.k_kept, key.tile), seed=1,
                   dtype_tag=key.dtype) / (key.k_kept ** 0.5)
    values = values.astype(_jnp_dtype(key.dtype))
    stride = max(key.d_in // key.k_kept, 1)
    idx1 = (jnp.arange(key.k_kept, dtype=jnp.int32) * stride) % key.d_in
    idx = jnp.broadcast_to(jnp.sort(idx1)[None, :], (n_tiles, key.k_kept))
    return values, jnp.asarray(idx, jnp.int32)


def _bench_linear_xla(key: OpKey):
    import jax

    from repro.core.sparse_linear import forward_compressed_xla

    x = _rand((key.batch, key.d_in), dtype_tag=key.dtype)
    values, idx = _synth_compressed(key)
    f = jax.jit(lambda x: forward_compressed_xla(x, values, idx))
    return lambda: f(x)


def _bench_linear_pallas(key: OpKey, block_b: int = 128, block_k: int = 128):
    import jax

    from repro.kernels.colwise_nm import ops as cops

    x = _rand((key.batch, key.d_in), dtype_tag=key.dtype)
    values, idx = _synth_compressed(key)
    # jitted like every other candidate's closure: profiling must compare
    # steady-state (traced) execution, not eager per-op dispatch overhead
    f = jax.jit(lambda x: cops.colwise_nm_matmul(x, values, idx,
                                                 block_b=block_b,
                                                 block_k=block_k))
    return lambda: f(x)


def _bench_linear_dense(key: OpKey):
    import jax

    x = _rand((key.batch, key.d_in), dtype_tag=key.dtype)
    w = _rand((key.d_in, key.d_out), seed=2, dtype_tag=key.dtype) / (key.d_in ** 0.5)
    f = jax.jit(lambda x: x @ w)
    return lambda: f(x)


def _apply_linear_xla(params, x):
    from repro.core.sparse_linear import forward_compressed_xla

    return forward_compressed_xla(x, params["values"], params["idx"])


def _apply_linear_pallas(params, x, block_b: int = 128, block_k: int = 128):
    from repro.kernels.colwise_nm import ops as cops

    return cops.colwise_nm_matmul(x, params["values"], params["idx"],
                                  block_b=block_b, block_k=block_k)


def _apply_linear_masked(params, x):
    from repro.core.sparse_linear import forward_masked

    return forward_masked(x, params["w"], params["mask"])


def _apply_linear_dense(params, x):
    return x @ params["w"]


REGISTRY.register(ImplSpec(
    name="compressed_xla", op="linear", backend="xla",
    requires=frozenset({"values", "idx"}), priority=10,
    feasible=_always, vmem_bytes=_no_vmem,
    apply=_apply_linear_xla, make_bench=_bench_linear_xla,
))

# one candidate per geometry point — profile_op races them all, so a single
# profiling pass picks implementation AND block geometry jointly
for _geom in LINEAR_GEOMETRY:
    _bb, _bk = dict(_geom)["bb"], dict(_geom)["bk"]
    REGISTRY.register(ImplSpec(
        name=geometry_name("compressed_pallas", _geom, LINEAR_GEOMETRY[0]),
        op="linear", backend="pallas",
        requires=frozenset({"values", "idx"}), priority=10,
        feasible=_pallas_feasible_for(_bb, _bk),
        vmem_bytes=_pallas_vmem_for(_bb, _bk),
        apply=functools.partial(_apply_linear_pallas, block_b=_bb, block_k=_bk),
        make_bench=functools.partial(_bench_linear_pallas, block_b=_bb,
                                     block_k=_bk),
        geometry=_geom,
    ))

REGISTRY.register(ImplSpec(
    name="masked", op="linear", backend="xla",
    requires=frozenset({"w", "mask"}), priority=20,
    feasible=_always, vmem_bytes=_no_vmem,
    apply=_apply_linear_masked, make_bench=_bench_linear_dense,
))

REGISTRY.register(ImplSpec(
    name="dense", op="linear", backend="xla",
    requires=frozenset({"w"}), priority=30,
    feasible=_always, vmem_bytes=_no_vmem,
    apply=_apply_linear_dense, make_bench=_bench_linear_dense,
))


# ---------------------------------------------------------------------------
# Built-in conv candidates (GEMM view: [P, KhKwC] x [KhKwC, O])
# ---------------------------------------------------------------------------


def _synth_conv_input(key: OpKey):
    c, h, w = key.get("c"), key.get("h"), key.get("w", key.get("h"))
    b = max(key.get("b", 1), 1)
    return _rand((c, b, h, w), seed=3, dtype_tag=key.dtype)


def _conv_args(key: OpKey):
    return dict(kh=key.get("kh"), kw=key.get("kw"), stride=key.get("s", 1),
                pad=key.get("p", 0), v=key.get("v", 128))


def _bench_conv_dense(key: OpKey):
    import jax

    from repro.kernels.conv_gemm.ref import conv2d_cnhw_ref

    x = _synth_conv_input(key)
    a = _conv_args(key)
    wt = _rand((key.d_out, a["kh"], a["kw"], key.get("c")), seed=4,
                dtype_tag=key.dtype)
    f = jax.jit(lambda x: conv2d_cnhw_ref(x, wt, stride=a["stride"], pad=a["pad"]))
    return lambda: f(x)


def _bench_conv_im2col_dense(key: OpKey):
    import jax
    import jax.numpy as jnp

    from repro.kernels.im2col_pack.ops import im2col_then_pack

    x = _synth_conv_input(key)
    a = _conv_args(key)
    w = _rand((key.d_in, key.d_out), seed=5, dtype_tag=key.dtype) / (key.d_in ** 0.5)

    @jax.jit
    def f(x):
        strips = im2col_then_pack(x, kh=a["kh"], kw=a["kw"], stride=a["stride"],
                                  pad=a["pad"], v=a["v"])
        xt = strips.transpose(0, 2, 1).reshape(-1, key.d_in)
        return xt @ w

    return lambda: f(x)


def _apply_conv_xla(params, x, *, kh, kw, stride=1, pad=0, v=128):
    from repro.kernels.conv_gemm.ops import conv2d_xla_ref

    return conv2d_xla_ref(x, params["values"], params["idx"], kh=kh, kw=kw,
                          stride=stride, pad=pad, v=v)


def _apply_conv_two_kernel(params, x, *, kh, kw, stride=1, pad=0, v=128):
    from repro.kernels.conv_gemm.ops import conv2d_two_kernel

    return conv2d_two_kernel(x, params["values"], params["idx"], kh=kh, kw=kw,
                             stride=stride, pad=pad, v=v)


def _apply_conv_fused(params, x, *, kh, kw, stride=1, pad=0, v=128,
                      geom_v=128, geom_bk=128):
    # the megakernel's strips never exist in HBM, so its strip width is pure
    # execution geometry — it uses the profiled geom_v, not the caller's v
    from repro.kernels.conv_gemm.ops import conv2d_fused

    return conv2d_fused(x, params["values"], params["idx"], kh=kh, kw=kw,
                        stride=stride, pad=pad, v=geom_v, block_k=geom_bk)


def _bench_conv(key: OpKey, apply_fn):
    import jax

    x = _synth_conv_input(key)
    a = _conv_args(key)
    values, idx = _synth_compressed(key)
    params = {"values": values, "idx": idx}
    f = jax.jit(lambda x: apply_fn(params, x, **a))
    return lambda: f(x)


def _strips_vmem(key: OpKey) -> int:
    from repro.kernels.colwise_nm.kernel import strips_vmem_bytes

    return strips_vmem_bytes(key.d_in, key.get("v", 128),
                             min(128, key.k_kept), min(key.tile, 512),
                             in_bytes=_key_itemsize(key))


def _strips_feasible(key: OpKey) -> Tuple[bool, str]:
    ok, reason = _tile_ok(key)
    if not ok:
        return ok, reason
    vm = _strips_vmem(key)
    if vm > VMEM_BYTES:
        return False, f"VMEM {vm} > budget {VMEM_BYTES}"
    return True, "ok"


def _fused_vmem_for(geom_v: int, geom_bk: int):
    def vm(key: OpKey) -> int:
        from repro.kernels.conv_gemm.kernel import fused_vmem_bytes

        return fused_vmem_bytes(
            key.get("c"), max(key.get("b", 1), 1), key.get("h"),
            key.get("w", key.get("h")), geom_v, min(geom_bk, key.k_kept),
            min(key.tile, 512), in_bytes=_key_itemsize(key))

    return vm


def _fused_feasible_for(geom_v: int, geom_bk: int):
    vm_fn = _fused_vmem_for(geom_v, geom_bk)

    def feasible(key: OpKey) -> Tuple[bool, str]:
        ok, reason = _tile_ok(key)
        if not ok:
            return ok, reason
        if key.get("c") <= 0 or key.get("h") <= 0:
            return False, "conv geometry (c, h, w) missing from key extras"
        vm = vm_fn(key)  # the whole CNHW feature map must sit in VMEM
        if vm > VMEM_BYTES:
            return False, f"VMEM {vm} > budget {VMEM_BYTES}"
        return True, "ok"

    return feasible


REGISTRY.register(ImplSpec(
    name="dense_conv", op="conv", backend="xla",
    requires=frozenset({"w"}), priority=30,
    feasible=_always, vmem_bytes=_no_vmem,
    make_bench=_bench_conv_dense,
))

REGISTRY.register(ImplSpec(
    name="im2col_dense_gemm", op="conv", backend="xla",
    requires=frozenset({"w"}), priority=20,
    feasible=_always, vmem_bytes=_no_vmem,
    make_bench=_bench_conv_im2col_dense,
))

REGISTRY.register(ImplSpec(
    name="im2col_sparse_xla", op="conv", backend="xla",
    requires=frozenset({"values", "idx"}), priority=10,
    feasible=_always, vmem_bytes=_no_vmem,
    apply=_apply_conv_xla,
    make_bench=lambda key: _bench_conv(key, _apply_conv_xla),
))

# two-kernel Pallas plan: pack kernel + strip-major GEMM (no HBM relayout)
REGISTRY.register(ImplSpec(
    name="im2col_sparse_pallas", op="conv", backend="pallas",
    requires=frozenset({"values", "idx"}), priority=10,
    feasible=_strips_feasible, vmem_bytes=_strips_vmem,
    apply=_apply_conv_two_kernel,
    make_bench=lambda key: _bench_conv(key, _apply_conv_two_kernel),
))

def _apply_conv_banded(params, x, *, kh, kw, stride=1, pad=0, v=128,
                       geom_v=128, geom_bk=128, geom_hb=2):
    # like the resident megakernel, the banded kernel's strips never exist in
    # HBM — strip width and band depth are pure execution geometry
    from repro.kernels.conv_gemm.ops import conv2d_fused_banded

    return conv2d_fused_banded(x, params["values"], params["idx"], kh=kh,
                               kw=kw, stride=stride, pad=pad, v=geom_v,
                               block_k=geom_bk, hb=geom_hb)


def _apply_conv_pipelined(params, x, *, kh, kw, stride=1, pad=0, v=128,
                          geom_v=128, geom_bk=128, geom_hb=2):
    # the pipelined plan writes and reads its own strips, so the profiled
    # strip width applies to both kernels of the pair
    from repro.kernels.conv_gemm.ops import conv2d_two_kernel_pipelined

    return conv2d_two_kernel_pipelined(x, params["values"], params["idx"],
                                       kh=kh, kw=kw, stride=stride, pad=pad,
                                       v=geom_v, block_k=geom_bk, hb=geom_hb)


def _conv_hw(key: OpKey):
    """(c, b, h, w, ho, wo) of a conv key (ho/wo recomputed from extras)."""
    from repro.kernels.im2col_pack.ref import out_size

    c, h = key.get("c"), key.get("h")
    w = key.get("w", h)
    b = max(key.get("b", 1), 1)
    ho = out_size(h, key.get("kh"), key.get("s", 1), key.get("p", 0))
    wo = out_size(w, key.get("kw"), key.get("s", 1), key.get("p", 0))
    return c, b, h, w, ho, wo


def _banded_vmem_for(geom_v: int, geom_bk: int, geom_hb: int):
    def vm(key: OpKey) -> int:
        from repro.kernels.conv_gemm.kernel import band_plan, banded_vmem_bytes

        c, b, h, w, ho, wo = _conv_hw(key)
        _, band_rows = band_plan(b=b, h=h, kh=key.get("kh"),
                                 stride=key.get("s", 1), pad=key.get("p", 0),
                                 ho=ho, wo=wo, v=geom_v, hb=geom_hb)
        return banded_vmem_bytes(c, w, band_rows, geom_v,
                                 min(geom_bk, key.k_kept), min(key.tile, 512),
                                 in_bytes=_key_itemsize(key))

    return vm


def _dma_conv_feasible_for(vm_fn):
    """Predicate factory shared by the manual-DMA conv plans: tile shape,
    conv extras present, an async-copy-capable pallas build, and the
    double-buffered footprint within budget."""

    def feasible(key: OpKey) -> Tuple[bool, str]:
        from repro.kernels.pltpu_compat import HAS_ASYNC_COPY

        ok, reason = _tile_ok(key)
        if not ok:
            return ok, reason
        if key.get("c") <= 0 or key.get("h") <= 0:
            return False, "conv geometry (c, h, w) missing from key extras"
        if not HAS_ASYNC_COPY:
            return False, "pallas build has no make_async_copy"
        vm = vm_fn(key)
        if vm > VMEM_BYTES:
            return False, f"VMEM {vm} > budget {VMEM_BYTES}"
        return True, "ok"

    return feasible


def _pipelined_vmem_for(geom_v: int, geom_bk: int, geom_hb: int):
    def vm(key: OpKey) -> int:
        from repro.kernels.colwise_nm.kernel import pipelined_strips_vmem_bytes

        return pipelined_strips_vmem_bytes(
            key.d_in, geom_v, geom_hb, min(geom_bk, key.k_kept),
            min(key.tile, 512), in_bytes=_key_itemsize(key))

    return vm


# fused megakernel: one geometry-pinned candidate per (strip width, block_k)
for _geom in FUSED_CONV_GEOMETRY:
    _gv, _gbk = dict(_geom)["v"], dict(_geom)["bk"]
    _apply = functools.partial(_apply_conv_fused, geom_v=_gv, geom_bk=_gbk)
    REGISTRY.register(ImplSpec(
        name=geometry_name("fused_sparse_pallas", _geom,
                           FUSED_CONV_GEOMETRY[0]),
        op="conv", backend="pallas",
        requires=frozenset({"values", "idx"}), priority=5,
        feasible=_fused_feasible_for(_gv, _gbk),
        vmem_bytes=_fused_vmem_for(_gv, _gbk),
        apply=_apply,
        make_bench=functools.partial(_bench_conv, apply_fn=_apply),
        geometry=_geom,
    ))

# The banded megakernel and pipelined two-kernel plans: the next rungs of the
# conv plan ladder (VMEM-resident -> banded -> pipelined two-kernel -> XLA;
# see docs/kernels.md).  Both are geometry-parameterized over strip width x
# block_k x band depth, with dtype-aware predicates that account for the
# DOUBLE buffers their DMA pipelines keep resident.
for _family, _apply_fn, _vm_for, _prio in (
        ("fused_banded_pallas", _apply_conv_banded, _banded_vmem_for, 6),
        ("two_kernel_pipelined", _apply_conv_pipelined, _pipelined_vmem_for,
         8)):
    for _geom in BANDED_CONV_GEOMETRY:
        _gv, _gbk, _ghb = (dict(_geom)["v"], dict(_geom)["bk"],
                           dict(_geom)["hb"])
        _apply = functools.partial(_apply_fn, geom_v=_gv, geom_bk=_gbk,
                                   geom_hb=_ghb)
        _vm = _vm_for(_gv, _gbk, _ghb)
        REGISTRY.register(ImplSpec(
            name=geometry_name(_family, _geom, BANDED_CONV_GEOMETRY[0]),
            op="conv", backend="pallas",
            requires=frozenset({"values", "idx"}), priority=_prio,
            feasible=_dma_conv_feasible_for(_vm),
            vmem_bytes=_vm,
            apply=_apply,
            make_bench=functools.partial(_bench_conv, apply_fn=_apply),
            geometry=_geom,
        ))


# ---------------------------------------------------------------------------
# Paged attention (the serve.kv_pages memory tier): page_size x block_q
# geometry ladder.  Page size is a *cache layout* decision, so it has two key
# flavors: a planning key (no "ps" extra) races every geometry in profile_op
# — that's how choose_page_size picks the layout before the cache is
# allocated — and an execution key (pinned "ps") where only matching-layout
# pallas candidates plus the gather reference remain feasible.
# ---------------------------------------------------------------------------

PAGED_ATTN_GEOMETRY = (
    (("ps", 16), ("bq", 8)),
    (("ps", 8), ("bq", 8)),
    (("ps", 32), ("bq", 8)),
    (("ps", 16), ("bq", 16)),
)

DEFAULT_PAGE_SIZE = dict(PAGED_ATTN_GEOMETRY[0])["ps"]


def paged_attn_key(q_rows: int, n_heads: int, kv_heads: int, head_dim: int,
                   kv_capacity: int, page_size: int = 0, dtype="float32",
                   phase: str = "") -> OpKey:
    """OpKey for one paged-attention instance.

    ``page_size == 0`` builds the planning flavor; nonzero pins the physical
    layout. ``kv_capacity`` (table width x page size) is bucketed like batch
    so the DB is keyed by a bounded family of cache capacities.
    """
    extra = (("hd", head_dim), ("kvcap", bucket_batch(max(kv_capacity, 1))))
    if page_size:
        extra += (("ps", page_size),)
    return OpKey(op="paged_attn", batch=bucket_batch(max(q_rows, 1)),
                 d_in=head_dim, d_out=n_heads * head_dim, k_kept=kv_heads,
                 tile=8, dtype=_dtype_tag(dtype), extra=extra, phase=phase)


def _paged_vmem_for(geom_ps: int, geom_bq: int):
    def vm(key: OpKey) -> int:
        from repro.kernels.flash_attn.paged import paged_vmem_bytes

        hd, kv = key.get("hd", key.d_in), max(key.k_kept, 1)
        h = key.d_out // max(hd, 1)
        return paged_vmem_bytes(geom_ps, kv, hd, geom_bq, h, sn=geom_bq,
                                in_bytes=_key_itemsize(key))

    return vm


def _paged_feasible_for(geom_ps: int, geom_bq: int):
    def feasible(key: OpKey) -> Tuple[bool, str]:
        from repro.kernels.flash_attn.paged import paged_kernel_available

        if not paged_kernel_available():
            return False, "pallas build lacks async-copy or scalar prefetch"
        hd, kv = key.get("hd"), key.k_kept
        if hd <= 0 or kv <= 0:
            return False, "paged geometry (hd, kv) missing from key extras"
        h = key.d_out // hd
        if h % kv != 0:
            return False, f"H={h} not divisible by KV={kv} (head-map GQA)"
        pinned = key.get("ps", 0)
        if pinned and pinned != geom_ps:
            return False, f"cache layout pinned to page size {pinned}"
        vm = _paged_vmem_for(geom_ps, geom_bq)(key)
        if vm > VMEM_BYTES:
            return False, f"VMEM {vm} > budget {VMEM_BYTES}"
        return True, "ok"

    return feasible


def _synth_paged(key: OpKey, ps: int):
    """Deterministic decode-shaped operands for a paged-attention bench."""
    import numpy as np

    hd, kv = key.get("hd"), key.k_kept
    h = key.d_out // hd
    b = key.batch
    kvcap = key.get("kvcap", 128)
    n_max = -(-kvcap // ps)
    p = b * n_max
    q = _rand((b, 1, h, hd), 1, key.dtype)
    kn = _rand((b, 1, kv, hd), 2, key.dtype)
    vn = _rand((b, 1, kv, hd), 3, key.dtype)
    kp = _rand((p + 1, ps, kv, hd), 4, key.dtype)
    vp = _rand((p + 1, ps, kv, hd), 5, key.dtype)
    tables = np.arange(p, dtype=np.int32).reshape(b, n_max)
    # three-quarter-full caches: the ragged-final-page case is the hot one
    lengths = np.full((b,), max(kvcap * 3 // 4, 1), np.int32)
    return q, kn, vn, kp, vp, tables, lengths


def _bench_paged_ref(key: OpKey):
    import jax

    from repro.kernels.flash_attn.paged import paged_attention_ref

    ps = key.get("ps", 0) or DEFAULT_PAGE_SIZE
    q, kn, vn, kp, vp, tables, lengths = _synth_paged(key, ps)
    f = jax.jit(lambda q: paged_attention_ref(q, kn, vn, kp, vp, tables,
                                              lengths))
    return lambda: f(q)


def _bench_paged_pallas(key: OpKey, geom_ps: int, geom_bq: int):
    import jax

    from repro.kernels.flash_attn.paged import paged_attention_pallas
    from repro.kernels.pltpu_compat import should_interpret

    # the candidate's OWN page size, not the key's: a planning key races
    # every geometry's physical layout against the others
    q, kn, vn, kp, vp, tables, lengths = _synth_paged(key, geom_ps)
    interp = should_interpret()
    f = jax.jit(lambda q: paged_attention_pallas(
        q, kn, vn, kp, vp, tables, lengths, page_size=geom_ps,
        block_q=geom_bq, interpret=interp))
    return lambda: f(q)


REGISTRY.register(ImplSpec(
    name="paged_attn_ref", op="paged_attn", backend="xla",
    requires=frozenset(), priority=10,
    feasible=_always, vmem_bytes=_no_vmem,
    make_bench=_bench_paged_ref,
))

for _geom in PAGED_ATTN_GEOMETRY:
    _gps, _gbq = dict(_geom)["ps"], dict(_geom)["bq"]
    REGISTRY.register(ImplSpec(
        name=geometry_name("paged_attn_pallas", _geom,
                           PAGED_ATTN_GEOMETRY[0]),
        op="paged_attn", backend="pallas",
        requires=frozenset(), priority=5,
        feasible=_paged_feasible_for(_gps, _gbq),
        vmem_bytes=_paged_vmem_for(_gps, _gbq),
        make_bench=functools.partial(_bench_paged_pallas, geom_ps=_gps,
                                     geom_bq=_gbq),
        geometry=_geom,
    ))


def choose_page_size(n_heads: int, kv_heads: int, head_dim: int,
                     kv_capacity: int, *, q_rows: int = 8, dtype="float32",
                     phase: str = "decode", db=None,
                     profile: bool = False) -> int:
    """Pick the KV page size for a serving config (the cache-layout plan).

    Resolves the unpinned planning key: with ``profile=True`` (or a warm
    DB), profile_op has raced every ``PAGED_ATTN_GEOMETRY`` page size for
    this shape and the winner's layout is returned; otherwise the heuristic
    rung decides (DEFAULT_PAGE_SIZE when the gather reference wins).
    """
    from repro.dispatch.dispatch import best_impl, ensure_profiled
    from repro.dispatch.profiler import TuningError

    key = paged_attn_key(q_rows, n_heads, kv_heads, head_dim, kv_capacity,
                         page_size=0, dtype=dtype, phase=phase)
    if profile:
        try:
            ensure_profiled(key, db=db)
        except TuningError:
            pass
    spec = best_impl(key, db=db)
    ps = spec.geom("ps", 0) if spec is not None else 0
    return ps or DEFAULT_PAGE_SIZE
