"""Profiler harness + persistent profile DB (paper §3.3, AITemplate-analog).

The paper parameterizes its XNNPACK micro-kernels by tile size T and LMUL,
profiles every candidate on the target, and bakes the fastest into the
executable.  Here the same loop is split into reusable pieces:

  * :class:`ProfileDB` — a versioned, environment-fingerprinted JSON store of
    profiling results.  Entries recorded under a different backend/device/jax
    version (or an older schema, including the seed-era ``tuning_cache.json``
    format) are invalidated on load instead of silently reused.  Writes are
    atomic (temp file + ``os.replace``) so a crash mid-save never corrupts
    the DB, and an in-memory LRU bounds resident entries.
  * :func:`profile_op` — wall-clocks every feasible registered candidate for
    an :class:`OpKey` and records the winner.  Since block geometry was
    folded into the candidate space (``registry.LINEAR_GEOMETRY`` /
    ``registry.FUSED_CONV_GEOMETRY`` — one geometry-pinned candidate per grid
    point), this single pass selects implementation AND geometry jointly.
  * :class:`Tuner` — DEPRECATED compatibility shim for the seed's separate
    block-geometry tier.  Its candidate enumeration is now just a view over
    the registry's geometry grid; new code should profile an
    :class:`OpKey` via :func:`profile_op` (or ``dispatch.ensure_profiled``)
    and read the winning candidate's ``geometry`` instead.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from collections import OrderedDict
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.dispatch.registry import REGISTRY, VMEM_BYTES, ImplSpec, OpKey
from repro.obs import metrics as _om
from repro.obs import trace as _ot

_C_PROFILE_RUNS = _om.counter("dispatch.profile_runs")
_C_PROFILED_CANDS = _om.counter("dispatch.profiled_candidates")

SCHEMA_VERSION = 2
DEFAULT_DB_PATH = "artifacts/dispatch_profile.json"


class TuningError(RuntimeError):
    """No feasible candidate exists for an operator shape."""


def env_fingerprint() -> Dict[str, str]:
    """Identity of the profiling environment; a profile is only valid on the
    machine/backend/software that produced it."""
    import jax

    try:
        device = jax.devices()[0].device_kind
    except Exception:  # noqa: BLE001 — no devices in some dry-run contexts
        device = "unknown"
    return {
        "backend": jax.default_backend(),
        "device": device,
        "jax": jax.__version__,
        "schema": SCHEMA_VERSION,
    }


def median_wall_us(fn: Callable[[], object], iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock of ``fn()`` in microseconds (blocks on results)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


class ProfileDB:
    """Persistent profile store: ``{version, fingerprint, entries}``.

    ``entries`` maps an :attr:`OpKey.token` (or a Tuner shape key) to a JSON
    record.  The in-memory view is an LRU capped at ``max_entries``; the
    on-disk file holds whatever was resident at the last save.
    """

    _uid_counter = 0  # process-unique instance ids (id() can be recycled)

    def __init__(self, path: Optional[str] = None, max_entries: int = 1024,
                 autosave: bool = True):
        from repro import env as _env

        self.path = Path(path or _env.get("REPRO_DISPATCH_DB")
                         or DEFAULT_DB_PATH)
        self.max_entries = max_entries
        self.autosave = autosave
        self.fingerprint = env_fingerprint()
        self._entries: "OrderedDict[str, Dict]" = OrderedDict()
        self.invalidated = False  # a stale/foreign file was found and ignored
        self.generation = 0       # bumped on every mutation (memo invalidation)
        ProfileDB._uid_counter += 1
        self.uid = ProfileDB._uid_counter
        self._load()

    # -- persistence --------------------------------------------------------

    def _load(self) -> None:
        if not self.path.exists():
            return
        try:
            data = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            self.invalidated = True
            return
        if not isinstance(data, dict) or data.get("version") != SCHEMA_VERSION:
            # seed-era caches were a bare {key: record} dict with no version
            self.invalidated = True
            return
        if data.get("fingerprint") != self.fingerprint:
            self.invalidated = True
            return
        for k, v in data.get("entries", {}).items():
            self._entries[k] = v

    def save(self) -> None:
        """Atomic write: serialize to a temp file in the same directory, then
        ``os.replace`` so readers never observe a torn file."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = json.dumps({
            "version": SCHEMA_VERSION,
            "fingerprint": self.fingerprint,
            "entries": dict(self._entries),
        }, indent=1)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # -- entry access -------------------------------------------------------

    def get(self, token: str) -> Optional[Dict]:
        rec = self._entries.get(token)
        if rec is not None:
            self._entries.move_to_end(token)
        return rec

    def put(self, token: str, record: Dict, save: Optional[bool] = None) -> None:
        self._entries[token] = record
        self._entries.move_to_end(token)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
        self.generation += 1
        if save if save is not None else self.autosave:
            self.save()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, token: str) -> bool:
        return token in self._entries

    def tokens(self) -> List[str]:
        return list(self._entries)


# ---------------------------------------------------------------------------
# Candidate-level profiling (which implementation wins for this op shape)
# ---------------------------------------------------------------------------


def profile_op(key: OpKey, db: Optional[ProfileDB] = None, *,
               impls: Optional[List[ImplSpec]] = None, iters: int = 5,
               param_keys=None) -> Dict:
    """Wall-clock every feasible candidate for ``key``; record + return the
    winner's record ``{"impl", "wall_us", "all": {name: us}}``."""
    if impls is None:
        impls = REGISTRY.candidates(key.op, param_keys=param_keys)
    feasible = [s for s in impls if s.feasible(key)[0] and s.make_bench]
    if not feasible:
        reasons = {s.name: s.feasible(key)[1] for s in impls}
        raise TuningError(
            f"no feasible candidate for {key.token}: {reasons}")
    _C_PROFILE_RUNS.inc()
    timings: Dict[str, float] = {}
    with _ot.span("dispatch.profile", token=key.token,
                  candidates=len(feasible)) as psp:
        for spec in feasible:
            with _ot.span("dispatch.profile.candidate", impl=spec.name) as sp:
                timings[spec.name] = median_wall_us(spec.make_bench(key),
                                                    iters=iters)
                sp.set(wall_us=timings[spec.name])
            _C_PROFILED_CANDS.inc()
            # per-candidate measurement as a first-class event, so a trace
            # alone reconstructs the whole race, not just the winner
            _ot.instant("dispatch.candidate_wall", token=key.token,
                        impl=spec.name, wall_us=timings[spec.name])
        winner = min(timings, key=timings.get)
        psp.set(winner=winner, wall_us=timings[winner])
    record = {"impl": winner, "wall_us": timings[winner], "all": timings}
    if db is not None:
        db.put(key.token, record)
    return record


# ---------------------------------------------------------------------------
# DEPRECATED geometry-level tuning shim (seed Tuner: tile x block_b x block_k)
#
# Geometry now lives in the candidate space: profile_op over the registry's
# geometry-pinned candidates replaces this tier.  The class is kept only so
# seed-era imports (`repro.core.tuning.Tuner`) keep working; its block grid
# is derived from the same registry.LINEAR_GEOMETRY the candidates use.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Candidate:
    tile: int
    block_b: int
    block_k: int
    wall_us: Optional[float] = None
    vmem_bytes: int = 0
    feasible: bool = True
    score: float = 0.0


def _pallas_vmem(block_b: int, block_k: int, d_in: int, tile: int, itemsize=2) -> int:
    from repro.kernels.colwise_nm.kernel import vmem_bytes

    return vmem_bytes(block_b, block_k, d_in, tile, itemsize)


def _time_xla_candidate(batch, d_in, d_out, sparsity, tile, iters=5) -> float:
    import jax
    import jax.numpy as jnp

    from repro.core.formats import meta_for, pack_colwise
    from repro.core.pruning import SparsityConfig, colwise_nm_mask

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (batch, d_in))
    w = jax.random.normal(jax.random.PRNGKey(1), (d_in, d_out)) / (d_in ** 0.5)
    cfg = SparsityConfig(sparsity, m=None, tile=tile, format="compressed_xla")
    meta = meta_for(d_in, d_out, cfg)
    mask = colwise_nm_mask(w, sparsity, tile=meta.tile)
    values, idx = pack_colwise(w, mask, meta)

    @jax.jit
    def f(x):
        xg = jnp.take(x, idx, axis=-1)
        return jnp.einsum("btk,tkf->btf", xg, values)

    return median_wall_us(lambda: f(x), iters=iters, warmup=1)


def enumerate_candidates(d_in: int, d_out: int) -> List[Candidate]:
    from repro.dispatch.registry import LINEAR_GEOMETRY

    tiles = sorted({t for t in (32, 64, 128, 256, 512, d_out) if d_out % t == 0})
    # single source of geometry truth: the registry's candidate grid
    blocks = [(dict(g)["bb"], dict(g)["bk"]) for g in LINEAR_GEOMETRY]
    out = []
    for t in tiles:
        for bb, bk in blocks:
            vm = _pallas_vmem(bb, bk, d_in, min(t, 512))
            out.append(Candidate(tile=t, block_b=bb, block_k=bk,
                                 vmem_bytes=vm, feasible=vm <= VMEM_BYTES))
    return out


class Tuner:
    """DEPRECATED block-geometry auto-tuner over (tile, block_b, block_k).

    Geometry selection moved into the dispatch candidate space — register a
    geometry variant (see ``registry.LINEAR_GEOMETRY``) and profile the
    :class:`OpKey` instead; the winning candidate's ``geometry`` is the tuned
    block configuration.  This shim remains for seed-era callers.

    Backed by a :class:`ProfileDB`, so selections are versioned, fingerprinted
    and atomically persisted; a seed-era ``tuning_cache.json`` (bare dict, no
    version key) is invalidated on load instead of silently reused.
    """

    def __init__(self, cache_path: str = "artifacts/tuning_cache.json"):
        self.db = ProfileDB(path=cache_path, autosave=True)
        self.path = self.db.path

    @property
    def cache(self) -> Dict[str, Dict]:
        return dict(self.db._entries)

    def _key(self, batch, d_in, d_out, sparsity) -> str:
        return f"b{batch}_i{d_in}_o{d_out}_s{int(sparsity * 100)}"

    def tune(self, batch: int, d_in: int, d_out: int, sparsity: float = 0.5,
             profile: bool = True) -> Dict:
        """Profile candidates; returns the winning config (cached).

        ``profile=False`` skips wall-clocking and falls back to the
        smallest-VMEM feasible candidate (a pure-static selection for hosts
        where profiling is unavailable or disabled).
        """
        key = self._key(batch, d_in, d_out, sparsity)
        cached = self.db.get(key)
        if cached is not None:
            return cached
        cands = enumerate_candidates(d_in, d_out)
        feasible = [c for c in cands if c.feasible]
        if not feasible:
            min_vm = min(c.vmem_bytes for c in cands) if cands else 0
            raise TuningError(
                f"no feasible kernel candidate for shape batch={batch}, "
                f"d_in={d_in}, d_out={d_out}, sparsity={sparsity}: smallest "
                f"candidate needs {min_vm} B of VMEM (budget {VMEM_BYTES} B)")
        if not profile:
            best = min(feasible, key=lambda c: (c.vmem_bytes, c.tile))
        else:
            best = None
            tried_tiles = set()
            for c in feasible:
                if c.tile not in tried_tiles:
                    # wall time depends on the tile (XLA path); block geometry
                    # is scored analytically (VMEM pressure => prefer bigger
                    # blocks while they fit, like the paper prefers higher LMUL)
                    c.wall_us = _time_xla_candidate(batch, d_in, d_out, sparsity, c.tile)
                    tried_tiles.add(c.tile)
                wall = c.wall_us or next(
                    (o.wall_us for o in feasible if o.tile == c.tile and o.wall_us),
                    1e9,
                )
                c.wall_us = wall  # every block point carries its tile's wall
                c.score = wall * (1.0 + c.vmem_bytes / VMEM_BYTES * 0.1)
                if best is None or c.score < best.score:
                    best = c
        result = {
            "tile": best.tile, "block_b": best.block_b, "block_k": best.block_k,
            "wall_us": best.wall_us, "vmem_bytes": best.vmem_bytes,
        }
        self.db.put(key, result)
        return result

    def tuned_tile(self, batch: int, d_in: int, d_out: int, sparsity: float = 0.5) -> int:
        return int(self.tune(batch, d_in, d_out, sparsity)["tile"])
