# Operator dispatch & profiling subsystem (paper §3.3, AITemplate-analog):
# a registry of candidate implementations per logical op, a profiler that
# races the feasible ones, a fingerprinted persistent profile DB, and the
# best_impl() selection layer every sparse call site consults.
from repro.dispatch.registry import (  # noqa: F401
    BANDED_CONV_GEOMETRY,
    DEFAULT_PAGE_SIZE,
    FUSED_CONV_GEOMETRY,
    LINEAR_GEOMETRY,
    PAGED_ATTN_GEOMETRY,
    REGISTRY,
    VMEM_BYTES,
    ImplSpec,
    OperatorRegistry,
    OpKey,
    bucket_batch,
    choose_page_size,
    conv_key,
    geometry_name,
    linear_key,
    linear_key_from,
    paged_attn_key,
)
from repro.dispatch.profiler import (  # noqa: F401
    DEFAULT_DB_PATH,
    SCHEMA_VERSION,
    Candidate,
    ProfileDB,
    Tuner,
    TuningError,
    enumerate_candidates,
    env_fingerprint,
    median_wall_us,
    profile_op,
)
from repro.dispatch.dispatch import (  # noqa: F401
    best_impl,
    clear_quarantine,
    current_phase,
    dispatch_enabled,
    ensure_profiled,
    get_db,
    iter_compressed_layers,
    iter_op_layers,
    linear_impl,
    no_profile_scope,
    phase_scope,
    plan_params,
    quarantine,
    quarantined,
    run_guarded,
    set_db,
)
