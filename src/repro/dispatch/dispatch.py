"""Dispatch layer: pick the implementation that runs a logical op.

Selection order for :func:`best_impl` (first hit wins):

  1. explicit ``force=`` argument — call-site override (e.g. the
     ``prefer_pallas`` compat flag on ``linear_apply``); honoured even with
     dispatch off, matching the pre-dispatch call-site semantics.
  2. ``REPRO_DISPATCH_FORCE=<impl>`` — process-wide override by name (only
     consulted while dispatch is enabled).
  3. ``REPRO_DISPATCH=off``       — dispatch disabled; the legacy default
     implementation for the op is returned (``compressed_xla`` for linear,
     ``im2col_sparse_pallas`` for conv), so behaviour is bit-identical to the
     pre-dispatch code paths.
  4. profile DB entry              — a previously profiled winner for this
     exact :class:`OpKey` token, if it is still feasible and registered.
  5. heuristic                     — among feasible candidates prefer the
     backend that matches the platform (pallas on TPU, XLA elsewhere), then
     registry priority, then smallest VMEM footprint.

Profiling never happens implicitly inside a model trace; callers that want a
populated DB run :func:`ensure_profiled` / :func:`plan_params` at build time
(the serve ``Engine`` does) or set ``REPRO_DISPATCH_PROFILE=1``.
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Dict, Iterable, Mapping, Optional

from repro.dispatch.profiler import ProfileDB, TuningError, profile_op
from repro.dispatch.registry import (
    REGISTRY,
    ImplSpec,
    OpKey,
    conv_key,
    linear_key,
    linear_key_from,
)
from repro.obs import metrics as _om
from repro.obs import trace as _ot

# Cached instrument references (module-level, created once): each probe on
# the resolution path costs one enabled-bool read while observability is off.
_C_RESOLVE = _om.counter("dispatch.resolves")
_C_MEMO_HIT = _om.counter("dispatch.memo_hits")
_C_DB_HIT = _om.counter("dispatch.db_hits")
_C_DB_MISS = _om.counter("dispatch.db_misses")
_C_CANDS = _om.counter("dispatch.candidates_considered")
_C_NO_PROFILE = _om.counter("dispatch.no_profile_resolves")
_C_QUARANTINE = _om.counter("dispatch.quarantine")
_C_EXEC_RETRY = _om.counter("dispatch.execute_retries")

# legacy per-op defaults used when dispatch is switched off
_LEGACY_DEFAULT = {"linear": "compressed_xla", "conv": "im2col_sparse_pallas",
                   "paged_attn": "paged_attn_ref"}

_DB: Optional[ProfileDB] = None
_MEMO: Dict[tuple, ImplSpec] = {}

# ---------------------------------------------------------------------------
# Execution-time quarantine (with TTL/backoff re-probe)
# ---------------------------------------------------------------------------
#
# The profiler picks the *fastest* candidate; nothing above this layer knows
# whether that candidate can actually *run* here.  When execution fails (a
# real kernel crash at trace time, or an injected fault from repro.fault),
# run_guarded adds the (op, impl-name) pair to this process-local denylist —
# geometry-pinned candidates carry their geometry in the name, so the pair IS
# (impl, geometry) — and re-resolves down the normal ladder.  Quarantine is
# deliberately ephemeral: it is never written to the ProfileDB, so a process
# restart retries the full candidate space (the failure may have been
# environmental).  _Q_GEN joins every memo key, so quarantining an impl
# invalidates memoized resolutions the same way a registry change does.
#
# Entries EXPIRE: each carries a monotonic deadline (base TTL doubled per
# consecutive failure, capped).  An expired entry moves to *probation* —
# the impl rejoins the candidate space, so the next resolution may pick it
# again — and its fate is decided at the next guarded execution:
#
#       active ──ttl elapses──► probation ──run_guarded ok──► (entry gone)
#         ▲                        │
#         └──── guarded failure ───┘   (fails += 1, ttl doubles)
#
# A transiently-failing kernel therefore earns its way back WITHOUT a
# process restart, while a persistently-failing one re-quarantines on its
# first re-probe and stays degraded (with exponentially rarer probes).
# REPRO_DISPATCH_QUARANTINE_TTL_S tunes the base TTL; <= 0 disables expiry
# (the pre-TTL all-or-nothing behaviour).


class _QuarantineEntry:
    __slots__ = ("fails", "until", "probation", "reason")

    def __init__(self, fails: int, until: float, reason: str):
        self.fails = fails
        self.until = until
        self.probation = False
        self.reason = reason


_QUARANTINE: Dict[tuple, _QuarantineEntry] = {}
_Q_GEN = 0

_now = time.monotonic  # test seam: monkeypatch dispatch._now for fake clocks
_TTL_BACKOFF = 2.0
_TTL_MAX_DOUBLINGS = 6  # cap the backoff at base * 2**6


def quarantine_ttl_s() -> float:
    """Base quarantine TTL in seconds (``REPRO_DISPATCH_QUARANTINE_TTL_S``,
    default 30).  <= 0 means entries never expire."""
    from repro import env as _env

    return float(_env.get("REPRO_DISPATCH_QUARANTINE_TTL_S"))


def _entry_ttl(fails: int) -> float:
    base = quarantine_ttl_s()
    if base <= 0:
        return float("inf")
    return base * _TTL_BACKOFF ** min(fails - 1, _TTL_MAX_DOUBLINGS)


def quarantine(op: str, impl: str, reason: str = "") -> bool:
    """Denylist ``impl`` for ``op`` in this process.  Returns True when this
    starts a new quarantine period (first failure, or a failed re-probe of an
    expired entry — which doubles the TTL); False when the pair is already
    actively quarantined.  Emits a ``dispatch.quarantine`` instant + counter
    so degraded serving is visible in traces."""
    global _Q_GEN
    ent = _QUARANTINE.get((op, impl))
    if ent is not None and not ent.probation:
        return False
    if ent is None:
        ent = _QuarantineEntry(1, 0.0, reason)
        _QUARANTINE[(op, impl)] = ent
    else:
        # failed re-probe: back off exponentially
        ent.fails += 1
        ent.probation = False
        ent.reason = reason or ent.reason
    ent.until = _now() + _entry_ttl(ent.fails)
    _Q_GEN += 1
    _C_QUARANTINE.inc()
    _ot.instant("dispatch.quarantine", op=op, impl=impl,
                reason=reason[:200] if reason else "",
                fails=ent.fails, ttl_s=_entry_ttl(ent.fails),
                denylist=len(_QUARANTINE))
    return True


def _sweep_expired() -> None:
    """Move entries whose TTL elapsed to probation (candidate space rejoin).
    Bumps the memo generation so the change is visible despite memoization.
    Called on every resolution while any entry exists — cheap (dict walk)."""
    global _Q_GEN
    now = _now()
    for (op, impl), ent in _QUARANTINE.items():
        if not ent.probation and now >= ent.until:
            ent.probation = True
            _Q_GEN += 1
            _ot.instant("dispatch.quarantine_expired", op=op, impl=impl,
                        fails=ent.fails)


def _is_quarantined(op: str, impl: str) -> bool:
    """Actively denylisted (probation entries are eligible again)."""
    ent = _QUARANTINE.get((op, impl))
    return ent is not None and not ent.probation


def _clear_probation(op: str, impl: str) -> None:
    """A guarded execution of a probation impl succeeded: the impl has
    recovered; drop the entry entirely (fail count resets)."""
    global _Q_GEN
    ent = _QUARANTINE.get((op, impl))
    if ent is not None and ent.probation:
        del _QUARANTINE[(op, impl)]
        _Q_GEN += 1
        _ot.instant("dispatch.quarantine_recovered", op=op, impl=impl,
                    fails=ent.fails)


def quarantined(op: Optional[str] = None) -> frozenset:
    """The *active* denylist: ``{(op, impl)}`` pairs, or just the impl names
    for one ``op``.  Expired (probation) entries are not listed — they are
    back in the candidate space pending a guarded re-probe."""
    if op is None:
        return frozenset(k for k, e in _QUARANTINE.items() if not e.probation)
    return frozenset(i for (o, i), e in _QUARANTINE.items()
                     if o == op and not e.probation)


def quarantine_info(op: str, impl: str) -> Optional[Dict]:
    """Introspection: ``{fails, until, probation, reason}`` for a pair, or
    None when it has no entry (never failed, or recovered)."""
    ent = _QUARANTINE.get((op, impl))
    if ent is None:
        return None
    return {"fails": ent.fails, "until": ent.until,
            "probation": ent.probation, "reason": ent.reason}


def clear_quarantine() -> None:
    """Empty the denylist (tests; operator intervention)."""
    global _Q_GEN
    if _QUARANTINE:
        _QUARANTINE.clear()
        _Q_GEN += 1


def run_guarded(key: OpKey, spec: ImplSpec, call, *,
                param_keys: Optional[Iterable[str]] = None,
                db: Optional[ProfileDB] = None):
    """Execute ``call(spec)`` with quarantine-degradation.

    The ``dispatch.execute`` fault site probes first (so chaos schedules can
    fail any candidate by name), then ``call`` runs.  On failure the
    candidate is quarantined and the key re-resolves down the ladder —
    explicit forces included: a forced impl that cannot execute degrades
    rather than killing the serve loop.  Raises the last error only when
    every remaining rung has been tried.

    On CPU/interpret builds candidate execution happens during jit *tracing*,
    so this try/except at the call boundary catches both injected faults and
    real trace-time kernel failures before any donated buffer is consumed.
    """
    from repro import fault as _fault

    pk = tuple(param_keys) if param_keys is not None else None
    tried = set()
    while True:
        try:
            _fault.maybe_fail("dispatch.execute", op=key.op, impl=spec.name)
            out = call(spec)
            # a probation (TTL-expired) impl that just executed cleanly has
            # recovered: drop its entry so it fully rejoins the ladder
            _clear_probation(key.op, spec.name)
            return out
        except Exception as e:  # noqa: BLE001 - degrade on any exec failure
            tried.add(spec.name)
            quarantine(key.op, spec.name,
                       reason=f"{type(e).__name__}: {e}")
            nxt = best_impl(key, param_keys=pk, db=db)
            if nxt.name in tried:
                raise
            _C_EXEC_RETRY.inc()
            _ot.instant("dispatch.execute_retry", op=key.op,
                        failed=spec.name, retry=nxt.name)
            spec = nxt


def get_db() -> ProfileDB:
    """Process-wide profile DB singleton (path via ``REPRO_DISPATCH_DB``)."""
    global _DB
    if _DB is None:
        _DB = ProfileDB()
    return _DB


def set_db(db: Optional[ProfileDB]) -> None:
    """Swap the active profile DB (tests, benchmark isolation)."""
    global _DB
    _DB = db
    _MEMO.clear()


def dispatch_enabled() -> bool:
    from repro import env as _env

    return bool(_env.get("REPRO_DISPATCH"))


# ---------------------------------------------------------------------------
# Serving-phase scope
# ---------------------------------------------------------------------------

# Ambient serving phase ("prefill" | "decode" | None).  The serve Engine wraps
# its traced step functions in phase_scope so every linear_apply call site
# inside the trace resolves a phase-tagged OpKey without threading a phase
# argument through the whole model stack.  jit tracing runs the wrapped Python
# function synchronously, so a plain module global is sufficient (retraces go
# through the wrapper again).
_PHASE: Optional[str] = None


@contextlib.contextmanager
def phase_scope(phase: Optional[str]):
    """Tag dispatch lookups in this (tracing) scope with a serving phase."""
    global _PHASE
    prev = _PHASE
    _PHASE = phase or None
    try:
        yield
    finally:
        _PHASE = prev


def current_phase() -> str:
    """The ambient serving phase ("" outside any phase_scope)."""
    return _PHASE or ""


def _env_force() -> Optional[str]:
    from repro import env as _env

    return _env.get("REPRO_DISPATCH_FORCE")


# Ambient profiling suppression.  ``REPRO_DISPATCH_PROFILE=1`` lets best_impl
# wall-clock candidates on a DB miss — acceptable while tracing a *forward*
# (the historical behaviour), but a gradient trace re-enters every call site
# a second time through the custom-VJP fwd rule, and wall-clocking synthetic
# candidates from inside jax.grad tracing would both skew the measurements
# and stall the trace.  The conv/linear VJP fwd rules wrap their dispatch
# resolution in :func:`no_profile_scope`, so grad tracing resolves from the
# DB/heuristic only and never re-enters the profiler.
_NO_PROFILE = False


@contextlib.contextmanager
def no_profile_scope():
    """Suppress profile-on-miss inside this (tracing) scope: best_impl falls
    back to DB / heuristic resolution, never wall-clocks candidates."""
    global _NO_PROFILE
    prev = _NO_PROFILE
    _NO_PROFILE = True
    try:
        yield
    finally:
        _NO_PROFILE = prev


def _profile_on_miss() -> bool:
    if _NO_PROFILE:
        return False
    from repro import env as _env

    return bool(_env.get("REPRO_DISPATCH_PROFILE"))


def _heuristic(specs, key: OpKey) -> ImplSpec:
    import jax

    on_tpu = jax.default_backend() == "tpu"

    def rank(s: ImplSpec):
        backend_match = 0 if (s.backend == "pallas") == on_tpu else 1
        return (backend_match, s.priority, s.vmem_bytes(key))

    return min(specs, key=rank)


def best_impl(key: OpKey, *, param_keys: Optional[Iterable[str]] = None,
              force: Optional[str] = None, db: Optional[ProfileDB] = None) -> ImplSpec:
    """Resolve the implementation to run for ``key`` (see module docstring).

    ``param_keys`` restricts candidates to those executable from a given
    param dict (a compressed layer cannot run the dense candidate).
    Pure lookup — never wall-clocks anything.
    """
    pk = frozenset(param_keys) if param_keys is not None else None
    if _QUARANTINE:
        # TTL sweep first: an expired entry must flip to probation (and bump
        # the generation) BEFORE the memo lookup, or a stale memoized
        # degradation would outlive its quarantine period
        _sweep_expired()
    explicit = force is not None
    if force is None and dispatch_enabled():
        # the env override only applies when dispatch is on; an explicit
        # force= argument (e.g. prefer_pallas) always wins, matching the
        # pre-dispatch behaviour of the call sites
        force = _env_force()
    the_db = db if db is not None else get_db()
    # _profile_on_miss() is part of the key: a resolution memoized inside a
    # no_profile_scope (grad tracing) must not shadow a later forward-trace
    # lookup that is allowed to profile the same token
    # _Q_GEN: quarantining an impl must invalidate memoized resolutions
    # (quarantine survives memoization, not the other way around)
    memo_key = (key.token, pk, force, explicit, dispatch_enabled(),
                _profile_on_miss(), the_db.uid, the_db.generation,
                REGISTRY.generation, _Q_GEN)
    hit = _MEMO.get(memo_key)
    if hit is not None:
        _C_MEMO_HIT.inc()
        return hit
    _C_RESOLVE.inc()
    if _NO_PROFILE:
        _C_NO_PROFILE.inc()
    with _ot.span("dispatch.resolve", token=key.token, op=key.op,
                  phase=key.phase) as sp:
        spec, source = _resolve(key, pk, force, explicit, the_db)
        sp.set(impl=spec.name, source=source)
    if _ot.enabled():
        # every plan decision is auditable: winning impl + geometry token +
        # why it won + its analytic VMEM footprint, in one instant event
        _ot.instant(
            "dispatch.decision", op=key.op, token=key.token,
            phase=key.phase, impl=spec.name, source=source,
            geometry="_".join(f"{k}{v}" for k, v in spec.geometry) or "default",
            backend=spec.backend, vmem_bytes=int(spec.vmem_bytes(key)),
            no_profile_scope=_NO_PROFILE)
    if len(_MEMO) > 4096:
        _MEMO.clear()
    _MEMO[memo_key] = spec
    return spec


def _resolve(key: OpKey, pk, force: Optional[str], explicit: bool,
             db: ProfileDB) -> tuple:
    """Returns ``(spec, source)`` — the selection plus which rung of the
    selection order produced it ("forced" | "legacy" | "degraded" | "db" |
    "profiled" | "heuristic"), recorded in the dispatch-decision event."""
    cands = REGISTRY.candidates(key.op, param_keys=pk)
    if not cands:
        raise TuningError(f"no candidates registered for op {key.op!r} "
                          f"executable from params {sorted(pk or ())}")
    _C_CANDS.inc(len(cands))
    by_name = {s.name: s for s in cands}

    if force is not None and not explicit and _is_quarantined(key.op, force):
        # a process-wide env force naming a quarantined impl yields to the
        # ladder (the quarantine exists because that impl failed to execute);
        # an explicit call-site force= still wins below — the caller asked
        # for this impl by name and run_guarded handles its failure
        force = None

    if force is not None:
        if force in by_name:
            return by_name[force], "forced"
        registered = force in {s.name for s in REGISTRY.candidates(key.op)}
        if not registered:
            raise KeyError(
                f"REPRO_DISPATCH_FORCE / force={force!r} is not a registered "
                f"{key.op!r} impl; known: {sorted(by_name)}")
        if explicit or pk is None:
            # an explicit call-site force= naming an impl that cannot execute
            # these params is a caller bug — surface it, never substitute
            raise KeyError(
                f"force={force!r} cannot execute a {key.op!r} layer with "
                f"params {sorted(pk or ())}; it requires "
                f"{sorted(REGISTRY.get(key.op, force).requires)}")
        # process-wide env override that doesn't apply to this layer's param
        # format: ignore it for this call rather than crash mid-model

    if _QUARANTINE:
        # drop actively-denylisted candidates from every remaining rung
        # (legacy, DB hit, profiled, heuristic); probation (TTL-expired)
        # entries stay eligible — that IS the re-probe.  If quarantine would
        # empty the candidate set entirely, resolution proceeds on the full
        # set rather than refusing to run (run_guarded will surface the
        # execution failure if it recurs)
        alive = [s for s in cands if not _is_quarantined(key.op, s.name)]
        if alive and len(alive) < len(cands):
            cands = alive
            by_name = {s.name: s for s in cands}

    if not dispatch_enabled():
        legacy = _LEGACY_DEFAULT.get(key.op)
        if legacy in by_name:
            return by_name[legacy], "legacy"
        return cands[0], "legacy"

    feasible = [s for s in cands if s.feasible(key)[0]]
    if not feasible:
        # nothing passes the static predicates: degrade to the candidate with
        # the smallest declared footprint instead of refusing to run
        return min(cands, key=lambda s: s.vmem_bytes(key)), "degraded"

    rec = db.get(key.token)
    if rec is not None and rec.get("impl") in by_name:
        spec = by_name[rec["impl"]]
        if spec.feasible(key)[0]:
            _C_DB_HIT.inc()
            return spec, "db"
    _C_DB_MISS.inc()

    if _profile_on_miss():
        try:
            rec = profile_op(key, db, param_keys=pk)
            if rec["impl"] in by_name:
                return by_name[rec["impl"]], "profiled"
        except TuningError:
            pass

    return _heuristic(feasible, key), "heuristic"


def ensure_profiled(key: OpKey, *, param_keys=None, db: Optional[ProfileDB] = None,
                    iters: int = 5) -> Dict:
    """Profile ``key`` if the DB has no entry for it; return the record."""
    the_db = db if db is not None else get_db()
    rec = the_db.get(key.token)
    if rec is None:
        rec = profile_op(key, the_db, iters=iters, param_keys=param_keys)
    return rec


# ---------------------------------------------------------------------------
# Call-site helpers
# ---------------------------------------------------------------------------


def linear_impl(x_shape, values_shape, dtype="float32", *,
                force: Optional[str] = None,
                phase: Optional[str] = None) -> ImplSpec:
    """Implementation for a compressed linear given activation/values shapes
    (the hot path used by ``core.sparse_linear.linear_apply``).

    ``phase`` defaults to the ambient :func:`phase_scope` tag, so call sites
    traced inside the serve Engine's prefill/decode steps resolve the
    phase-specialized entry without any signature changes.
    """
    if phase is None:
        phase = current_phase()
    key = linear_key_from(x_shape, values_shape, dtype, phase=phase)
    return best_impl(key, param_keys=("values", "idx"), force=force)


def iter_compressed_layers(tree, prefix: str = ""):
    """Yield (path, values, idx) for every compressed layer in a params tree
    (plain dicts or ``Boxed`` leaves; scan-stacked leading dims allowed)."""
    for path, _op, info in iter_op_layers(tree, prefix):
        yield path, info["values"], info["idx"]


def iter_op_layers(tree, prefix: str = ""):
    """Yield (path, op, info) for every dispatchable compressed layer in a
    params tree (plain dicts or ``Boxed`` leaves; scan-stacked leading dims
    allowed).

    ``op`` is the layer's operator kind: ``"conv"`` when the dict carries the
    ``conv_init`` discriminator (a ``conv_geom`` [kh, kw, c_in] leaf — the
    pair (values, idx) alone is shape-indistinguishable from a linear layer),
    else ``"linear"``.  ``info`` always has ``values``/``idx``; conv layers
    add static ``kh``/``kw``/``c_in`` ints read off the marker.
    """
    def unval(v):
        return getattr(v, "value", v)

    if isinstance(tree, dict):
        if "values" in tree and "idx" in tree:
            info = {"values": unval(tree["values"]), "idx": unval(tree["idx"])}
            if "conv_geom" in tree:
                import numpy as np

                # scan-stacked layers carry a stacked [L, 3] marker; the
                # statics are identical across the stack, so read layer 0
                geom = np.asarray(unval(tree["conv_geom"])).reshape(-1, 3)[0]
                info["kh"], info["kw"] = int(geom[0]), int(geom[1])
                info["c_in"] = int(geom[2])
                yield prefix or ".", "conv", info
            else:
                yield prefix or ".", "linear", info
        for k, v in tree.items():
            if k in ("values", "idx", "conv_geom"):
                continue
            yield from iter_op_layers(v, f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_op_layers(v, f"{prefix}[{i}]")


def _match_conv_hint(conv_hints: Optional[Mapping[str, Mapping[str, int]]],
                     path: str) -> Optional[Mapping[str, int]]:
    """Most-specific (longest) hint whose key is a substring of ``path``;
    the empty-string key is the catch-all default."""
    if not conv_hints:
        return None
    best = None
    for pat, hint in conv_hints.items():
        if pat in path and (best is None or len(pat) > len(best[0])):
            best = (pat, hint)
    return best[1] if best else None


def plan_params(params, *, batch_hint: int = 8, db: Optional[ProfileDB] = None,
                profile: Optional[bool] = None,
                phase_hints: Optional[Mapping[str, int]] = None,
                conv_hints: Optional[Mapping[str, Mapping[str, int]]] = None,
                ) -> Dict[str, str]:
    """Build-time dispatch plan for a model's params tree.

    Scans for compressed layers, resolves (and optionally profiles) the
    implementation for each distinct OpKey, and returns {token: impl name}.
    Called by the serve ``Engine`` so the first traced forward already sees a
    warm DB.  ``profile`` defaults to ``REPRO_DISPATCH_PROFILE``.

    ``phase_hints`` maps serving-phase tags to expected operand row counts,
    e.g. ``{"prefill": batch * prompt_len, "decode": batch}``; each phase gets
    its own phase-tagged OpKey (and, when profiling, its own DB entry), so
    prefill and decode shapes are profiled separately and the engine can pin
    per-phase implementations.  Without it the single ``batch_hint`` plans
    phase-agnostic keys exactly as before.

    Conv layers (tagged by ``conv_init``'s ``conv_geom`` discriminator — see
    :func:`iter_op_layers`) are planned under ``conv_key`` tokens, NOT
    misfiled as linear ops.  A conv OpKey needs the input-map shape, which is
    a call-time property, so ``conv_hints`` supplies it: a mapping from
    layer-path substring to ``{"h", "w", "batch", "stride", "pad", "v"}``
    (``w`` defaults to ``h``, ``stride`` to 1, ``pad`` to "same" = kh//2,
    ``batch`` to 1, ``v`` to 128); the longest matching key wins and ``""``
    is the catch-all.  Vision configs generate exact per-layer hints —
    ``repro.models.vision.conv_hints`` — so the planned tokens are identical
    to the ones ``conv_apply`` resolves at trace time.  Conv layers without a
    matching hint are skipped (their profiling happens lazily at the call
    site); conv tokens are planned phase-agnostic.
    """
    if not dispatch_enabled():
        # legacy fixed routing ignores the plan; skip the tree walk and the
        # per-layer idx.max() device syncs entirely
        return {}
    if profile is None:
        profile = _profile_on_miss()
    the_db = db if db is not None else get_db()
    hints: Mapping[str, int] = phase_hints if phase_hints else {"": batch_hint}
    plan: Dict[str, str] = {}

    def _plan_key(key: OpKey) -> None:
        if key.token in plan:
            return
        if profile and key.token not in the_db:
            try:
                ensure_profiled(key, param_keys=("values", "idx"), db=the_db)
            except TuningError:
                pass
        plan[key.token] = best_impl(
            key, param_keys=("values", "idx"), db=the_db).name

    with _ot.span("dispatch.plan_params", profile=bool(profile),
                  phases=",".join(sorted(hints))) as sp:
        for path, op, info in iter_op_layers(params):
            values, idx = info["values"], info["idx"]
            n_tiles, k_kept, tile = (int(s) for s in values.shape[-3:])
            dtype = getattr(values, "dtype", "float32")
            if op == "conv":
                hint = _match_conv_hint(conv_hints, path)
                if hint is None:
                    continue  # no map-shape hint: cannot form the conv token
                kh, kw, c = info["kh"], info["kw"], info["c_in"]
                h = int(hint["h"])
                key = conv_key(
                    c, h, int(hint.get("w", h)), n_tiles * tile, kh, kw,
                    int(hint.get("stride", 1)), int(hint.get("pad", kh // 2)),
                    k_kept, tile, v=int(hint.get("v", 128)), dtype=dtype,
                    batch=int(hint.get("batch", 1)))
                _plan_key(key)
                continue
            # d_in is not stored in the compressed layout; the max kept index
            # bounds it from below, and OpKey buckets d_in to a power of two,
            # so this lands in the trace-time token whenever the kept support
            # reaches the top half of the reduction dim (essentially always
            # for magnitude-pruned weights).  If it doesn't, the plan warms a
            # token the forward never looks up and that layer falls back to
            # the heuristic — a missed warm-up, never a wrong result.
            d_in = int(idx.max()) + 1 if getattr(idx, "size", 0) else k_kept
            for ph, rows in hints.items():
                _plan_key(linear_key(rows, d_in, n_tiles * tile, k_kept, tile,
                                     dtype=dtype, phase=ph))
        sp.set(planned=len(plan))
    return plan
