"""Loop-aware HLO cost analyzer.

``compiled.cost_analysis()`` visits every computation ONCE — a while loop
(scan over layers, grad-accumulation microbatches, chunked SSM scans) is
counted as a single iteration, which under-counts a stacked-layer LM by
orders of magnitude.  This module re-derives FLOPs / HBM bytes / collective
bytes from the optimized HLO text with per-loop trip-count multipliers
(XLA annotates ``backend_config={"known_trip_count":{"n":...}}``).

Accounting rules (per-device, since the input is the post-SPMD module):
  flops:
    dot        2 * prod(output dims) * prod(lhs contracting dim sizes)
    elementwise/reduce/etc.: 1 flop per output element (dots dominate; this
    matches the coarse convention of HloCostAnalysis)
  bytes (HBM traffic proxy):
    per instruction: output bytes + operand bytes, where fusions count only
    their boundary (internal fused ops move no HBM data) — closer to real
    traffic than cost_analysis' raw "bytes accessed"
  collective bytes:
    max(input, output) bytes per collective op, x loop multipliers
  while: (body + cond) * known_trip_count (default 1 if unknown)
  conditional: max over branch computations
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s2": 0.25, "u2": 0.25,
}

_SHAPE_TOKEN = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "ragged-all-to-all",
}

_ZERO_COST_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "get-dimension-size", "opt-barrier", "optimization-barrier",
}

_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"([\w\-]+)\(")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalized ``compiled.cost_analysis()``: jax has returned both a dict
    and a one-element list of dicts across 0.4.x/0.5.x; always give a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def dtype_bytes(dt: str) -> float:
    return _DTYPE_BYTES.get(dt, 4)


def shape_elems_bytes(shape_str: str) -> Tuple[int, float]:
    """Total (elements, bytes) across all array components in a shape string."""
    elems, byts = 0, 0.0
    for dt, dims in _SHAPE_TOKEN.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def first_shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_TOKEN.search(shape_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        return self

    def scaled(self, mult: float) -> "Cost":
        return Cost(
            flops=self.flops * mult,
            bytes=self.bytes * mult,
            coll_bytes=self.coll_bytes * mult,
            coll_by_kind={k: v * mult for k, v in self.coll_by_kind.items()},
            coll_counts={k: v * mult for k, v in self.coll_counts.items()},
        )


def _balanced_paren(s: str, start: int) -> int:
    """Index just past the matching ')' for the '(' at s[start]."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_instr(s: str) -> Optional[Instr]:
    m = _NAME_RE.match(s)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(s):
        return None
    # shape: tuple shapes need balanced-paren scanning (nested tuples)
    if s[i] == "(":
        j = _balanced_paren(s, i)
        shape = s[i:j]
    else:
        j = s.find(" ", i)
        if j == -1:
            return None
        shape = s[i:j]
    rest = s[j:].lstrip()
    off = len(s) - len(rest)
    m2 = _OP_RE.match(rest)
    if not m2:
        return None
    op = m2.group(1)
    paren_start = off + m2.end() - 1
    end = _balanced_paren(s, paren_start)
    operand_str = s[paren_start:end]
    attrs = s[end:]
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return Instr(name, shape, op, operands, attrs, s)


def parse_module(hlo_text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_RE.match(s)
            if m and s.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if s.startswith("ENTRY"):
                    entry = cur
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        instr = _parse_instr(s)
        if instr is not None:
            comps[cur].append(instr)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _dot_flops(instr: Instr, symtab: Dict[str, str]) -> float:
    _, out_dims = first_shape_dims(instr.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    if mc and instr.operands:
        lhs_shape = symtab.get(instr.operands[0], "")
        _, lhs_dims = first_shape_dims(lhs_shape)
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "remainder",
    "compare", "select", "clamp", "floor", "ceil", "round-nearest-afz",
    "reduce", "reduce-window", "erf", "cbrt",
}


class HloCost:
    """Recursive, memoized per-computation cost with loop multipliers."""

    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        self.symtabs: Dict[str, Dict[str, str]] = {
            cname: {i.name: i.shape for i in instrs}
            for cname, instrs in self.comps.items()
        }
        self._memo: Dict[str, Cost] = {}

    # -- helpers ----------------------------------------------------------
    def _called(self, instr: Instr, key: str) -> List[str]:
        names = []
        m = re.search(key + r"=%?([\w.\-]+)", instr.attrs)
        if m:
            names.append(m.group(1))
        return names

    def _trip_count(self, instr: Instr) -> float:
        m = re.search(r'known_trip_count[^0-9]*(\d+)', instr.attrs)
        return float(m.group(1)) if m else 1.0

    # -- per-instruction --------------------------------------------------
    def instr_cost(self, instr: Instr, comp: str, *, inside_fusion: bool) -> Cost:
        c = Cost()
        op = instr.op
        symtab = self.symtabs.get(comp, {})
        out_elems, out_bytes = shape_elems_bytes(instr.shape)
        in_bytes = sum(shape_elems_bytes(symtab.get(o, ""))[1] for o in instr.operands)

        if op in _ZERO_COST_OPS:
            return c
        # flops
        if op in ("dot", "dot-general"):
            c.flops += _dot_flops(instr, symtab)
        elif op == "convolution":
            # rough: 2 * out_elems * (kernel elems) — no convs in the zoo's
            # hot path (frontends are stubs), keep a floor of out_elems
            c.flops += 2.0 * out_elems
        elif op in _ELEMENTWISE_FLOP_OPS:
            c.flops += float(out_elems)

        # bytes: fusion boundaries only
        if not inside_fusion:
            if op == "fusion":
                c.bytes += out_bytes + in_bytes
            elif op not in ("while", "conditional", "call"):
                c.bytes += out_bytes + in_bytes

        # collectives
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES or op in _COLLECTIVES:
            if not op.endswith("-done"):
                traffic = max(in_bytes, out_bytes)
                c.coll_bytes += traffic
                c.coll_by_kind[base] = c.coll_by_kind.get(base, 0) + traffic
                c.coll_counts[base] = c.coll_counts.get(base, 0) + 1

        # called computations
        if op == "fusion":
            for callee in self._called(instr, "calls"):
                c += self.comp_cost(callee, inside_fusion=True)
        elif op == "while":
            mult = self._trip_count(instr)
            inner = Cost()
            for key in ("body", "condition"):
                for callee in self._called(instr, key):
                    inner += self.comp_cost(callee, inside_fusion=False)
            c += inner.scaled(mult)
        elif op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\})", instr.attrs)
            names: List[str] = []
            if branches:
                names = re.findall(r"%([\w.\-]+)", branches[0])
            else:
                names = self._called(instr, "true_computation") + self._called(
                    instr, "false_computation"
                )
            if names:
                costs = [self.comp_cost(n, inside_fusion=False) for n in names]
                best = max(costs, key=lambda x: x.flops + x.bytes)
                c += best
        elif op == "call":
            for callee in self._called(instr, "to_apply"):
                c += self.comp_cost(callee, inside_fusion=False)
        return c

    def comp_cost(self, name: str, *, inside_fusion: bool) -> Cost:
        key = f"{name}|{inside_fusion}"
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        for instr in self.comps.get(name, []):
            total += self.instr_cost(instr, name, inside_fusion=inside_fusion)
        self._memo[key] = total
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost("__entry__", inside_fusion=False)


def analyze_hlo(hlo_text: str) -> Dict:
    cost = HloCost(hlo_text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.coll_bytes,
        "collective_by_kind": cost.coll_by_kind,
        "collective_counts": cost.coll_counts,
    }


def bytes_details(hlo_text: str, top: int = 25) -> List[Dict]:
    """Attribution: top HBM-traffic instructions by (bytes x loop multiplier)."""
    hc = HloCost(hlo_text)
    rows: List[Dict] = []

    def walk(comp: str, mult: float):
        for instr in hc.comps.get(comp, []):
            op = instr.op
            if op in _ZERO_COST_OPS:
                continue
            symtab = hc.symtabs.get(comp, {})
            _, out_b = shape_elems_bytes(instr.shape)
            in_b = sum(shape_elems_bytes(symtab.get(o, ""))[1] for o in instr.operands)
            if op == "while":
                tm = hc._trip_count(instr)
                for key in ("body", "condition"):
                    for callee in hc._called(instr, key):
                        walk(callee, mult * tm)
                continue
            if op == "call":
                for callee in hc._called(instr, "to_apply"):
                    walk(callee, mult)
                continue
            if op == "conditional":
                continue
            b = (out_b + in_b) * mult
            if b < 1e6:
                continue
            m = re.search(r'op_name="([^"]+)"', instr.attrs)
            rows.append({
                "op": op,
                "bytes": b,
                "mult": mult,
                "shape": instr.shape[:60],
                "op_name": (m.group(1) if m else "")[-120:],
            })

    walk("__entry__", 1.0)
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:top]


def collective_details(hlo_text: str, top: int = 25) -> List[Dict]:
    """Attribution: the top collectives by (bytes x loop multiplier), with the
    jax op_name metadata that produced them — the hillclimb diagnostic."""
    hc = HloCost(hlo_text)
    rows: List[Dict] = []

    def walk(comp: str, mult: float, seen: set):
        if comp in seen:
            return
        for instr in hc.comps.get(comp, []):
            op = instr.op
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                symtab = hc.symtabs.get(comp, {})
                _, out_b = shape_elems_bytes(instr.shape)
                in_b = sum(shape_elems_bytes(symtab.get(o, ""))[1] for o in instr.operands)
                m = re.search(r'op_name="([^"]+)"', instr.attrs)
                rows.append({
                    "kind": base,
                    "bytes": max(in_b, out_b) * mult,
                    "mult": mult,
                    "shape": instr.shape[:80],
                    "op_name": (m.group(1) if m else "")[-140:],
                })
            if op == "fusion":
                for callee in hc._called(instr, "calls"):
                    walk(callee, mult, seen)
            elif op == "while":
                tm = hc._trip_count(instr)
                for key in ("body", "condition"):
                    for callee in hc._called(instr, key):
                        walk(callee, mult * tm, seen)
            elif op == "call":
                for callee in hc._called(instr, "to_apply"):
                    walk(callee, mult, seen)

    walk("__entry__", 1.0, set())
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:top]
