"""Roofline analysis from dry-run compiled artifacts.

Three terms per (arch × shape × mesh), hardware = TPU v5e:
  compute    = HLO_FLOPs_per_chip / peak_FLOP/s        (197 TF bf16 / chip)
  memory     = HLO_bytes_per_chip / HBM_bw             (819 GB/s / chip)
  collective = collective_bytes_per_chip / link_bw     (~50 GB/s / ICI link)

cost_analysis() is computed on the post-SPMD per-device module, so flops /
bytes are already per-chip.  Collective bytes are NOT in cost_analysis —
they are parsed from the optimized HLO text: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we take
max(input bytes, output bytes) as the wire-traffic proxy.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' -> byte count. Tuple shapes handled by the caller."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum wire bytes of every collective op in an (optimized) HLO module.

    Loop bodies are counted once (an under-estimate when collectives sit in a
    scanned layer body — the per-layer trip count multiplier is applied by the
    caller when known via `loop_multipliers`).
    """
    counts: Dict[str, int] = {}
    by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        out_shape, op = m.groups()
        base_op = op.rstrip("-start").rstrip("-done") if op.endswith(("-start", "-done")) else op
        if base_op not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        out_b = shape_bytes(out_shape)
        # operand bytes: parse shapes inside the argument list
        args = s[s.find("(") :]
        in_b = shape_bytes(args)
        traffic = max(in_b, out_b)
        counts[base_op] = counts.get(base_op, 0) + 1
        by[base_op] = by.get(base_op, 0) + traffic
    return CollectiveStats(counts=counts, bytes_by_kind=by)


def count_while_trip(hlo_text: str) -> List[int]:
    """Best-effort trip counts of while loops (from known_trip_count)."""
    return [int(x) for x in re.findall(r'known_trip_count=\{?"?n"?[=:](\d+)', hlo_text)]


@dataclasses.dataclass
class Roofline:
    flops: float                 # per chip
    hlo_bytes: float             # per chip
    collective_bytes: float      # per chip
    model_flops: float           # 6*N*D global
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step's bound time spent at the compute roofline if
        only MODEL_FLOPS were executed — the 'score' we hillclimb."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "collective_bytes_per_chip": self.collective_bytes,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, cell, sparsity: float = 0.0) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed.

    For decode cells D = global_batch (one token each); the attention
    KV-read work is memory-side and not part of the 6ND convention.
    Sparsity scales the prunable fraction of N (embeddings excluded).
    """
    n_active = cfg.active_param_count()
    emb = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    body = max(n_active - emb, 0)
    n_eff = emb + body * (1.0 - sparsity)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_eff * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_eff * tokens
    return 2.0 * n_eff * cell.global_batch  # decode: one token per sequence
