from repro.roofline.analysis import (  # noqa: F401
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    CollectiveStats,
    Roofline,
    model_flops_for,
    parse_collectives,
    shape_bytes,
)
