"""Deterministic, seeded fault injection for the serving runtime.

Robustness code is only trustworthy if its failure paths actually run.  This
module gives the repo ONE way to make them run: named **fault sites** planted
at the runtime's failure boundaries probe :func:`maybe_fail`, and a **fault
plan** — parsed from the ``REPRO_FAULTS`` env var or installed
programmatically via :func:`fault_scope` — decides which probes raise a typed
:class:`InjectedFault`.  Everything is deterministic under a seed, so a chaos
test that found a leak replays bit-for-bit.

Design mirrors :mod:`repro.obs.trace`:

  * **Zero-cost when off.**  Every probe first reads one module-global bool
    (:func:`enabled`); with no plan installed (the default) ``maybe_fail``
    returns immediately — the serving hot loop pays a single attribute read.
  * **Env-var or programmatic.**  ``REPRO_FAULTS="site:iter=3,site:p=0.05"``
    arms injection process-wide (picked up at import, like ``REPRO_OBS``);
    tests use ``with fault_scope("page_pool.alloc:n=1"): ...`` which
    installs a fresh plan and restores the previous state on exit.
  * **Observable.**  Every injection emits a ``fault.inject`` obs instant and
    bumps the ``fault.injected`` counter, so a trace of a chaos run shows
    exactly where the failures landed.

Schedule grammar (comma-separated entries)::

    site[@match]:kind=value

  ``site``   one of :data:`SITES`.  Unknown sites still parse and arm (the
             escape hatch tests rely on), but the first probe or plan entry
             naming one warns once and bumps the ``fault.unknown_site``
             counter — a typo'd site in a chaos spec is a probe that never
             fires, which is exactly the silent failure mode chaos testing
             exists to remove;
  ``match``  optional filter: the entry only applies to probes whose context
             (the ``**ctx`` kwargs of :func:`maybe_fail`) contains the value,
             e.g. ``dispatch.execute@compressed_xla:n=1`` fails only the
             ``compressed_xla`` candidate;
  ``kind``   ``iter=K`` fire on the entry's K-th matching probe (0-based);
             ``n=K``    fire on the first K matching probes;
             ``p=F``    fire each matching probe with probability F, drawn
                        from the plan's seeded RNG.

Fault sites in the tree today (see ``docs/robustness.md``):

    ``page_pool.alloc``   PagePool.alloc / PagePool.grow (simulated KV-page
                          exhaustion -> scheduler preemption policy)
    ``dispatch.execute``  dispatch.run_guarded around every resolved
                          candidate's apply (-> quarantine-degradation)
    ``kernel.paged_attn`` paged-attention execution boundary
    ``scheduler.iter``    top of each scheduler iteration (transient hiccup)
    ``train.step``        top of each trainer step (crash mid-run -> restart
                          from checkpoint, resume-determinism contract)
    ``ckpt.write``        checkpoint serialization, before any file is
                          written (async-save failure propagation)
    ``ckpt.rename``       after a complete tmp dir is written, before the
                          atomic rename (preempted writer -> orphaned tmp)
    ``data.batch``        data-pipeline batch materialization

Note on jit: sites inside traced step functions (``dispatch.execute``,
``kernel.paged_attn``) probe at *trace time* — an already-compiled executable
re-probes only on retrace.  Sites in Python-level control flow
(``scheduler.iter``, ``page_pool.alloc``) probe on every call.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import warnings
from typing import Dict, List, Optional, Set, Tuple

from repro import env as _env

from repro.obs import metrics as _om
from repro.obs import trace as _ot

__all__ = [
    "SITES", "InjectedFault", "FaultRule", "FaultPlan", "parse_spec",
    "enabled", "plan", "install", "uninstall", "configure", "fault_scope",
    "maybe_fail",
]

# The named failure boundaries the runtime plants probes at.  New sites must
# be added here and to docs/robustness.md — the repro.analysis RC201 lint
# checks probe literals against this tuple, and an unregistered site warns
# once at runtime (see _note_unknown_site).
SITES: Tuple[str, ...] = (
    "page_pool.alloc",
    "dispatch.execute",
    "kernel.paged_attn",
    "scheduler.iter",
    # training tier (docs/robustness.md "Training tier")
    "train.step",
    "ckpt.write",
    "ckpt.rename",
    "data.batch",
)

_C_INJECTED = _om.counter("fault.injected")
_C_UNKNOWN_SITE = _om.counter("fault.unknown_site")
_WARNED_UNKNOWN: Set[str] = set()
_SITE_SET = frozenset(SITES)


def _note_unknown_site(site: str, where: str) -> None:
    """Warn once per unknown site (plan entries and armed probes): unknown
    sites stay allowed — tests probe scratch sites — but silently inert
    entries are how chaos-spec typos hide."""
    if site in _SITE_SET or site in _WARNED_UNKNOWN:
        return
    _WARNED_UNKNOWN.add(site)
    _C_UNKNOWN_SITE.inc()
    _ot.instant("fault.unknown_site", site=site, where=where)
    warnings.warn(
        f"fault site {site!r} ({where}) is not registered in fault.SITES; "
        f"a misspelled site never fires — register new sites in "
        f"repro/fault.py and docs/robustness.md",
        RuntimeWarning, stacklevel=3)


class InjectedFault(RuntimeError):
    """Raised by an armed fault site.  Carries the site name, the 1-based
    injection ordinal at that site, and the probe's context kwargs."""

    def __init__(self, site: str, hit: int, ctx: Optional[Dict] = None):
        self.site = site
        self.hit = hit
        self.ctx = dict(ctx or {})
        detail = f" {self.ctx}" if self.ctx else ""
        super().__init__(f"injected fault at {site} (hit #{hit}){detail}")


@dataclasses.dataclass
class FaultRule:
    """One parsed schedule entry.  ``seen``/``fired`` are per-rule counters
    over *matching* probes, so ``iter``/``n`` schedules on a filtered rule
    count only the probes the filter admits."""

    site: str
    match: Optional[str] = None
    iters: frozenset = frozenset()
    n: int = 0
    p: float = 0.0
    seen: int = 0
    fired: int = 0

    def applies(self, ctx: Dict) -> bool:
        if self.match is None:
            return True
        return any(self.match == str(v) for v in ctx.values())

    def wants(self, rng: random.Random) -> bool:
        """Advance this rule's probe counter; True if it schedules a fault
        now.  The RNG is always consulted for ``p`` rules so the draw
        sequence (hence determinism) is independent of other rules firing."""
        i = self.seen
        self.seen += 1
        fire = i in self.iters or i < self.n
        if self.p > 0.0:
            fire = (rng.random() < self.p) or fire
        return fire


class FaultPlan:
    """A set of :class:`FaultRule` plus the seeded RNG and hit bookkeeping."""

    def __init__(self, rules: List[FaultRule], seed: int = 0,
                 spec: str = ""):
        self.rules = list(rules)
        self.seed = int(seed)
        self.spec = spec
        self._rng = random.Random(self.seed)
        self.probes: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    def probe(self, site: str, ctx: Dict) -> None:
        """Count one probe of ``site``; raise :class:`InjectedFault` if any
        matching rule schedules a fault for it."""
        self.probes[site] = self.probes.get(site, 0) + 1
        hit: Optional[FaultRule] = None
        for rule in self.rules:
            if rule.site != site or not rule.applies(ctx):
                continue
            if rule.wants(self._rng) and hit is None:
                hit = rule
        if hit is None:
            return
        hit.fired += 1
        self.fired[site] = self.fired.get(site, 0) + 1
        _C_INJECTED.inc()
        _ot.instant("fault.inject", site=site, hit=self.fired[site],
                    rule=hit.match or "*", **{k: str(v) for k, v in ctx.items()})
        raise InjectedFault(site, self.fired[site], ctx)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FaultPlan({self.spec!r}, seed={self.seed}, fired={self.fired})"


def parse_spec(spec: str, seed: int = 0) -> FaultPlan:
    """Parse the ``REPRO_FAULTS`` grammar into a :class:`FaultPlan`."""
    rules: List[FaultRule] = []
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        site, sep, sched = entry.partition(":")
        if not sep or not site:
            raise ValueError(
                f"fault entry {entry!r}: expected 'site:kind=value'")
        match = None
        if "@" in site:
            site, match = site.split("@", 1)
            if not site or not match:
                raise ValueError(f"fault entry {entry!r}: bad '@' filter")
        kind, sep, value = sched.partition("=")
        if not sep:
            raise ValueError(f"fault entry {entry!r}: expected 'kind=value'")
        try:
            if kind == "iter":
                rule = FaultRule(site, match, iters=frozenset({int(value)}))
            elif kind == "n":
                rule = FaultRule(site, match, n=int(value))
            elif kind == "p":
                rule = FaultRule(site, match, p=float(value))
            else:
                raise ValueError(
                    f"fault entry {entry!r}: unknown schedule kind {kind!r} "
                    f"(use iter=K, n=K, or p=F)")
        except (TypeError, ValueError) as e:
            if "unknown schedule kind" in str(e):
                raise
            raise ValueError(f"fault entry {entry!r}: bad value {value!r}")
        if rule.p < 0.0 or rule.p > 1.0:
            raise ValueError(f"fault entry {entry!r}: p outside [0, 1]")
        _note_unknown_site(rule.site, "plan entry")
        rules.append(rule)
    return FaultPlan(rules, seed=seed, spec=spec)


# module-global fast path: maybe_fail reads one bool while injection is off
_ENABLED: bool = False
_PLAN: Optional[FaultPlan] = None


def enabled() -> bool:
    """Is a fault plan armed?  The single gate every probe checks first."""
    return _ENABLED


def plan() -> Optional[FaultPlan]:
    """The armed plan (its per-site ``probes``/``fired`` counters are the
    post-mortem view a chaos test asserts against), or None."""
    return _PLAN


def install(spec, seed: Optional[int] = None) -> FaultPlan:
    """Arm a fault plan process-wide.  ``spec`` is a grammar string or a
    ready :class:`FaultPlan`; returns the installed plan."""
    global _ENABLED, _PLAN
    if isinstance(spec, FaultPlan):
        p = spec
    else:
        p = parse_spec(str(spec), seed=0 if seed is None else seed)
    _PLAN = p
    _ENABLED = bool(p.rules)
    return p


def uninstall() -> None:
    """Disarm injection (probes return to the one-bool fast path)."""
    global _ENABLED, _PLAN
    _ENABLED = False
    _PLAN = None


def configure() -> Optional[FaultPlan]:
    """(Re-)read ``REPRO_FAULTS`` / ``REPRO_FAULTS_SEED`` from the
    environment; arms a plan when the spec is non-empty, disarms otherwise."""
    spec = str(_env.get("REPRO_FAULTS")).strip()
    if not spec:
        uninstall()
        return None
    return install(spec, seed=_env.get("REPRO_FAULTS_SEED"))


@contextlib.contextmanager
def fault_scope(spec, seed: int = 0):
    """Arm ``spec`` inside this scope only; restores the previous plan (or
    disarmed state) on exit.  Yields the :class:`FaultPlan` so the body can
    assert on its ``fired``/``probes`` counters."""
    global _ENABLED, _PLAN
    prev = (_ENABLED, _PLAN)
    p = install(spec, seed=seed)
    try:
        yield p
    finally:
        _ENABLED, _PLAN = prev


def maybe_fail(site: str, **ctx) -> None:
    """Probe a fault site.  No-op unless a plan is armed; raises
    :class:`InjectedFault` when the armed plan schedules a fault here.
    An armed probe at a site missing from :data:`SITES` warns once (the
    off path stays a single bool read)."""
    if not _ENABLED:
        return
    _note_unknown_site(site, "probe")
    _PLAN.probe(site, ctx)


# arm from the environment at import, mirroring REPRO_OBS: a subprocess
# started with REPRO_FAULTS=... runs chaos without any code changes
configure()
