"""Named counters, gauges, and fixed-bucket histograms.

Two kinds of registry share one implementation:

  * the **process-global** :data:`REGISTRY` — the sink every instrumented
    module (dispatch, serve, kernels, benchmarks) records into.  Its
    instruments consult :func:`repro.obs.trace.enabled` on every mutation,
    so with observability off each probe costs one bool read and returns;
  * **private always-on registries** — e.g. the serve ``Scheduler`` owns one
    as the backing store for its ``stats`` view.  Pass ``on=None`` (the
    default) to :class:`Registry` for an unconditional instance.

Instruments are created once and cached by name (module-level references are
the intended usage — no per-call dict lookups on hot paths); ``reset()``
zeroes values in place so cached references stay valid.  ``snapshot()``
returns a plain-JSON nested dict suitable for embedding in a trace file's
``otherData`` or a benchmark report.

Histograms use fixed geometric buckets (default: factor-2 from 1 µs when the
recorded unit is seconds — 40 buckets cover ~9 decades).  Percentiles are
nearest-rank over the bucket counts and return the **upper edge** of the
bucket holding the ranked sample, so the estimate always bounds the true
percentile from above and is off by at most one bucket ratio; the exact
observed min/max tighten the ends.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import trace as _trace

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY", "exp_buckets",
    "counter", "gauge", "histogram", "snapshot", "reset",
]


def exp_buckets(start: float = 1e-6, factor: float = 2.0,
                count: int = 40) -> Tuple[float, ...]:
    """Geometric bucket upper bounds ``start * factor**i``; the implicit
    final bucket is ``(last, inf)``."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(f"bad bucket spec start={start} factor={factor} "
                         f"count={count}")
    return tuple(start * factor ** i for i in range(count))


DEFAULT_BUCKETS = exp_buckets()


class _Instrument:
    __slots__ = ("name", "_on", "_lock")

    def __init__(self, name: str, on: Optional[Callable[[], bool]],
                 lock: threading.Lock):
        self.name = name
        self._on = on
        self._lock = lock

    def _recording(self) -> bool:
        return self._on is None or self._on()


class Counter(_Instrument):
    """Monotonic accumulator (ints or float totals like seconds)."""

    __slots__ = ("_value",)

    def __init__(self, name, on, lock):
        super().__init__(name, on, lock)
        self._value = 0

    def inc(self, n=1) -> None:
        if not self._recording():
            return
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value

    def _reset(self) -> None:
        self._value = 0


class Gauge(_Instrument):
    """Last-write-wins point-in-time value (queue depth, slot occupancy)."""

    __slots__ = ("_value",)

    def __init__(self, name, on, lock):
        super().__init__(name, on, lock)
        self._value = 0

    def set(self, v) -> None:
        if not self._recording():
            return
        self._value = v

    @property
    def value(self):
        return self._value

    def _reset(self) -> None:
        self._value = 0


class Histogram(_Instrument):
    """Fixed-bucket histogram with nearest-rank percentile estimates."""

    __slots__ = ("buckets", "_counts", "_count", "_sum", "_min", "_max")

    def __init__(self, name, on, lock, buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, on, lock)
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"histogram buckets must be strictly "
                             f"increasing, got {b!r}")
        self.buckets = b
        self._counts = [0] * (len(b) + 1)  # +1: overflow bucket (last, inf)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        if not self._recording():
            return
        v = float(v)
        # binary search for the first bucket whose upper edge holds v
        lo, hi = 0, len(self.buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            self._counts[lo] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile estimate: the upper edge of the bucket
        containing the ranked sample (exact observed max for the overflow
        bucket / p=100, exact min when the rank lands in the first occupied
        bucket's floor).  0.0 when empty."""
        if self._count == 0:
            return 0.0
        if not 0 <= p <= 100:
            raise ValueError(f"percentile p={p} outside [0, 100]")
        rank = max(int(math.ceil(p / 100.0 * self._count)), 1)
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                if i >= len(self.buckets):
                    return self._max  # overflow bucket: max is exact
                return min(self.buckets[i], self._max)
        return self._max

    def summary(self) -> Dict[str, float]:
        return {
            "count": self._count,
            "sum": self._sum,
            "min": 0.0 if self._count == 0 else self._min,
            "max": 0.0 if self._count == 0 else self._max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }

    def _reset(self) -> None:
        self._counts = [0] * (len(self.buckets) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf


class Registry:
    """Get-or-create store of named instruments.

    ``on`` gates every instrument's mutators; the process-global
    :data:`REGISTRY` passes :func:`repro.obs.trace.enabled`, private
    registries pass ``None`` (always record).
    """

    def __init__(self, on: Optional[Callable[[], bool]] = None):
        self._on = on
        self._lock = threading.Lock()
        self._instruments: Dict[str, _Instrument] = {}

    def _get(self, name: str, cls, **kwargs):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, self._on, self._lock, **kwargs)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, buckets=buckets)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> Dict[str, Dict]:
        """Plain-JSON view: {"counters": {...}, "gauges": {...},
        "histograms": {name: {count, sum, min, max, p50, p90, p99}}}."""
        with self._lock:
            insts = list(self._instruments.values())
        out: Dict[str, Dict] = {"counters": {}, "gauges": {}, "histograms": {}}
        for inst in insts:
            if isinstance(inst, Counter):
                out["counters"][inst.name] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][inst.name] = inst.value
            elif isinstance(inst, Histogram):
                out["histograms"][inst.name] = inst.summary()
        return out

    def reset(self) -> None:
        """Zero every instrument IN PLACE — cached references stay valid."""
        with self._lock:
            for inst in self._instruments.values():
                inst._reset()


# the process-global sink; its instruments are no-ops while obs is disabled
REGISTRY = Registry(on=_trace.enabled)


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str, buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, buckets=buckets)


def snapshot() -> Dict[str, Dict]:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
