"""Chrome trace-event schema validation (shared by CI smoke + tests).

Checks the properties the rest of the tooling relies on, not the full Chrome
spec: the file parses, ``traceEvents`` is a non-empty list, every event has
the required fields, timestamps are monotonically non-decreasing per
``(pid, tid)`` lane, and duration events form balanced, properly nested
B/E pairs per lane (what Perfetto needs to draw the span tree).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Union

REQUIRED_FIELDS = ("name", "ph", "ts", "pid", "tid")
KNOWN_PHASES = {"B", "E", "i", "I", "X", "C", "M"}


class TraceValidationError(ValueError):
    """The trace file violates the Chrome trace-event contract."""


def validate_chrome_trace(source: Union[str, os.PathLike, Dict]) -> Dict:
    """Validate a trace file (path) or already-parsed payload (dict).

    Returns a summary ``{"events", "spans", "instants", "lanes"}`` on
    success; raises :class:`TraceValidationError` naming the first violation
    otherwise.
    """
    if isinstance(source, dict):
        payload = source
    else:
        try:
            payload = json.loads(open(os.fspath(source)).read())
        except (OSError, json.JSONDecodeError) as e:
            raise TraceValidationError(f"unreadable trace file: {e}") from e
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise TraceValidationError("payload has no 'traceEvents' key")
    events = payload["traceEvents"]
    if not isinstance(events, list) or not events:
        raise TraceValidationError("'traceEvents' is empty — nothing was "
                                   "recorded (is REPRO_OBS on?)")

    last_ts: Dict[tuple, float] = {}
    stacks: Dict[tuple, List[str]] = {}
    spans = instants = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise TraceValidationError(f"event #{i} is not an object: {ev!r}")
        missing = [f for f in REQUIRED_FIELDS if f not in ev]
        if missing:
            raise TraceValidationError(
                f"event #{i} ({ev.get('name')!r}) missing fields {missing}")
        ph = ev["ph"]
        if ph not in KNOWN_PHASES:
            raise TraceValidationError(
                f"event #{i} ({ev['name']!r}) has unknown phase {ph!r}")
        lane = (ev["pid"], ev["tid"])
        ts = float(ev["ts"])
        if lane in last_ts and ts < last_ts[lane]:
            raise TraceValidationError(
                f"event #{i} ({ev['name']!r}): timestamp {ts} goes backwards "
                f"on lane {lane} (prev {last_ts[lane]})")
        last_ts[lane] = ts
        if ph == "B":
            stacks.setdefault(lane, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(lane, [])
            if not stack:
                raise TraceValidationError(
                    f"event #{i}: E for {ev['name']!r} on lane {lane} with "
                    f"no open span")
            opened = stack.pop()
            if opened != ev["name"]:
                raise TraceValidationError(
                    f"event #{i}: E for {ev['name']!r} closes span "
                    f"{opened!r} (improper nesting) on lane {lane}")
            spans += 1
        elif ph in ("i", "I"):
            instants += 1
    open_spans = {lane: stack for lane, stack in stacks.items() if stack}
    if open_spans:
        raise TraceValidationError(
            f"unbalanced B/E pairs — spans left open: {open_spans}")
    return {"events": len(events), "spans": spans, "instants": instants,
            "lanes": len(last_ts)}


def main(argv=None) -> None:
    """CLI: ``python -m repro.obs.validate trace.json``."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="Chrome trace-event JSON file")
    args = ap.parse_args(argv)
    summary = validate_chrome_trace(args.path)
    print(f"trace OK: {summary['events']} events, {summary['spans']} spans, "
          f"{summary['instants']} instants, {summary['lanes']} lane(s)")


if __name__ == "__main__":
    main()
