"""Structured span/instant-event tracer with Chrome-trace-event export.

Design constraints (the reason this module exists instead of printf):

  * **Compiled out by default.** Every instrumentation point first asks
    :func:`enabled`; when observability is off (the default) ``span()``
    returns a shared no-op context manager and ``instant()`` returns without
    allocating, so the serving hot loop pays one module-global bool read per
    probe.  ``REPRO_OBS=on`` (or :func:`set_enabled`) turns recording on.
  * **Bounded memory.** Events land in a thread-safe ring buffer
    (``REPRO_OBS_RING`` entries, default 65536).  Overflow drops the *oldest*
    events and counts the drops — a long-running server can leave tracing on
    without unbounded growth.
  * **Ambient nesting.** A contextvar stack (the same ambient-scope pattern
    as ``dispatch.phase_scope``) tracks the open-span path, so events carry
    their nesting depth/parent without threading a span object through call
    signatures; spans close correctly under exceptions (``finally``).
  * **Standard export.** :func:`dump_chrome_trace` writes the Chrome
    trace-event JSON format (``{"traceEvents": [...]}``) loadable in
    Perfetto / ``chrome://tracing``; spans are B/E duration-event pairs,
    instants are ``ph="i"`` events.  ``REPRO_OBS_TRACE=<path>`` dumps
    automatically at interpreter exit.

See ``docs/observability.md`` for the event schema and env-var reference.
"""
from __future__ import annotations

import atexit
import contextvars
import json
import os
import tempfile
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = [
    "enabled", "set_enabled", "configure", "span", "instant", "events",
    "reset", "dropped_events", "dump_chrome_trace", "current_stack", "now_us",
]

DEFAULT_RING = 65536

# process-relative clock origin: Chrome trace ts are microseconds from an
# arbitrary epoch, so perf_counter (monotonic, high-resolution) is the right
# source; anchoring at import keeps the numbers small and diff-friendly
_T0 = time.perf_counter()


def now_us() -> float:
    """Microseconds since module import (monotonic)."""
    return (time.perf_counter() - _T0) * 1e6


def _env_enabled() -> bool:
    from repro import env as _env

    return bool(_env.get("REPRO_OBS"))


def _env_ring() -> int:
    from repro import env as _env

    return max(int(_env.get("REPRO_OBS_RING")), 1)


# module-global fast path: instrumentation points read one bool
_ENABLED: bool = _env_enabled()


def enabled() -> bool:
    """Is event recording on?  The single gate every probe checks first."""
    return _ENABLED


def set_enabled(value: Optional[bool]) -> None:
    """Force recording on/off; ``None`` re-reads ``REPRO_OBS`` from the
    environment (tests toggling the env var mid-process)."""
    global _ENABLED
    _ENABLED = _env_enabled() if value is None else bool(value)


class _RingBuffer:
    """Thread-safe bounded event store; overflow drops oldest, counts drops."""

    def __init__(self, capacity: int):
        self._lock = threading.Lock()
        self._buf: deque = deque(maxlen=capacity)
        self.dropped = 0

    @property
    def capacity(self) -> int:
        return self._buf.maxlen or 0

    def append(self, event: Dict) -> None:
        with self._lock:
            if len(self._buf) == self._buf.maxlen:
                self.dropped += 1
            self._buf.append(event)

    def snapshot(self) -> List[Dict]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0


_RING = _RingBuffer(_env_ring())

# open-span name path of the current (logical) thread of execution; a tuple
# so each set() is an immutable snapshot (async/generator-safe)
_STACK: contextvars.ContextVar = contextvars.ContextVar("obs_span_stack",
                                                        default=())


def configure(capacity: Optional[int] = None) -> None:
    """Replace the ring buffer (tests sizing overflow behaviour).  ``None``
    re-reads ``REPRO_OBS_RING``."""
    global _RING
    _RING = _RingBuffer(_env_ring() if capacity is None else max(capacity, 1))


def current_stack() -> tuple:
    """Names of the spans currently open in this execution context."""
    return _STACK.get()


def _event(ph: str, name: str, cat: str, args: Optional[Dict] = None,
           ts: Optional[float] = None) -> Dict:
    ev = {
        "name": name,
        "cat": cat,
        "ph": ph,
        "ts": now_us() if ts is None else ts,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
    }
    if args:
        ev["args"] = args
    return ev


class _Span:
    """Recording span: emits a B event on enter, an E event on exit (also on
    exceptions), and maintains the ambient nesting stack."""

    __slots__ = ("name", "cat", "args", "_token", "_extra")

    def __init__(self, name: str, cat: str, args: Dict):
        self.name = name
        self.cat = cat
        self.args = args
        self._token = None
        self._extra: Dict = {}

    def set(self, **kwargs) -> "_Span":
        """Attach result args known only at span end (merged into E)."""
        self._extra.update(kwargs)
        return self

    def __enter__(self) -> "_Span":
        stack = _STACK.get()
        args = dict(self.args)
        args["depth"] = len(stack)
        self._token = _STACK.set(stack + (self.name,))
        _RING.append(_event("B", self.name, self.cat, args))
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._token is not None:
            _STACK.reset(self._token)
        args = dict(self._extra)
        if exc is not None:
            args["error"] = f"{exc_type.__name__}: {exc}"
        _RING.append(_event("E", self.name, self.cat, args or None))
        return False  # never swallow


class _NullSpan:
    """No-op span handed out while recording is off (one shared instance)."""

    __slots__ = ()

    def set(self, **kwargs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "repro", **args):
    """Context manager recording a B/E duration pair around its body.

    Zero-cost when disabled: returns a shared no-op object, allocates
    nothing.  ``with span("dispatch.resolve", token=...) as s: ...;
    s.set(impl=...)`` attaches end-of-span result args.
    """
    if not _ENABLED:
        return _NULL_SPAN
    return _Span(name, cat, args)


def instant(name: str, cat: str = "repro", **args) -> None:
    """Record a point-in-time event (Chrome ``ph="i"``, thread scope)."""
    if not _ENABLED:
        return
    ev = _event("i", name, cat, args or None)
    ev["s"] = "t"
    _RING.append(ev)


def events() -> List[Dict]:
    """Snapshot of the ring buffer (oldest first)."""
    return _RING.snapshot()


def dropped_events() -> int:
    """Events lost to ring overflow since the last :func:`reset`."""
    return _RING.dropped


def reset() -> None:
    """Clear the ring buffer and the drop counter."""
    _RING.clear()


def dump_chrome_trace(path, metadata: Optional[Dict] = None) -> int:
    """Write the buffered events as a Chrome trace-event JSON file.

    The file is the object form (``{"traceEvents": [...]}``) so Perfetto /
    ``chrome://tracing`` load it directly; ``metadata`` (e.g. a metrics
    snapshot) lands under ``otherData``.  Atomic write (temp + rename) so a
    crash mid-dump never leaves a torn file.  Returns the event count.
    """
    evs = _RING.snapshot()
    payload = {
        "traceEvents": evs,
        "displayTimeUnit": "ms",
        "otherData": dict(metadata or {}, dropped_events=_RING.dropped),
    }
    path = os.fspath(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return len(evs)


def _atexit_dump() -> None:
    from repro import env as _env

    path = _env.get("REPRO_OBS_TRACE")
    if path and _RING.snapshot():
        try:
            dump_chrome_trace(path)
        except OSError:
            pass  # exiting anyway; never mask the real exit


atexit.register(_atexit_dump)
