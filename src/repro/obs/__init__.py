# Runtime observability subsystem: structured tracing (Chrome-trace-event
# export, Perfetto-loadable) + named metrics (counters/gauges/histograms).
# Off by default — every probe is a no-op until REPRO_OBS=on or
# obs.set_enabled(True); see docs/observability.md.
from repro.obs.metrics import (  # noqa: F401
    DEFAULT_BUCKETS,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    Registry,
    counter,
    exp_buckets,
    gauge,
    histogram,
    snapshot,
)
from repro.obs.trace import (  # noqa: F401
    configure,
    current_stack,
    dropped_events,
    dump_chrome_trace,
    enabled,
    events,
    instant,
    now_us,
    set_enabled,
    span,
)
from repro.obs.validate import (  # noqa: F401
    TraceValidationError,
    validate_chrome_trace,
)

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def reset() -> None:
    """Clear the trace ring buffer AND zero the global metrics registry."""
    _trace.reset()
    _metrics.reset()
