"""Serving launcher: batched generation with the column-wise N:M engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --new-tokens 32 --sparsity 0.5
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.pruning import SparsityConfig
from repro.models import registry as reg
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    scfg = SparsityConfig(sparsity=args.sparsity, m=None, tile=None,
                          format="compressed_xla" if args.sparsity > 0 else "dense",
                          min_dim=64 if args.smoke else 512)
    cfg = (smoke_config(args.arch) if args.smoke else get_config(args.arch)).with_(
        sparsity=scfg)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          temperature=args.temperature))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    eng.generate(prompts)  # compile
    res = eng.generate(prompts)
    print(f"arch={cfg.name} sparse={args.sparsity} batch={args.batch}")
    print(f"prefill {res['prefill_s']*1e3:.1f} ms; decode {res['decode_tok_s']:.1f} tok/s")
    for i, row in enumerate(res["tokens"][:2]):
        print(f"  seq{i}: {row[:16].tolist()}")


if __name__ == "__main__":
    main()
