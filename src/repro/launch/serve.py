"""Serving launcher: batched generation with the column-wise N:M engine.

Static batch (pads every request to the slowest sequence):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --new-tokens 32 --sparsity 0.5

Continuous batching (slot-based in-flight admission over a synthetic
mixed-length request trace).  Bare ``--trace`` prints the admit/retire event
log; ``--trace out.json`` additionally turns on the observability layer and
writes a Chrome-trace-event file (dispatch decisions, scheduler iteration
spans, per-request TTFT/TPOT metrics) loadable in Perfetto:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --continuous --requests 12 --slots 4 --trace out.json

``--paged`` switches the continuous scheduler onto the paged-KV memory tier
(``repro.serve.kv_pages``): block-granular admission, packed padding-free
prefill, and page-occupancy gauges in the summary (and in the ``--trace``
metrics snapshot).  ``--page-size`` pins the page size; omitted, dispatch
races the registered page-size geometries for the serving shape:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --continuous --paged --page-size 8 --requests 12 --slots 4

Robustness knobs (``docs/robustness.md``): ``--deadline-s`` stamps every
trace request with a deadline, ``--faults SPEC`` installs the seeded
fault-injection plan (``repro.fault`` grammar, e.g.
``page_pool.alloc:n=2,scheduler.iter:iter=3``), ``--alloc grow`` switches the
paged tier to grow-on-demand allocation with preemption-restore, and SIGTERM
(or Ctrl-C) drains gracefully: admissions stop, in-flight requests finish,
queued ones flush as cancelled.  A scheduler-iteration watchdog
(``--watchdog-s``) aborts a wedged serve loop:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --continuous --paged --alloc grow --deadline-s 30 \
        --faults 'page_pool.alloc:p=0.05' --requests 12 --slots 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import fault as rfault
from repro import obs
from repro.configs import get_config, smoke_config
from repro.core.pruning import SparsityConfig
from repro.models import registry as reg
from repro.serve import (
    STATUSES,
    Engine,
    Scheduler,
    ServeConfig,
    latency_percentiles,
    synthetic_trace,
)
from repro.train.fault import PreemptionGuard, StepWatchdog


def build_engine(args) -> Engine:
    scfg = SparsityConfig(sparsity=args.sparsity, m=None, tile=None,
                          format="compressed_xla" if args.sparsity > 0 else "dense",
                          min_dim=64 if args.smoke else 512)
    cfg = (smoke_config(args.arch) if args.smoke else get_config(args.arch)).with_(
        sparsity=scfg)
    params, _ = reg.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                           temperature=args.temperature))


def run_static(args) -> None:
    eng = build_engine(args)
    cfg = eng.cfg
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    eng.generate(prompts)  # compile
    res = eng.generate(prompts)
    print(f"arch={cfg.name} sparse={args.sparsity} batch={args.batch}")
    print(f"prefill {res['prefill_s']*1e3:.1f} ms; decode {res['decode_tok_s']:.1f} tok/s")
    for i, row in enumerate(res["tokens"][:2]):
        print(f"  seq{i}: {row[:16].tolist()}")


def run_continuous(args) -> None:
    if args.requests < 1:
        raise SystemExit("--continuous needs --requests >= 1")
    eng = build_engine(args)
    cfg = eng.cfg
    trace = synthetic_trace(
        args.requests, seed=0, vocab=cfg.vocab_size,
        prompt_lens=(max(args.prompt_len // 4, 1), args.prompt_len),
        new_tokens=(max(args.new_tokens // 4, 1), args.new_tokens))
    if args.deadline_s is not None:
        for r in trace:
            r.deadline_s = args.deadline_s
    sched = Scheduler(eng, n_slots=args.slots, prefill_chunk=args.prefill_chunk,
                      paged=args.paged, page_size=args.page_size,
                      kv_budget_rows=args.kv_budget_rows, alloc=args.alloc)
    log = print if args.trace == "" else None
    # SIGTERM/SIGINT -> graceful drain (finish in-flight, flush the queue);
    # the watchdog aborts the process if no scheduler iteration completes
    # inside the window (wedged decode step / hung runtime)
    guard = PreemptionGuard().install()
    dog = StepWatchdog(timeout_s=args.watchdog_s).start()
    try:
        completions = sched.run(trace, log_fn=log,
                                should_drain=lambda: guard.requested,
                                heartbeat=dog.beat)
    finally:
        dog.stop()
        guard.uninstall()
    stats = sched.stats
    p50, p99 = latency_percentiles(completions)
    mode = f"paged(page_size={sched.page_size},alloc={args.alloc})" \
        if args.paged else "contiguous"
    print(f"arch={cfg.name} sparse={args.sparsity} continuous kv={mode} "
          f"slots={args.slots} requests={len(completions)}")
    by_status = " ".join(
        f"{s}={int(stats[f'retired_{s}'])}" for s in STATUSES
        if stats[f"retired_{s}"])
    print(f"status: {by_status or 'none'}; "
          f"preemptions {int(stats['preemptions'])}, "
          f"iter faults {int(stats['iter_faults'])}"
          + (" [drained]" if guard.requested else ""))
    print(f"decode {stats['decode_tok_s']:.1f} tok/s "
          f"({stats['generated_tokens']} tokens, "
          f"{stats['decode_steps']} steps); "
          f"latency p50 {p50*1e3:.1f} ms p99 {p99*1e3:.1f} ms")
    print(f"ttft p50 {stats['ttft_p50_s']*1e3:.1f} ms "
          f"p99 {stats['ttft_p99_s']*1e3:.1f} ms; "
          f"tpot p50 {stats['tpot_p50_s']*1e3:.2f} ms "
          f"p99 {stats['tpot_p99_s']*1e3:.2f} ms")
    if args.paged:
        ps = sched.page_stats
        print(f"pages peak {int(ps['pages_peak'])} "
              f"(hwm {int(ps['kv_rows_hwm'])} KV rows), "
              f"occupancy {int(ps['pages_active'])} active / "
              f"{int(ps['pages_free'])} free, "
              f"fragmentation {ps['page_fragmentation']:.2f}")
    for c in completions[:2]:
        print(f"  uid={c.uid}: {c.tokens[:16].tolist()}")


def _finish_trace(path: str) -> None:
    n = obs.dump_chrome_trace(path, metadata={"metrics": obs.snapshot()})
    print(f"trace: wrote {n} events to {path} (load in ui.perfetto.dev)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching over a synthetic "
                         "mixed-length request trace")
    ap.add_argument("--requests", type=int, default=12,
                    help="trace size for --continuous")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV slot count (decode batch width) for --continuous")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--paged", action="store_true",
                    help="page the KV cache (serve.kv_pages) and prefill "
                         "admitted prompts as one packed padding-free "
                         "stream; --continuous only")
    ap.add_argument("--page-size", type=int, default=None,
                    help="KV rows per page; default lets "
                         "dispatch.choose_page_size race the registered "
                         "page-size geometries for this serving shape")
    ap.add_argument("--kv-budget-rows", type=int, default=None,
                    help="total physical KV rows for the paged pool "
                         "(default: slots * max_len)")
    ap.add_argument("--alloc", choices=("reserve", "grow"), default="reserve",
                    help="paged allocation policy: reserve prompt+budget up "
                         "front, or grow on demand with preemption-restore "
                         "on exhaustion")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (seconds from submission) "
                         "stamped onto every trace request; expiry retires "
                         "with status=timeout")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="seeded fault-injection plan, repro.fault grammar "
                         "(e.g. 'page_pool.alloc:n=2,kernel.paged_attn:"
                         "iter=0')")
    ap.add_argument("--faults-seed", type=int, default=0,
                    help="seed for probabilistic (p=) fault rules")
    ap.add_argument("--watchdog-s", type=float, default=300.0,
                    help="scheduler-iteration watchdog: abort the process "
                         "if no iteration completes within this window")
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="bare: print per-request admit/retire events; "
                         "with PATH: enable the obs layer and write a "
                         "Perfetto-loadable Chrome trace to PATH")
    args = ap.parse_args()
    if args.paged and not args.continuous:
        raise SystemExit("--paged requires --continuous (the static engine "
                         "uses the contiguous per-batch cache)")
    if (args.alloc != "reserve" or args.deadline_s is not None) \
            and not args.continuous:
        raise SystemExit("--alloc/--deadline-s require --continuous")
    trace_path = args.trace if args.trace else None
    if trace_path:
        obs.set_enabled(True)
    if args.faults:
        rfault.install(args.faults, seed=args.faults_seed)
    try:
        if args.continuous:
            run_continuous(args)
        else:
            run_static(args)
    finally:
        if args.faults:
            print(f"faults: fired {dict(rfault.plan().fired)} "
                  f"of probes {dict(rfault.plan().probes)}")
            rfault.uninstall()
        if trace_path:
            _finish_trace(trace_path)


if __name__ == "__main__":
    main()
