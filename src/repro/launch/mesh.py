"""Production mesh factory.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialization — required because the dry-run pins the host
platform device count before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 (256 chips) per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_tp(mesh) -> int:
    return mesh.shape.get("model", 1)


def mesh_dp(mesh) -> int:
    return mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
