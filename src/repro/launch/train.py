"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 100 \
        --sparsity 0.5 --ckpt-dir /tmp/ckpt [--mesh host|single|multi] [--smoke]

On the host (default) this trains the reduced config for real; with
--mesh single/multi it installs the production mesh + shardings (on real TPU
hardware that is the deployment path; on this CPU container use
repro.launch.dryrun to validate compilation instead).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.core.pruning import SparsityConfig
from repro.data import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh, mesh_tp
from repro.optim import AdamWConfig
from repro.sharding import ShardingCtx, use_ctx
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--format", default="compressed_xla")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", choices=["host", "single", "multi"], default="host")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    args = ap.parse_args()

    scfg = SparsityConfig(sparsity=args.sparsity, m=None, tile=None,
                          format=args.format if args.sparsity > 0 else "dense",
                          min_dim=64 if args.smoke else 512)
    cfg = (smoke_config(args.arch) if args.smoke else get_config(args.arch))
    mesh = {"host": make_host_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    cfg = cfg.with_(sparsity=scfg, tp=mesh_tp(mesh))

    data = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                      seq_len=args.seq, seed=0)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, log_every=10,
                       microbatches=args.microbatches)
    ctx = ShardingCtx(mesh=mesh) if args.mesh != "host" else None
    with use_ctx(ctx), mesh:
        tr = Trainer(cfg, data, AdamWConfig(lr=args.lr), tcfg)
        out = tr.run()
    for h in out["history"]:
        print(f"step {h['step']:>6}  loss {h['loss']:.4f}  "
              f"gnorm {h.get('grad_norm', 0):.2f}  {h['sec_per_step']*1e3:.0f} ms")
    if out["preempted"]:
        print("preempted — final checkpoint written; restart to resume")


if __name__ == "__main__":
    main()
