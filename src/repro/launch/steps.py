"""Jitted step builders (train / prefill / decode) with full sharding specs —
shared by the real trainer, the serving engine, and the multi-pod dry-run."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import registry as reg
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import RULES, ShardingCtx, resolve_spec, use_ctx


def named(mesh, spec_names, shape):
    return NamedSharding(mesh, resolve_spec(shape, spec_names, RULES, mesh))


def tree_shardings(mesh, spec_tree, shape_tree):
    return jax.tree_util.tree_map(
        lambda s, a: named(mesh, s, a.shape),
        spec_tree,
        shape_tree,
        is_leaf=lambda s: isinstance(s, tuple),
    )


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation over `microbatches` along the batch dim (scan) —
    cuts activation memory for the big train cells.
    """
    lfn = reg.loss_fn(cfg)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True, allow_int=True)(
                params, batch
            )
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def acc_body(carry, mbatch):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(lfn, has_aux=True, allow_int=True)(
                    params, mbatch
                )
                g_acc = jax.tree_util.tree_map(
                    lambda a, b2: a + b2.astype(a.dtype)
                    if jnp.issubdtype(a.dtype, jnp.floating)
                    else a,
                    g_acc,
                    g,
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32)
                if jnp.issubdtype(p.dtype, jnp.floating)
                else p,
                params,
            )
            (grads, loss_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches if jnp.issubdtype(g.dtype, jnp.floating) else g,
                grads,
            )
            loss = loss_sum / microbatches
            metrics = {"nll": loss, "aux": jnp.zeros(())}
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return step


def train_shardings(cfg: ModelConfig, mesh: Mesh, param_shapes, param_specs, batch):
    """(in_shardings, out_shardings) for the train step."""
    p_sh = tree_shardings(mesh, param_specs, param_shapes)
    opt_shapes = jax.eval_shape(adamw_init, param_shapes)
    from repro.optim import opt_state_specs

    o_specs_full = opt_state_specs(param_specs)
    o_specs = {k: o_specs_full[k] for k in opt_shapes}
    o_sh = tree_shardings(mesh, o_specs, opt_shapes)
    b_specs = reg.batch_specs(cfg, batch)
    b_sh = tree_shardings(mesh, b_specs, batch)
    rep = NamedSharding(mesh, P())
    metrics_sh = None  # let XLA pick (scalars)
    return (p_sh, o_sh, b_sh), (p_sh, o_sh, metrics_sh)


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig):
    pf = reg.prefill_fn(cfg)

    def step(params, batch):
        logits, cache = pf(params, batch)
        return logits, cache

    return step


def make_decode_step(cfg: ModelConfig):
    df = reg.decode_fn(cfg)

    def step(params, cache, tokens, pos):
        return df(params, cache, tokens, pos)

    return step


def serve_shardings(cfg: ModelConfig, mesh: Mesh, param_shapes, param_specs, spec: Dict,
                    cache_auto: bool = True):
    p_sh = tree_shardings(mesh, param_specs, param_shapes)
    if spec["kind"] == "prefill":
        b_specs = reg.batch_specs(cfg, spec["batch"])
        b_sh = tree_shardings(mesh, b_specs, spec["batch"])
        return (p_sh, b_sh)
    if cache_auto:
        # leave the cache layout to GSPMD: forcing the logical spec made the
        # partitioner materialize a full f32 gather at the donated-output
        # boundary when its preferred internal sharding (partial-axis KV)
        # differed (EXPERIMENTS §Perf iteration K)
        c_sh = jax.tree_util.tree_map(lambda _: None, spec["cache"])
    else:
        cache_specs = reg.cache_specs(cfg, spec["cache"])
        c_sh = tree_shardings(mesh, cache_specs, spec["cache"])
    tok_sh = named(mesh, ("act_batch", None), spec["tokens"].shape)
    pos_sh = NamedSharding(mesh, P())
    return (p_sh, c_sh, tok_sh, pos_sh), c_sh
