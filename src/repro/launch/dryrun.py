import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import LONG_CONTEXT_ARCHS, SHAPES, get_config, list_archs  # noqa: E402
from repro.core.pruning import SparsityConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_dp, mesh_tp  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.models import registry as reg  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402
from repro.roofline.analysis import (  # noqa: E402
    Roofline,
    model_flops_for,
)
from repro.roofline.hlo_analyzer import analyze_hlo, xla_cost_analysis  # noqa: E402
from repro.sharding import RULES, ShardingCtx, use_ctx  # noqa: E402


def cell_skipped(arch: str, shape: str) -> str:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return "long_500k needs sub-quadratic attention; skipped for pure full-attention archs (DESIGN.md §6)"
    return ""


# per-cell microbatch counts for the big training cells (activation memory)
MICROBATCH = {
    ("qwen2-vl-72b", "train_4k"): 8,
    ("nemotron-4-15b", "train_4k"): 4,
    ("qwen2-7b", "train_4k"): 4,
    ("zamba2-7b", "train_4k"): 4,
    ("moonshot-v1-16b-a3b", "train_4k"): 2,
}


def build_cfg(arch: str, sparsity: float, fmt: str, mesh, attn: str = "naive",
              local_reduce: bool = False, remat_policy: str = "nothing",
              attn_chunk: int = 512, moe_impl: str = "auto") -> "ModelConfig":
    cfg = get_config(arch)
    scfg = SparsityConfig(
        sparsity=sparsity,
        m=None,               # adaptive M = full reduction dim (paper §3.1)
        tile=None,            # tile = d_out / tp (DESIGN §4)
        format=fmt if sparsity > 0 else "dense",
        min_dim=512,
        shard_local_reduce=local_reduce,
        reduce_groups=mesh_tp(mesh),
    )
    return cfg.with_(
        dtype="bfloat16",
        param_dtype="bfloat16",
        remat=True,
        tp=mesh_tp(mesh),
        dp=mesh_dp(mesh),
        sparsity=scfg,
        attn_impl=attn,
        remat_policy=remat_policy,
        attn_chunk=attn_chunk,
        moe_impl=moe_impl,
    )


def lower_cell(arch: str, shape: str, mesh, sparsity: float, fmt: str, attn: str = "naive",
               local_reduce: bool = False, remat_policy: str = "nothing",
               attn_chunk: int = 512, moe_impl: str = "auto"):
    """Lower + compile one (arch, shape) cell on the given mesh."""
    cfg = build_cfg(arch, sparsity, fmt, mesh, attn, local_reduce, remat_policy, attn_chunk, moe_impl)
    cell = SHAPES[shape]
    spec = reg.input_specs(cfg, cell)
    param_shapes, param_specs = reg.abstract_params(cfg)

    ctx = ShardingCtx(mesh=mesh)
    with use_ctx(ctx), mesh:
        if spec["kind"] == "train":
            mb = MICROBATCH.get((arch, shape), 1)
            step = steps_mod.make_train_step(cfg, AdamWConfig(), microbatches=mb)
            opt_shapes = jax.eval_shape(adamw_init, param_shapes)
            in_sh, out_sh = steps_mod.train_shardings(
                cfg, mesh, param_shapes, param_specs, spec["batch"]
            )
            lowered = jax.jit(
                step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(0, 1)
            ).lower(param_shapes, opt_shapes, spec["batch"])
        elif spec["kind"] == "prefill":
            step = steps_mod.make_prefill_step(cfg)
            in_sh = steps_mod.serve_shardings(cfg, mesh, param_shapes, param_specs, spec)
            lowered = jax.jit(step, in_shardings=in_sh).lower(param_shapes, spec["batch"])
        else:
            step = steps_mod.make_decode_step(cfg)
            # batch-1 long-context cells need the explicit seq-sharded cache
            # (distributed flash-decode); bigger batches do best with GSPMD's
            # own partial-axis KV layout (EXPERIMENTS §Perf iteration K)
            auto = spec["tokens"].shape[0] > 1
            in_sh, cache_sh = steps_mod.serve_shardings(
                cfg, mesh, param_shapes, param_specs, spec, cache_auto=auto
            )
            lowered = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(param_shapes, spec["cache"], spec["tokens"], spec["pos"])
        compiled = lowered.compile()
    return cfg, cell, lowered, compiled


def analyze(cfg, cell, lowered, compiled, mesh, sparsity: float):
    chips = mesh.devices.size
    cost = xla_cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception:
        mem_d = {}
    hlo = compiled.as_text()
    # loop-aware per-chip accounting (XLA's cost_analysis counts while bodies
    # once; the analyzer multiplies by known trip counts)
    acc = analyze_hlo(hlo)
    rl = Roofline(
        flops=acc["flops"],
        hlo_bytes=acc["bytes"],
        collective_bytes=acc["collective_bytes"],
        model_flops=model_flops_for(cfg, cell, sparsity),
        chips=chips,
    )
    return {
        "memory_analysis": mem_d,
        "cost_analysis_raw": {"flops": flops, "bytes_accessed": hbm_bytes},
        "collectives": {
            "counts": acc["collective_counts"],
            "bytes": acc["collective_by_kind"],
        },
        "roofline": rl.to_dict(),
        "hlo_size_chars": len(hlo),
    }


def run_cell(arch, shape, multi_pod, sparsity, fmt, out_dir: Path, tag="", attn="naive",
             local_reduce=False, remat_policy="nothing", attn_chunk=512, moe_impl="auto"):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    name = f"{arch}__{shape}__{mesh_name}__s{int(sparsity*100)}{tag}"
    out_path = out_dir / f"{name}.json"
    if out_path.exists():
        print(f"[skip-cached] {name}")
        return True
    skip = cell_skipped(arch, shape)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "sparsity": sparsity, "format": fmt if sparsity > 0 else "dense",
    }
    if skip:
        rec["skipped"] = skip
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[skipped] {name}: {skip}")
        return True
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cfg, cell, lowered, compiled = lower_cell(arch, shape, mesh, sparsity, fmt, attn, local_reduce, remat_policy, attn_chunk, moe_impl)
        rec.update(analyze(cfg, cell, lowered, compiled, mesh, sparsity))
        rec["compile_seconds"] = time.time() - t0
        out_path.write_text(json.dumps(rec, indent=1))
        rl = rec["roofline"]
        print(
            f"[ok] {name}: bottleneck={rl['bottleneck']} "
            f"tc={rl['t_compute_s']:.4f}s tm={rl['t_memory_s']:.4f}s "
            f"tcoll={rl['t_collective_s']:.4f}s frac={rl['roofline_fraction']:.3f} "
            f"({rec['compile_seconds']:.0f}s compile)"
        )
        return True
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["compile_seconds"] = time.time() - t0
        out_path.with_suffix(".err.json").write_text(json.dumps(rec, indent=1))
        print(f"[FAIL] {name}: {rec['error'][:300]}")
        return False


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--format", default="compressed_xla")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--attn", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--local-reduce", action="store_true")
    ap.add_argument("--remat-policy", default="nothing", choices=["nothing", "dots"])
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--moe", default="auto", choices=["auto", "shard_map"])
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                ok = run_cell(arch, shape, mp, args.sparsity, args.format, out_dir,
                              tag=args.tag, attn=args.attn, local_reduce=args.local_reduce,
                              remat_policy=args.remat_policy, attn_chunk=args.attn_chunk,
                              moe_impl=args.moe)
                n_fail += 0 if ok else 1
    print(f"done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
