"""Model facade: one API over all 10 architectures + ShapeDtypeStruct input
specs for every (arch × shape) dry-run cell.

``input_specs`` follows the assignment contract: weak-type-correct,
shardable stand-ins, no device allocation.  Modality frontends are stubs —
whisper receives precomputed frame embeddings, qwen2-vl receives precomputed
patch embeddings + M-RoPE position ids.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.core.sparse_linear import unbox_tree
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Init (+ logical specs without materializing params)
# ---------------------------------------------------------------------------


def init_fn(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return lambda key: encdec_mod.encdec_init(cfg, key)
    return lambda key: lm_mod.lm_init(cfg, key)


def init_params(cfg: ModelConfig, key):
    """Materialized (values, logical_specs)."""
    return unbox_tree(init_fn(cfg)(key))


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct tree, logical_specs) with zero allocation — used by
    the dry-run for 72B-scale configs."""
    holder = {}

    def f():
        vals, specs = unbox_tree(init_fn(cfg)(jax.random.PRNGKey(0)))
        holder["specs"] = specs
        return vals

    shapes = jax.eval_shape(f)
    return shapes, holder["specs"]


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return lambda params, batch: encdec_mod.encdec_loss(params, cfg, batch)
    return lambda params, batch: lm_mod.loss_fn(params, cfg, batch)


def forward_fn(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        def f(params, batch):
            enc = encdec_mod.encode(params, cfg, batch["enc_embeds"])
            return encdec_mod.decode_forward(params, cfg, batch["tokens"], enc)
        return f
    return lambda params, batch: lm_mod.lm_forward(params, cfg, batch)[0]


def prefill_fn(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return lambda params, batch: encdec_mod.encdec_prefill(
            params, cfg, batch["enc_embeds"], batch["tokens"]
        )
    return lambda params, batch: lm_mod.prefill(params, cfg, batch["tokens"])


def decode_fn(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return lambda params, cache, tokens, pos: encdec_mod.encdec_decode_step(
            params, cfg, cache, tokens, pos
        )
    return lambda params, cache, tokens, pos: lm_mod.decode_step(
        params, cfg, cache, tokens, pos
    )


def prefill_chunk_fn(cfg: ModelConfig):
    """Chunked prefill step (continuous batching): processes tokens [B, C] at
    absolute positions [start, start+C) into a preallocated cache.
    Attention-pattern decoder-only families only."""
    if cfg.is_encoder_decoder or cfg.block_pattern != "attn":
        raise NotImplementedError(
            f"chunked prefill requires a decoder-only attention family; "
            f"{cfg.name} has block_pattern={cfg.block_pattern!r}"
            + (" (encoder-decoder)" if cfg.is_encoder_decoder else ""))
    return lambda params, cache, tokens, start, with_logits=True: (
        lm_mod.prefill_chunk(params, cfg, cache, tokens, start, with_logits)
    )


def _require_paged_family(cfg: ModelConfig, what: str):
    if cfg.is_encoder_decoder or cfg.block_pattern != "attn":
        raise NotImplementedError(
            f"{what} requires a decoder-only attention family; "
            f"{cfg.name} has block_pattern={cfg.block_pattern!r}"
            + (" (encoder-decoder)" if cfg.is_encoder_decoder else ""))


def paged_decode_fn(cfg: ModelConfig, page_size: int):
    """Decode step against a paged KV cache (serve.kv_pages tier): tokens
    [B, 1], pos [B], tables [B, n_max]. Attention families only."""
    _require_paged_family(cfg, "paged decode")
    return lambda params, cache, tokens, pos, tables: lm_mod.paged_decode_step(
        params, cfg, cache, tokens, pos, tables, page_size
    )


def prefill_packed_fn(cfg: ModelConfig, page_size: int):
    """Packed padding-free prefill into a paged cache: one concatenated
    [T]-token stream with per-token slot ids/positions."""
    _require_paged_family(cfg, "packed prefill")
    return lambda params, cache, tokens, slot_ids, positions, tables, last_idx: (
        lm_mod.prefill_packed(params, cfg, cache, tokens, slot_ids, positions,
                              tables, last_idx, page_size)
    )


def paged_cache_init_fn(cfg: ModelConfig, n_pages: int, page_size: int):
    """Physical paged cache ([L, n_pages + 1, page_size, KV, D] per leaf;
    the +1 is the trash page)."""
    _require_paged_family(cfg, "paged cache")
    from repro.models import attention as attn_mod

    return lambda: attn_mod.paged_cache_init(
        cfg, n_pages, page_size, cfg.n_layers, jnp.dtype(cfg.dtype))


def cache_init_fn(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.is_encoder_decoder:
        return lambda: encdec_mod.encdec_cache_init(cfg, batch, max_len, cfg.encoder_seq)
    return lambda: lm_mod.cache_init(cfg, batch, max_len)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(cache_init_fn(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Logical specs for activations / batches / caches
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, batch: Dict[str, Any]):
    """Logical dim names per batch entry (matched to input_specs output)."""
    names = {
        "tokens": ("act_batch", None),
        "mrope_positions": ("act_batch", None, None),
        "vision_embeds": ("act_batch", None, None),
        "vision_pos": ("act_batch", None),
        "enc_embeds": ("act_batch", None, None),
    }
    return {k: names[k] for k in batch}


def cache_specs(cfg: ModelConfig, cache) -> Any:
    """Logical dim-name tree matching the cache structure."""

    def kv_spec(x):
        return (None, "act_batch", "act_kv_seq", "act_kv_heads", None)

    if cfg.is_encoder_decoder:
        return {k: kv_spec(None) for k in ("k", "v", "xk", "xv")}
    pat = cfg.block_pattern
    if pat == "attn":
        return {"k": kv_spec(None), "v": kv_spec(None)}
    if pat == "xlstm":
        return {
            "mlstm": {
                "C": (None, None, "act_batch", "act_heads", None, None),
                "n": (None, None, "act_batch", "act_heads", None),
                "m": (None, None, "act_batch", "act_heads"),
            },
            "slstm": {
                "c": (None, "act_batch", "act_heads", None),
                "n": (None, "act_batch", "act_heads", None),
                "h": (None, "act_batch", "act_heads", None),
                "m": (None, "act_batch", "act_heads", None),
            },
        }
    if pat == "mamba_shared_attn":
        spec = {
            "mamba": {
                "ssm": (None, None, "act_batch", "act_heads", None, None),
                "conv": (None, None, "act_batch", None, "act_ffn"),
            },
            "shared_kv": {"k": kv_spec(None), "v": kv_spec(None)},
        }
        if isinstance(cache, dict) and "mamba_tail" in cache:
            spec["mamba_tail"] = {
                "ssm": (None, "act_batch", "act_heads", None, None),
                "conv": (None, "act_batch", None, "act_ffn"),
            }
        return spec
    raise ValueError(pat)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins per (arch × shape)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """Returns {"kind", "batch" or ("cache","tokens","pos")} of SDS stand-ins."""
    b, s = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    def train_batch(seq):
        batch = {"tokens": SDS((b, seq), i32)}
        if cfg.family == "vlm":
            batch["mrope_positions"] = SDS((b, 3, seq), i32)
            batch["vision_embeds"] = SDS((b, cfg.vision_patches, cfg.d_model), dt)
            batch["vision_pos"] = SDS((b, cfg.vision_patches), i32)
        if cfg.is_encoder_decoder:
            batch["enc_embeds"] = SDS((b, seq, cfg.d_model), dt)
        return batch

    if cell.kind == "train":
        return {"kind": "train", "batch": train_batch(s)}

    if cell.kind == "prefill":
        if cfg.is_encoder_decoder:
            # the 32k lands on the audio/frame axis; decoder prompt is short
            return {
                "kind": "prefill",
                "batch": {
                    "enc_embeds": SDS((b, s, cfg.d_model), dt),
                    "tokens": SDS((b, 128), i32),
                },
            }
        batch = {"tokens": SDS((b, s), i32)}
        if cfg.family == "vlm":
            batch["mrope_positions"] = SDS((b, 3, s), i32)
        return {"kind": "prefill", "batch": batch}

    # decode: one new token vs a cache of length s
    cache = abstract_cache(cfg, b, s)
    return {
        "kind": "decode",
        "cache": cache,
        "tokens": SDS((b, 1), i32),
        "pos": SDS((), i32),
    }
