"""Mamba2 (SSD) block — chunked parallel scan for training/prefill, O(1)
recurrent state update for decode.  Used by zamba2-7b.

State-space: per head h with head-dim p and state-dim N,
  S_t = a_t * S_{t-1} + dt_t * x_t ⊗ B_t      (a_t = exp(dt_t * A_h), A_h < 0)
  y_t = C_t · S_t + D_h * x_t

The chunked form computes, per chunk of Q tokens, an intra-chunk quadratic
(attention-like) term plus the carried-state contribution, with the carry
updated once per chunk — sequential only over n_chunks (lax.scan).
All decays are exp of non-positive numbers => numerically stable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import Boxed, linear_apply, linear_init
from repro.models.common import norm_apply, norm_init
from repro.sharding import shd


def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di, nh, ns = mamba_dims(cfg)
    conv_ch = di + 2 * ns
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.param_dtype)
    d_in_proj = 2 * di + 2 * ns + nh  # z, x, B, C, dt
    p = {
        "in_proj": linear_init(ks[0], d, d_in_proj, cfg.sparsity, dtype=dtype,
                               in_ax="embed", out_ax="ffn"),
        "out_proj": linear_init(ks[1], di, d, cfg.sparsity, dtype=dtype,
                                in_ax="ffn", out_ax="embed", mode="reduce"),
        "conv_w": Boxed(
            jax.random.normal(ks[2], (cfg.d_conv, conv_ch), dtype) * 0.1,
            (None, "ffn"),
        ),
        "conv_b": Boxed(jnp.zeros((conv_ch,), dtype), ("ffn",)),
        "A_log": Boxed(jnp.log(jnp.linspace(1.0, 16.0, nh)), (None,)),
        "D": Boxed(jnp.ones((nh,)), (None,)),
        "dt_bias": Boxed(jnp.zeros((nh,)), (None,)),
        "norm": norm_init(di, "rmsnorm", dtype),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. x [B,S,C]; w [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, nh, ns = mamba_dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * ns]
    dt = zxbcdt[..., 2 * di + 2 * ns :]
    return z, xbc, dt


def mamba_apply(params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    """Training / prefill forward. hidden [B, S, d_model]."""
    b, s, _ = hidden.shape
    di, nh, ns = mamba_dims(cfg)
    p = cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    while s % q != 0:
        q -= 1
    nc = s // q

    zxbcdt = linear_apply(params["in_proj"], hidden)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(jax.nn.silu(xbc), params["conv_w"], params["conv_b"])
    x = xbc[..., :di].reshape(b, s, nh, p)
    bm = xbc[..., di : di + ns]  # [B,S,N]
    cm = xbc[..., di + ns :]  # [B,S,N]

    a_neg = -jnp.exp(params["A_log"].astype(jnp.float32))  # [H] < 0
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    log_a = dt * a_neg[None, None, :]  # [B,S,H] <= 0

    # chunked shapes
    xc = x.reshape(b, nc, q, nh, p).astype(jnp.float32)
    bc = bm.reshape(b, nc, q, ns).astype(jnp.float32)
    cc = cm.reshape(b, nc, q, ns).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, nh)
    lac = log_a.reshape(b, nc, q, nh)

    def chunk_step(state, inputs):
        xq, bq, cq, dtq, laq = inputs  # [B,Q,...]
        g = jnp.cumsum(laq, axis=1)  # [B,Q,H] cumulative log-decay
        # carried-state contribution: y_state[i] = exp(g_i) * C_i . S
        y_state = jnp.einsum("bqn,bhpn->bqhp", cq, state) * jnp.exp(g)[..., None]
        # intra-chunk: L[i,j] = exp(g_i - g_j) for j<=i
        gi = g[:, :, None, :]  # [B,Q,1,H]
        gj = g[:, None, :, :]  # [B,1,Q,H]
        mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, :, :, None]
        # mask the exponent (not the exp) — exp of a masked-out large positive
        # delta would overflow and poison the backward pass with inf*0=NaN
        L = jnp.exp(jnp.where(mask, gi - gj, -1e30))  # [B,Q,Q,H]
        scores = jnp.einsum("bin,bjn->bij", cq, bq)  # [B,Q,Q]
        G = scores[..., None] * L * dtq[:, None, :, :]  # weight on x_j
        y_intra = jnp.einsum("bijh,bjhp->bihp", G, xq)
        # carry update
        decay_chunk = jnp.exp(g[:, -1:, :] - g)  # exp(g_Q - g_j) [B,Q,H]
        s_new = jnp.exp(g[:, -1, :])[:, :, None, None] * state + jnp.einsum(
            "bjh,bjhp,bjn->bhpn", decay_chunk * dtq, xq, bq
        )
        return s_new, y_state + y_intra

    s0 = jnp.zeros((b, nh, p, ns), jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, bc, cc, dtc, lac))
    _, ys = jax.lax.scan(chunk_step, s0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, p)  # [B,S,H,p]
    y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(hidden.dtype)
    y = norm_apply(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    return linear_apply(params["out_proj"], y)


# ---------------------------------------------------------------------------
# Decode (single-token recurrence)
# ---------------------------------------------------------------------------


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, nh, ns = mamba_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, ns), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di + 2 * ns), dtype),
    }


def mamba_decode(params, cfg: ModelConfig, hidden: jax.Array, cache):
    """hidden [B, 1, d_model] -> (out [B,1,d], new_cache)."""
    b = hidden.shape[0]
    di, nh, ns = mamba_dims(cfg)
    p = cfg.ssm_head_dim

    zxbcdt = linear_apply(params["in_proj"], hidden)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(xbc)  # [B,1,C]
    conv_hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"]  # [K, C]
    xbc_c = jnp.einsum("bkc,kc->bc", conv_hist.astype(jnp.float32), w.astype(jnp.float32))
    xbc_c = (xbc_c + params["conv_b"].astype(jnp.float32))[:, None, :]
    new_conv = conv_hist[:, 1:, :]

    x = xbc_c[..., :di].reshape(b, nh, p).astype(jnp.float32)
    bm = xbc_c[..., 0, di : di + ns].astype(jnp.float32)  # [B,N]
    cm = xbc_c[..., 0, di + ns :].astype(jnp.float32)
    a_neg = -jnp.exp(params["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = jnp.exp(dtv * a_neg[None, :])  # [B,H]

    s_new = a[:, :, None, None] * cache["ssm"] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, x, bm
    )
    y = jnp.einsum("bn,bhpn->bhp", cm, s_new) + params["D"][None, :, None] * x
    y = y.reshape(b, 1, di).astype(hidden.dtype)
    y = norm_apply(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    return linear_apply(params["out_proj"], y), {"ssm": s_new, "conv": new_conv}
