"""xLSTM blocks: mLSTM (matrix memory, exponential gating) in a chunked
parallel form, and sLSTM (scalar memory, recurrent mixing) as a time scan.

mLSTM recurrence (per head, head dim p):
  m_t = max(lf_t + m_{t-1}, i_t)                       (log-scale stabilizer)
  C_t = exp(lf_t + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) v_t k_t^T
  n_t = exp(lf_t + m_{t-1} - m_t) n_{t-1} + exp(i_t - m_t) k_t
  y_t = C_t q_t / max(|n_t . q_t|, exp(-m_t))

The chunked form evaluates the intra-chunk part as a masked attention-like
quadratic with log-domain weights D[i,j] = g_i - g_j + i_j (g = cumsum of
log-forget), carried state handled with its own log-scale, sequential only
over chunks.  Decode is the plain one-step recurrence.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import Boxed, linear_apply, linear_init
from repro.models.common import norm_apply, norm_init
from repro.sharding import shd

NEG = -1e30


def xlstm_dims(cfg: ModelConfig):
    d_inner = cfg.expand * cfg.d_model
    n_heads = cfg.padded_heads
    return d_inner, n_heads, d_inner // n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di, nh, p = xlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    dtype = jnp.dtype(cfg.param_dtype)
    scfg = cfg.sparsity
    return {
        "up": linear_init(ks[0], d, 2 * di, scfg, dtype=dtype, in_ax="embed", out_ax="ffn"),
        "q": linear_init(ks[1], di, di, scfg, dtype=dtype, in_ax="ffn", out_ax="heads_flat"),
        "k": linear_init(ks[2], di, di, scfg, dtype=dtype, in_ax="ffn", out_ax="heads_flat"),
        "v": linear_init(ks[3], di, di, scfg, dtype=dtype, in_ax="ffn", out_ax="heads_flat"),
        "gates": Boxed(jax.random.normal(ks[4], (di, 2 * nh), dtype) * 0.01, ("ffn", None)),
        "gates_b": Boxed(jnp.concatenate([jnp.ones((nh,)) * 3.0, jnp.zeros((nh,))]), (None,)),
        "norm": norm_init(di, "rmsnorm", dtype),
        "down": linear_init(ks[5], di, d, scfg, dtype=dtype, in_ax="ffn", out_ax="embed",
                            mode="reduce"),
    }


def _mlstm_qkvg(params, cfg: ModelConfig, hidden):
    b, s, _ = hidden.shape
    di, nh, p = xlstm_dims(cfg)
    up = linear_apply(params["up"], hidden)
    xi, z = up[..., :di], up[..., di:]
    q = linear_apply(params["q"], xi).reshape(b, s, nh, p)
    k = linear_apply(params["k"], xi).reshape(b, s, nh, p) / math.sqrt(p)
    v = linear_apply(params["v"], xi).reshape(b, s, nh, p)
    gates = xi @ params["gates"] + params["gates_b"]  # [B,S,2H]
    lf = jax.nn.log_sigmoid(gates[..., :nh].astype(jnp.float32))  # log forget
    ig = gates[..., nh:].astype(jnp.float32)  # input gate (log-domain)
    return q, k, v, lf, ig, z


def mlstm_apply(params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    b, s, _ = hidden.shape
    di, nh, p = xlstm_dims(cfg)
    qq = min(cfg.ssm_chunk, s)
    while s % qq != 0:
        qq -= 1
    nc = s // qq

    q, k, v, lf, ig, z = _mlstm_qkvg(params, cfg, hidden)
    f32 = jnp.float32
    qc = q.reshape(b, nc, qq, nh, p).astype(f32)
    kc = k.reshape(b, nc, qq, nh, p).astype(f32)
    vc = v.reshape(b, nc, qq, nh, p).astype(f32)
    lfc = lf.reshape(b, nc, qq, nh)
    igc = ig.reshape(b, nc, qq, nh)

    def chunk_step(carry, inputs):
        C, n, m = carry  # [B,H,p,p], [B,H,p], [B,H]
        qx, kx, vx, lfx, igx = inputs
        g = jnp.cumsum(lfx, axis=1)  # [B,Q,H]
        # log-weights
        d_intra = g[:, :, None, :] - g[:, None, :, :] + igx[:, None, :, :]  # [B,i,j,H]
        mask = (jnp.arange(qq)[:, None] >= jnp.arange(qq)[None, :])[None, :, :, None]
        d_intra = jnp.where(mask, d_intra, NEG)
        d_state = g + m[:, None, :]  # [B,Q,H]
        m_i = jnp.maximum(d_intra.max(axis=2), d_state)  # [B,Q,H]
        m_i = jnp.maximum(m_i, -m_i * 0)  # clamp at 0 => denominators sane
        w_intra = jnp.exp(d_intra - m_i[:, :, None, :])  # [B,i,j,H]
        w_state = jnp.exp(d_state - m_i)  # [B,Q,H]
        scores = jnp.einsum("bihp,bjhp->bijh", qx, kx)  # [B,i,j,H]
        num = jnp.einsum("bijh,bijh,bjhp->bihp", scores, w_intra, vx)
        # C stored as v⊗k ([b,h,p=v-dim,r=k-dim]): q contracts the KEY dim r
        num = num + w_state[..., None] * jnp.einsum("bhpr,bihr->bihp", C, qx)
        den = jnp.einsum("bijh,bijh->bih", scores, w_intra)
        den = den + w_state * jnp.einsum("bhp,bihp->bih", n, qx)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # carry update
        g_last = g[:, -1, :]  # [B,H]
        m_new = jnp.maximum(g_last + m, (g_last[:, None, :] - g + igx).max(axis=1))
        decay_c = jnp.exp(g_last + m - m_new)  # [B,H]
        w_new = jnp.exp(g_last[:, None, :] - g + igx - m_new[:, None, :])  # [B,Q,H]
        C_new = decay_c[:, :, None, None] * C + jnp.einsum("bjh,bjhp,bjhr->bhpr", w_new, vx, kx)
        n_new = decay_c[:, :, None] * n + jnp.einsum("bjh,bjhp->bhp", w_new, kx)
        return (C_new, n_new, m_new), y

    carry0 = (
        jnp.zeros((b, nh, p, p), f32),
        jnp.zeros((b, nh, p), f32),
        jnp.full((b, nh), 0.0, f32),
    )
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, lfc, igc))
    _, ys = jax.lax.scan(chunk_step, carry0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di).astype(hidden.dtype)
    y = norm_apply(params["norm"], y, "rmsnorm") * jax.nn.silu(z)
    return linear_apply(params["down"], y)


def mlstm_cache_init(cfg: ModelConfig, batch: int):
    di, nh, p = xlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, p, p), jnp.float32),
        "n": jnp.zeros((batch, nh, p), jnp.float32),
        "m": jnp.zeros((batch, nh), jnp.float32),
    }


def mlstm_decode(params, cfg: ModelConfig, hidden: jax.Array, cache):
    b = hidden.shape[0]
    di, nh, p = xlstm_dims(cfg)
    q, k, v, lf, ig, z = _mlstm_qkvg(params, cfg, hidden)
    f32 = jnp.float32
    qx, kx, vx = (t[:, 0].astype(f32) for t in (q, k, v))  # [B,H,p]
    lfx, igx = lf[:, 0], ig[:, 0]  # [B,H]
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lfx + m, igx)
    fdec = jnp.exp(lfx + m - m_new)
    iw = jnp.exp(igx - m_new)
    C_new = fdec[:, :, None, None] * C + iw[:, :, None, None] * jnp.einsum(
        "bhp,bhr->bhpr", vx, kx
    )
    n_new = fdec[:, :, None] * n + iw[:, :, None] * kx
    num = jnp.einsum("bhpr,bhr->bhp", C_new, qx)
    den = jnp.einsum("bhp,bhp->bh", n_new, qx)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    y = y.reshape(b, 1, di).astype(hidden.dtype)
    y = norm_apply(params["norm"], y, "rmsnorm") * jax.nn.silu(z)
    return linear_apply(params["down"], y), {"C": C_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di, nh, p = xlstm_dims(cfg)
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    scfg = cfg.sparsity
    return {
        "w": linear_init(ks[0], d, 4 * di, scfg, dtype=dtype, in_ax="embed", out_ax="ffn"),
        # recurrent mixing is block-diagonal per head: [H, p, 4p]
        "r": Boxed(jax.random.normal(ks[1], (nh, p, 4 * p), dtype) * 0.05, ("heads", None, None)),
        "b": Boxed(jnp.concatenate(
            [jnp.zeros((di,)), jnp.ones((di,)) * 3.0, jnp.zeros((2 * di,))]
        ), (None,)),
        "norm": norm_init(di, "rmsnorm", dtype),
        "down": linear_init(ks[2], di, d, scfg, dtype=dtype, in_ax="ffn", out_ax="embed",
                            mode="reduce"),
    }


def _slstm_cell(params, cfg, wx_t, state):
    """One sLSTM step. wx_t: [B, 4di]; state: (c, n, h, m) with [B,H,p]."""
    di, nh, p = xlstm_dims(cfg)
    c, n, h, m = state
    rh = jnp.einsum("bhp,hpq->bhq", h, params["r"].astype(jnp.float32))  # [B,H,4p]
    pre = wx_t.reshape(-1, nh, 4 * p).astype(jnp.float32) + rh + params["b"].reshape(
        nh, 4 * p
    ).astype(jnp.float32)
    i_g, f_g, z_g, o_g = jnp.split(pre, 4, axis=-1)  # [B,H,p] each
    lf = jax.nn.log_sigmoid(f_g)
    m_new = jnp.maximum(lf + m, i_g)
    i_t = jnp.exp(i_g - m_new)
    f_t = jnp.exp(lf + m - m_new)
    c_new = f_t * c + i_t * jnp.tanh(z_g)
    n_new = f_t * n + i_t
    h_new = jax.nn.sigmoid(o_g) * c_new / jnp.maximum(n_new, 1.0)
    return c_new, n_new, h_new, m_new


def slstm_apply(params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    b, s, _ = hidden.shape
    di, nh, p = xlstm_dims(cfg)
    wx = linear_apply(params["w"], hidden)  # [B,S,4di]

    def step(state, wx_t):
        new = _slstm_cell(params, cfg, wx_t, state)
        return new, new[2]

    z0 = jnp.zeros((b, nh, p), jnp.float32)
    state0 = (z0, z0, z0, jnp.zeros((b, nh, p), jnp.float32))
    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, di).astype(hidden.dtype)
    y = norm_apply(params["norm"], y, "rmsnorm")
    return linear_apply(params["down"], y)


def slstm_cache_init(cfg: ModelConfig, batch: int):
    di, nh, p = xlstm_dims(cfg)
    z = jnp.zeros((batch, nh, p), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_decode(params, cfg: ModelConfig, hidden: jax.Array, cache):
    wx = linear_apply(params["w"], hidden)[:, 0]  # [B,4di]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_cell(params, cfg, wx, state)
    di, nh, p = xlstm_dims(cfg)
    y = h.reshape(-1, 1, di).astype(hidden.dtype)
    y = norm_apply(params["norm"], y, "rmsnorm")
    return linear_apply(params["down"], y), {"c": c, "n": n, "h": h, "m": m}
