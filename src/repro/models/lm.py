"""Top-level language models for every family in the zoo.

A single init/apply pair covers:
  - dense / MoE / VLM transformers ("attn" pattern): scan over stacked blocks
  - xLSTM ("xlstm" pattern): scan over superblocks of (slstm_every-1) mLSTM
    blocks followed by one sLSTM block
  - Zamba2 hybrid ("mamba_shared_attn"): scan over superblocks of
    shared_attn_every Mamba2 blocks followed by one application of the
    *shared* attention block (one set of weights, 'layers//every' KV caches)

Training entry point: ``loss_fn``; serving entry points: ``prefill`` and
``decode_step`` (single new token against a KV/state cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import Boxed, box_map, unbox_tree
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.blocks import (
    block_apply,
    block_decode,
    block_init,
    block_paged_decode,
    block_prefill_chunk,
    block_prefill_packed,
    shared_block_apply,
    shared_block_decode,
    shared_block_init,
    stack_init,
)
from repro.models.common import embed_init, embed_lookup, norm_apply, norm_init
from repro.sharding import shd


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def lm_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.param_dtype)
    p: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = Boxed(
            jax.random.normal(ks[1], (cfg.d_model, cfg.padded_vocab), dtype) * 0.02,
            ("embed", "vocab"),
        )
    pat = cfg.block_pattern
    if pat == "attn":
        p["layers"] = stack_init(lambda k: block_init(k, cfg), ks[2], cfg.n_layers)
    elif pat == "xlstm":
        every = cfg.slstm_every
        assert cfg.n_layers % every == 0, "xlstm: n_layers % slstm_every == 0"
        n_super = cfg.n_layers // every
        p["mlstm"] = stack_init(
            lambda k: stack_init(lambda k2: xlstm_mod.mlstm_init(k2, cfg), k, every - 1),
            ks[2],
            n_super,
        )
        p["slstm"] = stack_init(lambda k: xlstm_mod.slstm_init(k, cfg), ks[3], n_super)
    elif pat == "mamba_shared_attn":
        every = cfg.shared_attn_every
        n_super = cfg.n_layers // every
        rem = cfg.n_layers - n_super * every
        p["mamba"] = stack_init(
            lambda k: stack_init(lambda k2: ssm_mod.mamba_init(k2, cfg), k, every),
            ks[2],
            n_super,
        )
        if rem:
            p["mamba_tail"] = stack_init(lambda k: ssm_mod.mamba_init(k, cfg), ks[4], rem)
        p["shared"] = shared_block_init(ks[3], cfg)
    else:
        raise ValueError(f"unknown block_pattern {pat}")
    return p


def n_shared_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ModelConfig, batch) -> jax.Array:
    h = embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and "vision_embeds" in batch:
        b = h.shape[0]
        ve = batch["vision_embeds"].astype(h.dtype)
        h = h.at[jnp.arange(b)[:, None], batch["vision_pos"]].set(ve)
    return shd(h, "act_batch", "act_seq_sp", None)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        return jax.checkpoint(fn, policy=policy)
    return fn


def lm_forward(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V_padded], aux_loss)."""
    h = _embed_tokens(params, cfg, batch)
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    mrope_positions = batch.get("mrope_positions") if cfg.mrope else None
    pat = cfg.block_pattern
    aux = jnp.zeros((), jnp.float32)

    if pat == "attn":
        def body(carry, layer_params):
            hh, = carry
            hh, a = block_apply(layer_params, cfg, hh, positions=positions,
                                mrope_positions=mrope_positions)
            return (hh,), a

        (h,), auxs = jax.lax.scan(_maybe_remat(body, cfg), (h,), params["layers"])
        aux = auxs.mean()
    elif pat == "xlstm":
        def super_body(carry, sp):
            hh, = carry
            mp, sp_params = sp

            def inner(c2, lp):
                (h2,) = c2
                h2 = h2 + xlstm_mod.mlstm_apply(lp, cfg, h2)
                h2 = shd(h2, "act_batch", "act_seq_sp", None)
                return (h2,), jnp.zeros(())

            (hh,), _ = jax.lax.scan(inner, (hh,), mp)
            hh = hh + xlstm_mod.slstm_apply(sp_params, cfg, hh)
            hh = shd(hh, "act_batch", "act_seq_sp", None)
            return (hh,), jnp.zeros(())

        (h,), _ = jax.lax.scan(
            _maybe_remat(super_body, cfg), (h,), (params["mlstm"], params["slstm"])
        )
    elif pat == "mamba_shared_attn":
        h0 = h

        def super_body(carry, mp):
            hh, = carry

            def inner(c2, lp):
                (h2,) = c2
                h2 = h2 + ssm_mod.mamba_apply(lp, cfg, h2)
                h2 = shd(h2, "act_batch", "act_seq_sp", None)
                return (h2,), jnp.zeros(())

            (hh,), _ = jax.lax.scan(inner, (hh,), mp)
            hh = shared_block_apply(params["shared"], cfg, hh, h0, positions=positions)
            hh = shd(hh, "act_batch", "act_seq_sp", None)
            return (hh,), jnp.zeros(())

        (h,), _ = jax.lax.scan(_maybe_remat(super_body, cfg), (h,), params["mamba"])
        if "mamba_tail" in params:
            def tail(c2, lp):
                (h2,) = c2
                h2 = h2 + ssm_mod.mamba_apply(lp, cfg, h2)
                return (h2,), jnp.zeros(())

            (h,), _ = jax.lax.scan(_maybe_remat(tail, cfg), (h,), params["mamba_tail"])
    else:
        raise ValueError(pat)

    h = norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)
    return logits, aux


def _unembed(params, cfg: ModelConfig, h) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    else:
        logits = h @ params["unembed"].astype(h.dtype)
    return shd(logits, "act_batch", None, "act_vocab")


def loss_fn(params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux = lm_forward(params, cfg, batch)
    logits = logits[:, :-1].astype(jnp.float32)
    labels = batch["tokens"][:, 1:]
    # padded vocab ids can never appear as labels; mask them out of the
    # softmax so padding does not leak probability mass
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def cache_init(cfg: ModelConfig, batch: int, max_len: int):
    """Family-specific decode cache (all leaves are jnp arrays)."""
    dtype = jnp.dtype(cfg.dtype)
    pat = cfg.block_pattern
    if pat == "attn":
        return attn_mod.cache_init(cfg, batch, max_len, cfg.n_layers, dtype)
    if pat == "xlstm":
        every = cfg.slstm_every
        n_super = cfg.n_layers // every

        def stack(fn, n):
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n,) + x.shape), fn
            )

        m1 = xlstm_mod.mlstm_cache_init(cfg, batch)
        s1 = xlstm_mod.slstm_cache_init(cfg, batch)
        return {
            "mlstm": jax.tree_util.tree_map(
                lambda x: jnp.zeros((n_super, every - 1) + x.shape, x.dtype), m1
            ),
            "slstm": jax.tree_util.tree_map(
                lambda x: jnp.zeros((n_super,) + x.shape, x.dtype), s1
            ),
        }
    if pat == "mamba_shared_attn":
        every = cfg.shared_attn_every
        n_super = cfg.n_layers // every
        rem = cfg.n_layers - n_super * every
        m1 = ssm_mod.mamba_cache_init(cfg, batch, dtype)
        out = {
            "mamba": jax.tree_util.tree_map(
                lambda x: jnp.zeros((n_super, every) + x.shape, x.dtype), m1
            ),
            "shared_kv": attn_mod.cache_init(cfg, batch, max_len, n_super, dtype),
        }
        if rem:
            out["mamba_tail"] = jax.tree_util.tree_map(
                lambda x: jnp.zeros((rem,) + x.shape, x.dtype), m1
            )
        return out
    raise ValueError(pat)


def decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array, pos: jax.Array):
    """One decode step. tokens [B,1]; pos scalar int32 (current length) or a
    per-sequence [B] int32 vector (continuous batching: slots at mixed
    lengths decode in one step).

    Returns (logits [B,1,V], new_cache).
    """
    h = embed_lookup(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    b = tokens.shape[0]
    pat = cfg.block_pattern
    mrope_positions = None
    if cfg.mrope:
        pos_b = jnp.broadcast_to(jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
        mrope_positions = jnp.broadcast_to(pos_b.reshape(b, 1, 1), (b, 3, 1))

    if pat == "attn":
        def body(carry, xs):
            hh, = carry
            lp, kc, vc = xs
            hh, (kn, vn) = block_decode(lp, cfg, hh, (kc, vc), pos=pos,
                                        mrope_positions=mrope_positions)
            return (hh,), (kn, vn)

        (h,), (k_news, v_news) = jax.lax.scan(
            body, (h,), (params["layers"], cache["k"], cache["v"])
        )
        k2, v2 = attn_mod.cache_write(cache["k"], cache["v"], k_news, v_news, pos)
        new_cache = {"k": k2, "v": v2}
    elif pat == "xlstm":
        def super_body(carry, xs):
            hh, = carry
            mp, sp_params, mcache, scache = xs

            def inner(c2, xs2):
                (h2,) = c2
                lp, lc = xs2
                dh, nc = xlstm_mod.mlstm_decode(lp, cfg, h2, lc)
                return (h2 + dh,), nc

            (hh,), m_new = jax.lax.scan(inner, (hh,), (mp, mcache))
            dh, s_new = xlstm_mod.slstm_decode(sp_params, cfg, hh, scache)
            return (hh + dh,), (m_new, s_new)

        (h,), (m_new, s_new) = jax.lax.scan(
            super_body, (h,),
            (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"]),
        )
        new_cache = {"mlstm": m_new, "slstm": s_new}
    elif pat == "mamba_shared_attn":
        h0 = h

        def super_body(carry, xs):
            hh, = carry
            mp, mcache, kc, vc = xs

            def inner(c2, xs2):
                (h2,) = c2
                lp, lc = xs2
                dh, nc = ssm_mod.mamba_decode(lp, cfg, h2, lc)
                return (h2 + dh,), nc

            (hh,), m_new = jax.lax.scan(inner, (hh,), (mp, mcache))
            hh, (kn, vn) = shared_block_decode(
                params["shared"], cfg, hh, h0, (kc, vc), pos=pos
            )
            return (hh,), (m_new, kn, vn)

        (h,), (m_new, k_news, v_news) = jax.lax.scan(
            super_body, (h,),
            (params["mamba"], cache["mamba"], cache["shared_kv"]["k"],
             cache["shared_kv"]["v"]),
        )
        k2, v2 = attn_mod.cache_write(cache["shared_kv"]["k"], cache["shared_kv"]["v"],
                                      k_news, v_news, pos)
        new_cache = {"mamba": m_new, "shared_kv": {"k": k2, "v": v2}}
        if "mamba_tail" in params:
            def tail(c2, xs2):
                (h2,) = c2
                lp, lc = xs2
                dh, nc = ssm_mod.mamba_decode(lp, cfg, h2, lc)
                return (h2 + dh,), nc

            (h,), t_new = jax.lax.scan(tail, (h,), (params["mamba_tail"], cache["mamba_tail"]))
            new_cache["mamba_tail"] = t_new
    else:
        raise ValueError(pat)

    h = norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, tokens: jax.Array):
    """Process a prompt, returning (last-token logits, populated cache).

    For attention archs the per-layer K/V come out of the scan as ys; for
    recurrent archs prefill is decode run over the prompt — for the dry-run
    shapes we instead run the chunked parallel forward and only materialize
    the final state, which is what a production prefill would do.
    """
    b, s = tokens.shape
    batch = {"tokens": tokens}
    if cfg.mrope:
        pos3 = jnp.broadcast_to(jnp.arange(s)[None, None, :], (b, 3, s))
        batch["mrope_positions"] = pos3
    pat = cfg.block_pattern
    h = _embed_tokens(params, cfg, batch)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    if pat == "attn":
        def body(carry, lp):
            hh, = carry
            x = norm_apply(lp["ln1"], hh, cfg.norm)
            q, k, v = attn_mod._qkv(lp["attn"], cfg, x,
                                    positions, batch.get("mrope_positions"))
            if cfg.attn_impl == "chunked" and s > cfg.attn_chunk:
                o = attn_mod.sdpa_gqa_chunked(q, k, v, causal=True,
                                              chunk=cfg.attn_chunk)
            else:
                o = attn_mod.sdpa_gqa(q, k, v, causal=True)
            from repro.core.sparse_linear import linear_apply as _la

            hh = hh + _la(lp["attn"]["o"], o.reshape(b, s, -1))
            x = norm_apply(lp["ln2"], hh, cfg.norm)
            if cfg.is_moe:
                if cfg.moe_impl == "shard_map":
                    from repro.models.moe import moe_apply_shard_map as _moe
                else:
                    from repro.models.moe import moe_apply as _moe

                y, _ = _moe(lp["moe"], cfg, x)
            else:
                from repro.models.mlp import mlp_apply

                y = mlp_apply(lp["mlp"], cfg, x)
            hh = hh + y
            hh = shd(hh, "act_batch", "act_seq_sp", None)
            return (hh,), (k, v)

        (h,), (ks, vs) = jax.lax.scan(_maybe_remat(body, cfg), (h,), params["layers"])
        cache = {"k": ks, "v": vs}  # [L, B, S, KV, D]
    else:
        # recurrent/hybrid prefill: run the parallel forward; dry-run cells
        # exercise decode_step for state-cache serving.
        logits, _ = lm_forward(params, cfg, batch)
        return logits[:, -1:], None

    h = norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)
    return logits[:, -1:], cache


def prefill_chunk(params, cfg: ModelConfig, cache, tokens: jax.Array,
                  start: jax.Array, with_logits: bool = True):
    """Prefill one chunk of a prompt into a preallocated cache.

    tokens [B, C] sit at absolute positions [start, start+C); ``cache`` is a
    full-size decode cache ([L, B, S_max, KV, D] per leaf) whose rows < start
    already hold this sequence's earlier chunks.  Returns
    (logits [B, C, V], cache with rows start..start+C written);
    ``with_logits=False`` skips the final-norm + unembed (the vocab-sized
    matmul) and returns (None, cache) — only the chunk containing the last
    prompt token needs logits.

    This is the unit of work the continuous-batching scheduler interleaves
    with decode steps: a long prompt is admitted as ceil(S/C) fixed-shape
    chunk calls (one compiled executable) instead of one [B, S]-shaped
    prefill per distinct prompt length.  Attention-cache families only —
    recurrent/hybrid state caches have no random-access rows to chunk into.
    """
    if cfg.block_pattern != "attn":
        raise NotImplementedError(
            f"prefill_chunk supports attention families only, not "
            f"block_pattern={cfg.block_pattern!r}")
    b, c_len = tokens.shape
    batch = {"tokens": tokens}
    mrope_positions = None
    if cfg.mrope:
        pos1 = start + jnp.arange(c_len, dtype=jnp.int32)
        mrope_positions = jnp.broadcast_to(pos1[None, None, :], (b, 3, c_len))
    h = _embed_tokens(params, cfg, batch)

    def body(carry, xs):
        hh, = carry
        lp, kc, vc = xs
        hh, (kn, vn) = block_prefill_chunk(
            lp, cfg, hh, (kc, vc), start=start,
            mrope_positions=mrope_positions)
        return (hh,), (kn, vn)

    (h,), (k_news, v_news) = jax.lax.scan(
        body, (h,), (params["layers"], cache["k"], cache["v"]))
    k2, v2 = attn_mod.cache_write(cache["k"], cache["v"], k_news, v_news, start)
    if not with_logits:
        return None, {"k": k2, "v": v2}
    h = norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)
    return logits, {"k": k2, "v": v2}


def paged_decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array,
                      pos: jax.Array, tables: jax.Array, page_size: int):
    """One decode step against a paged KV cache (serve.kv_pages tier).

    tokens [B, 1]; pos [B] int32 per-slot lengths; tables [B, n_max] int32
    page tables; ``cache`` leaves are [L, P, page_size, KV, D] (P includes
    the trash page). Returns (logits [B, 1, V], new_cache). Same
    no-write-in-scan contract as :func:`decode_step`: the layers' new K/V
    come out as scan ys and ONE page-table scatter commits them.
    Attention-pattern families only.
    """
    if cfg.block_pattern != "attn":
        raise NotImplementedError(
            f"paged_decode_step supports attention families only, not "
            f"block_pattern={cfg.block_pattern!r}")
    h = embed_lookup(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    b = tokens.shape[0]
    pos_b = jnp.broadcast_to(
        jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))

    def body(carry, xs):
        hh, = carry
        lp, kc, vc = xs
        hh, (kn, vn) = block_paged_decode(lp, cfg, hh, (kc, vc), pos=pos_b,
                                          tables=tables, page_size=page_size)
        return (hh,), (kn, vn)

    (h,), (k_news, v_news) = jax.lax.scan(
        body, (h,), (params["layers"], cache["k"], cache["v"]))
    # k_news [L, B, 1, KV, D] -> [L, B, KV, D]; one scatter through the
    # tables (inactive slots' rows land on the trash page)
    rows = attn_mod.page_rows(tables, jnp.arange(b, dtype=jnp.int32), pos_b,
                              page_size)
    k2, v2 = attn_mod.paged_cache_write(
        cache["k"], cache["v"], k_news[:, :, 0], v_news[:, :, 0], rows)
    h = norm_apply(params["final_norm"], h, cfg.norm)
    logits = _unembed(params, cfg, h)
    return logits, {"k": k2, "v": v2}


def prefill_packed(params, cfg: ModelConfig, cache, tokens: jax.Array,
                   slot_ids: jax.Array, positions: jax.Array,
                   tables: jax.Array, last_idx: jax.Array, page_size: int):
    """Packed (padding-free) multi-prompt prefill into a paged cache.

    tokens/slot_ids/positions [T] — several prompts concatenated into one
    exact-shape stream (see ``serve.kv_pages.pack_prompts``); tables
    [n_slots, n_max]; last_idx [n_new] stream indices of each prompt's final
    token. Attention is block-diagonal causal over the stream — zero padded
    columns, zero wasted FLOPs — and only the ``n_new`` last-token rows pay
    the unembed matmul. Returns (logits [n_new, 1, V], cache with every
    prompt's K/V scattered through its page table).

    Retraces per distinct total stream length T (the padding-free
    tradeoff); the scheduler admits all same-iteration arrivals in ONE
    stream, so retraces are bounded by distinct admission-batch shapes.
    """
    if cfg.block_pattern != "attn":
        raise NotImplementedError(
            f"prefill_packed supports attention families only, not "
            f"block_pattern={cfg.block_pattern!r}")
    h = embed_lookup(params["embed"], tokens[None, :]).astype(
        jnp.dtype(cfg.dtype))

    def body(carry, xs):
        hh, = carry
        lp, = xs
        hh, (kn, vn) = block_prefill_packed(lp, cfg, hh, seq_ids=slot_ids,
                                            positions=positions)
        return (hh,), (kn, vn)

    (h,), (k_news, v_news) = jax.lax.scan(body, (h,), (params["layers"],))
    # k_news [L, 1, T, KV, D] -> [L, T, KV, D]; one scatter commits the
    # whole stream's K/V through the page tables
    rows = attn_mod.page_rows(tables, slot_ids, positions, page_size)
    k2, v2 = attn_mod.paged_cache_write(
        cache["k"], cache["v"], k_news[:, 0], v_news[:, 0], rows)
    h = norm_apply(params["final_norm"], h, cfg.norm)
    h_last = jnp.take(h[0], last_idx, axis=0)  # [n_new, d]
    logits = _unembed(params, cfg, h_last[:, None, :])
    return logits, {"k": k2, "v": v2}
