"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv frontend is a STUB: ``input_specs()`` supplies
precomputed frame embeddings [B, S_enc, d_model].  Deviations recorded in
DESIGN.md: sinusoidal (not learned) decoder positions so 32k-token decode
cells need no giant learned tables; decoder ties unembed to its embedding as
in the original model.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import Boxed
from repro.models import attention as attn_mod
from repro.models.blocks import block_init, stack_init
from repro.models.common import (
    embed_init,
    embed_lookup,
    norm_apply,
    norm_init,
    sinusoidal_positions,
)
from repro.models.mlp import mlp_apply, mlp_init
from repro.sharding import shd


def _dec_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "self_attn": attn_mod.attn_init(ks[0], cfg),
        "ln_x": norm_init(cfg.d_model, cfg.norm, dtype),
        "cross_attn": attn_mod.attn_init(ks[1], cfg),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp_init(ks[2], cfg),
    }


def encdec_init(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "dec_embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "enc_layers": stack_init(lambda k: block_init(k, cfg), ks[1], cfg.encoder_layers),
        "dec_layers": stack_init(lambda k: _dec_block_init(k, cfg), ks[2], cfg.n_layers),
        "enc_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "dec_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }


def _pos(s: int, d: int, offset=0) -> jax.Array:
    return jnp.asarray(sinusoidal_positions(s + offset, d))[offset:]


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """enc_embeds [B, S_enc, d] (stub frontend output) -> encoder states."""
    from repro.models.blocks import block_apply

    b, s, d = enc_embeds.shape
    h = enc_embeds.astype(jnp.dtype(cfg.dtype)) + _pos(s, d).astype(cfg.dtype)
    h = shd(h, "act_batch", "act_seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, lp):
        hh, = carry
        hh, _ = block_apply(lp, cfg, hh, positions=positions, causal=False)
        return (hh,), None

    (h,), _ = jax.lax.scan(body, (h,), params["enc_layers"])
    return norm_apply(params["enc_norm"], h, cfg.norm)


def _dec_block_apply(lp, cfg, h, positions, enc_out, causal=True):
    x = norm_apply(lp["ln1"], h, cfg.norm)
    h = h + attn_mod.attn_apply(lp["self_attn"], cfg, x, positions=positions, causal=causal)
    x = norm_apply(lp["ln_x"], h, cfg.norm)
    kv = attn_mod.cross_kv(lp["cross_attn"], cfg, enc_out)
    h = h + attn_mod.cross_attn_apply(lp["cross_attn"], cfg, x, kv)
    x = norm_apply(lp["ln2"], h, cfg.norm)
    h = h + mlp_apply(lp["mlp"], cfg, x)
    return shd(h, "act_batch", "act_seq_sp", None)


def decode_forward(params, cfg: ModelConfig, tokens: jax.Array, enc_out: jax.Array):
    b, s = tokens.shape
    h = embed_lookup(params["dec_embed"], tokens).astype(jnp.dtype(cfg.dtype))
    h = h + _pos(s, cfg.d_model).astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, lp):
        hh, = carry
        hh = _dec_block_apply(lp, cfg, hh, positions, enc_out)
        return (hh,), None

    (h,), _ = jax.lax.scan(body, (h,), params["dec_layers"])
    h = norm_apply(params["dec_norm"], h, cfg.norm)
    return jnp.einsum("bsd,vd->bsv", h, params["dec_embed"].astype(h.dtype))


def encdec_loss(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["enc_embeds"])
    logits = decode_forward(params, cfg, batch["tokens"], enc_out)
    logits = logits[:, :-1].astype(jnp.float32)
    labels = batch["tokens"][:, 1:]
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll, "aux": jnp.zeros(())}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def encdec_cache_init(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    dtype = jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dtype),
        # cross K/V precomputed at prefill from the encoder output
        "xk": jnp.zeros((cfg.n_layers, batch, enc_len, kv, hd), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, enc_len, kv, hd), dtype),
    }


def encdec_prefill(params, cfg: ModelConfig, enc_embeds: jax.Array, tokens: jax.Array):
    """Encoder forward + decoder prefill; returns (last logits, cache)."""
    enc_out = encode(params, cfg, enc_embeds)
    b, s = tokens.shape
    h = embed_lookup(params["dec_embed"], tokens).astype(jnp.dtype(cfg.dtype))
    h = h + _pos(s, cfg.d_model).astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, lp):
        hh, = carry
        x = norm_apply(lp["ln1"], hh, cfg.norm)
        q, k, v = attn_mod._qkv(lp["self_attn"], cfg, x, positions, None)
        if cfg.attn_impl == "chunked" and s > cfg.attn_chunk:
            o = attn_mod.sdpa_gqa_chunked(q, k, v, causal=True, chunk=cfg.attn_chunk)
        else:
            o = attn_mod.sdpa_gqa(q, k, v, causal=True)
        from repro.core.sparse_linear import linear_apply as _la

        hh = hh + _la(lp["self_attn"]["o"], o.reshape(b, s, -1))
        x = norm_apply(lp["ln_x"], hh, cfg.norm)
        xk, xv = attn_mod.cross_kv(lp["cross_attn"], cfg, enc_out)
        hh = hh + attn_mod.cross_attn_apply(lp["cross_attn"], cfg, x, (xk, xv))
        x = norm_apply(lp["ln2"], hh, cfg.norm)
        hh = hh + mlp_apply(lp["mlp"], cfg, x)
        return (hh,), (k, v, xk, xv)

    (h,), (ks, vs, xks, xvs) = jax.lax.scan(body, (h,), params["dec_layers"])
    h = norm_apply(params["dec_norm"], h, cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", h[:, -1:], params["dec_embed"].astype(h.dtype))
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}
    return logits, cache


def encdec_decode_step(params, cfg: ModelConfig, cache, tokens: jax.Array, pos: jax.Array):
    """One decoder token against self-KV cache + precomputed cross-KV."""
    b = tokens.shape[0]
    h = embed_lookup(params["dec_embed"], tokens).astype(jnp.dtype(cfg.dtype))
    smax = cache["k"].shape[2]
    postab = jnp.asarray(sinusoidal_positions(smax, cfg.d_model))
    h = h + jax.lax.dynamic_slice_in_dim(postab, pos, 1, axis=0)[None].astype(h.dtype)

    def body(carry, xs):
        hh, = carry
        lp, kc, vc, xk, xv = xs
        x = norm_apply(lp["ln1"], hh, cfg.norm)
        a, (kn, vn) = attn_mod.attn_decode(lp["self_attn"], cfg, x, (kc, vc), pos=pos)
        hh = hh + a
        x = norm_apply(lp["ln_x"], hh, cfg.norm)
        hh = hh + attn_mod.cross_attn_apply(lp["cross_attn"], cfg, x, (xk, xv))
        x = norm_apply(lp["ln2"], hh, cfg.norm)
        hh = hh + mlp_apply(lp["mlp"], cfg, x)
        return (hh,), (kn, vn)

    (h,), (k_news, v_news) = jax.lax.scan(
        body, (h,), (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    h = norm_apply(params["dec_norm"], h, cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", h, params["dec_embed"].astype(h.dtype))
    k2, v2 = attn_mod.cache_write(cache["k"], cache["v"], k_news, v_news, pos)
    new_cache = dict(cache, k=k2, v=v2)
    return logits, new_cache
