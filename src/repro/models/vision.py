"""ResNet-style vision model built on ``conv_init``/``conv_apply``.

The LM side of the zoo exercises the paper's technique through
``linear_init``/``linear_apply``; this module is the conv twin: a stack of
ResNet *basic blocks* whose every convolution is a ``core.sparse_conv``
layer, so a :class:`repro.configs.base.VisionConfig` drives the pruned-conv
dispatch path (fused megakernel / banded / pipelined two-kernel / XLA — see
``docs/kernels.md``) end-to-end with real params.

Layout is the paper's CNHW throughout.  Norm layers are intentionally
omitted (parameter-free identity): the repro targets the conv GEMM data
path, and a norm between convs would not change which execution plan is
selected.  ``conv_hints`` walks the same structure the init does and emits
the per-layer map shapes ``dispatch.plan_params`` needs to pre-profile every
conv under its exact ``conv_key`` token — the build-time twin of what
``conv_apply`` resolves at trace time.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import VisionConfig
from repro.core.pruning import DENSE
from repro.core.sparse_conv import conv_apply, conv_init
from repro.core.sparse_linear import Boxed, linear_apply, linear_init
from repro.kernels.im2col_pack.ref import out_size


# ---------------------------------------------------------------------------
# ResNet basic block
# ---------------------------------------------------------------------------


def resnet_block_init(key, c_in: int, c_out: int, cfg: VisionConfig, *,
                      stride: int = 1, dtype=jnp.float32) -> Dict[str, Any]:
    """Params of one basic block: 3x3 conv -> 3x3 conv + residual; a 1x1
    strided projection when the shortcut changes shape.  Every conv is a
    ``conv_init`` layer (pruned per ``cfg.sparsity``; the stem-like 1x1
    projection is left dense by ``min_dim`` exactly as the paper skips its
    3-channel stem)."""
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "conv1": conv_init(k1, c_in, c_out, 3, 3, cfg.sparsity, dtype=dtype),
        "conv2": conv_init(k2, c_out, c_out, 3, 3, cfg.sparsity, dtype=dtype),
    }
    if stride != 1 or c_in != c_out:
        params["proj"] = conv_init(k3, c_in, c_out, 1, 1, cfg.sparsity,
                                   dtype=dtype)
    return params


def resnet_block_apply(params, x_cnhw: jax.Array, *, stride: int = 1,
                       v: int = 128, impl: Optional[str] = None) -> jax.Array:
    """Apply one basic block to a CNHW map (unboxed params)."""
    y = conv_apply(params["conv1"], x_cnhw, kh=3, kw=3, stride=stride, pad=1,
                   v=v, impl=impl)
    y = jax.nn.relu(y)
    y = conv_apply(params["conv2"], y, kh=3, kw=3, stride=1, pad=1, v=v,
                   impl=impl)
    if "proj" in params:
        short = conv_apply(params["proj"], x_cnhw, kh=1, kw=1, stride=stride,
                           pad=0, v=v, impl=impl)
    else:
        short = x_cnhw
    return jax.nn.relu(y + short)


# ---------------------------------------------------------------------------
# Whole model: stem conv -> stages of basic blocks -> pooled linear head
# ---------------------------------------------------------------------------


def _block_strides(cfg: VisionConfig):
    """(stage, index-in-stage, stride, c_in, c_out) per block, in order."""
    out = []
    c_prev = cfg.stem_channels
    for si, (ch, n, st) in enumerate(zip(cfg.stage_channels, cfg.stage_blocks,
                                         cfg.stage_strides)):
        for bi in range(n):
            out.append((si, bi, st if bi == 0 else 1, c_prev, ch))
            c_prev = ch
    return out


def vision_init(cfg: VisionConfig, key) -> Dict[str, Any]:
    """Boxed params tree: ``{"stem", "blocks": [...], "head"}``."""
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2 + len(_block_strides(cfg)))
    params: Dict[str, Any] = {
        # 3-channel stem stays dense via min_dim, mirroring the paper
        "stem": conv_init(ks[0], cfg.c_in, cfg.stem_channels, 3, 3,
                          cfg.sparsity, dtype=dtype),
        "blocks": [],
    }
    for i, (_si, _bi, stride, c_in, c_out) in enumerate(_block_strides(cfg)):
        params["blocks"].append(
            resnet_block_init(ks[1 + i], c_in, c_out, cfg, stride=stride,
                              dtype=dtype))
    # pooled classifier head: a sparse_linear layer (the same tree then
    # exercises BOTH op kinds of the plan_params discriminator); tiny heads
    # stay dense via min_dim
    params["head"] = linear_init(ks[-1], cfg.stage_channels[-1],
                                 cfg.num_classes, cfg.sparsity, dtype=dtype,
                                 in_ax="embed", out_ax=None)
    return params


def vision_apply(params, cfg: VisionConfig, x_cnhw: jax.Array, *,
                 impl: Optional[str] = None) -> jax.Array:
    """Forward pass: CNHW images [C, B, H, W] -> logits [B, num_classes]."""
    y = conv_apply(params["stem"], x_cnhw, kh=3, kw=3, stride=1, pad=1,
                   v=cfg.strip_v, impl=impl)
    y = jax.nn.relu(y)
    for block, (_si, _bi, stride, _ci, _co) in zip(params["blocks"],
                                                   _block_strides(cfg)):
        y = resnet_block_apply(block, y, stride=stride, v=cfg.strip_v,
                               impl=impl)
    feats = y.mean(axis=(2, 3)).T  # global average pool -> [B, C]
    return linear_apply(params["head"], feats)


def conv_hints(cfg: VisionConfig, batch: int = 1) -> Dict[str, Dict[str, int]]:
    """Per-layer map-shape hints for ``dispatch.plan_params(conv_hints=...)``.

    Walks the block structure with the same stride arithmetic as
    ``vision_apply``, so every planned ``conv_key`` token matches the one the
    trace-time ``conv_apply`` call site resolves.  Keys are layer-path
    substrings (``blocks[i]/conv1`` ...) as produced by
    ``dispatch.iter_op_layers``.
    """
    h, w = cfg.image_hw
    hints: Dict[str, Dict[str, int]] = {
        "stem": {"h": h, "w": w, "batch": batch, "stride": 1, "pad": 1,
                 "v": cfg.strip_v},
    }
    for i, (_si, _bi, stride, _ci, _co) in enumerate(_block_strides(cfg)):
        hints[f"blocks[{i}]/conv1"] = {
            "h": h, "w": w, "batch": batch, "stride": stride, "pad": 1,
            "v": cfg.strip_v}
        hints[f"blocks[{i}]/proj"] = {
            "h": h, "w": w, "batch": batch, "stride": stride, "pad": 0,
            "v": cfg.strip_v}
        h = out_size(h, 3, stride, 1)
        w = out_size(w, 3, stride, 1)
        hints[f"blocks[{i}]/conv2"] = {
            "h": h, "w": w, "batch": batch, "stride": 1, "pad": 1,
            "v": cfg.strip_v}
    return hints
