"""ResNet-style vision model built on ``conv_init``/``conv_apply``.

The LM side of the zoo exercises the paper's technique through
``linear_init``/``linear_apply``; this module is the conv twin: a stack of
ResNet *basic blocks* whose every convolution is a ``core.sparse_conv``
layer, so a :class:`repro.configs.base.VisionConfig` drives the pruned-conv
dispatch path (fused megakernel / banded / pipelined two-kernel / XLA — see
``docs/kernels.md``) end-to-end with real params.

Layout is the paper's CNHW throughout.  Norm layers are intentionally
omitted (parameter-free identity): the repro targets the conv GEMM data
path, and a norm between convs would not change which execution plan is
selected.  ``conv_hints`` walks the same structure the init does and emits
the per-layer map shapes ``dispatch.plan_params`` needs to pre-profile every
conv under its exact ``conv_key`` token — the build-time twin of what
``conv_apply`` resolves at trace time.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import VisionConfig
from repro.core.pruning import DENSE, mask_project_tree
from repro.core.sparse_conv import conv_apply, conv_init
from repro.core.sparse_linear import Boxed, linear_apply, linear_init
from repro.kernels.im2col_pack.ref import out_size


# ---------------------------------------------------------------------------
# ResNet basic block
# ---------------------------------------------------------------------------


def resnet_block_init(key, c_in: int, c_out: int, cfg: VisionConfig, *,
                      stride: int = 1, dtype=jnp.float32) -> Dict[str, Any]:
    """Params of one basic block: 3x3 conv -> 3x3 conv + residual; a 1x1
    strided projection when the shortcut changes shape.  Every conv is a
    ``conv_init`` layer (pruned per ``cfg.sparsity``; the stem-like 1x1
    projection is left dense by ``min_dim`` exactly as the paper skips its
    3-channel stem)."""
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "conv1": conv_init(k1, c_in, c_out, 3, 3, cfg.sparsity, dtype=dtype),
        "conv2": conv_init(k2, c_out, c_out, 3, 3, cfg.sparsity, dtype=dtype),
    }
    if stride != 1 or c_in != c_out:
        params["proj"] = conv_init(k3, c_in, c_out, 1, 1, cfg.sparsity,
                                   dtype=dtype)
    return params


def resnet_block_apply(params, x_cnhw: jax.Array, *, stride: int = 1,
                       v: int = 128, impl: Optional[str] = None) -> jax.Array:
    """Apply one basic block to a CNHW map (unboxed params)."""
    y = conv_apply(params["conv1"], x_cnhw, kh=3, kw=3, stride=stride, pad=1,
                   v=v, impl=impl)
    y = jax.nn.relu(y)
    y = conv_apply(params["conv2"], y, kh=3, kw=3, stride=1, pad=1, v=v,
                   impl=impl)
    if "proj" in params:
        short = conv_apply(params["proj"], x_cnhw, kh=1, kw=1, stride=stride,
                           pad=0, v=v, impl=impl)
    else:
        short = x_cnhw
    return jax.nn.relu(y + short)


# ---------------------------------------------------------------------------
# Whole model: stem conv -> stages of basic blocks -> pooled linear head
# ---------------------------------------------------------------------------


def _block_strides(cfg: VisionConfig):
    """(stage, index-in-stage, stride, c_in, c_out) per block, in order."""
    out = []
    c_prev = cfg.stem_channels
    for si, (ch, n, st) in enumerate(zip(cfg.stage_channels, cfg.stage_blocks,
                                         cfg.stage_strides)):
        for bi in range(n):
            out.append((si, bi, st if bi == 0 else 1, c_prev, ch))
            c_prev = ch
    return out


def vision_init(cfg: VisionConfig, key) -> Dict[str, Any]:
    """Boxed params tree: ``{"stem", "blocks": [...], "head"}``."""
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2 + len(_block_strides(cfg)))
    params: Dict[str, Any] = {
        # 3-channel stem stays dense via min_dim, mirroring the paper
        "stem": conv_init(ks[0], cfg.c_in, cfg.stem_channels, 3, 3,
                          cfg.sparsity, dtype=dtype),
        "blocks": [],
    }
    for i, (_si, _bi, stride, c_in, c_out) in enumerate(_block_strides(cfg)):
        params["blocks"].append(
            resnet_block_init(ks[1 + i], c_in, c_out, cfg, stride=stride,
                              dtype=dtype))
    # pooled classifier head: a sparse_linear layer (the same tree then
    # exercises BOTH op kinds of the plan_params discriminator); tiny heads
    # stay dense via min_dim
    params["head"] = linear_init(ks[-1], cfg.stage_channels[-1],
                                 cfg.num_classes, cfg.sparsity, dtype=dtype,
                                 in_ax="embed", out_ax=None)
    return params


def vision_apply(params, cfg: VisionConfig, x_cnhw: jax.Array, *,
                 impl: Optional[str] = None) -> jax.Array:
    """Forward pass: CNHW images [C, B, H, W] -> logits [B, num_classes]."""
    y = conv_apply(params["stem"], x_cnhw, kh=3, kw=3, stride=1, pad=1,
                   v=cfg.strip_v, impl=impl)
    y = jax.nn.relu(y)
    for block, (_si, _bi, stride, _ci, _co) in zip(params["blocks"],
                                                   _block_strides(cfg)):
        y = resnet_block_apply(block, y, stride=stride, v=cfg.strip_v,
                               impl=impl)
    feats = y.mean(axis=(2, 3)).T  # global average pool -> [B, C]
    return linear_apply(params["head"], feats)


# ---------------------------------------------------------------------------
# Sparse finetuning: cross-entropy loss + SGD/momentum train step
# ---------------------------------------------------------------------------
#
# The conv twin of the LM finetune story: `conv_apply` is differentiable for
# compressed layers (the `conv2d_sparse` custom VJP — gradients flow into the
# packed `values` whatever plan rung the forward ran on) and for masked
# layers (dense conv on w*mask; `mask_project_tree` re-projects after each
# optimizer step so the support stays fixed).  SGD with momentum, the
# paper-adjacent choice for the vision finetune.


def vision_loss(params, cfg: VisionConfig, x_cnhw: jax.Array,
                labels: jax.Array) -> jax.Array:
    """Mean cross-entropy of ``vision_apply`` logits against int labels."""
    logits = vision_apply(params, cfg, x_cnhw)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def _trainable(leaf) -> bool:
    return (hasattr(leaf, "dtype")
            and jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating))


def sgd_init(params):
    """Zero momentum buffers, one per leaf.  Non-float leaves (the
    compressed layers' int ``idx``/``conv_geom``, bool masks) keep a dummy
    zero buffer so the momentum tree matches the params structure; they are
    never updated."""
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def train_step(params, mom, cfg: VisionConfig, x_cnhw: jax.Array,
               labels: jax.Array, *, lr: float = 0.05,
               momentum: float = 0.9):
    """One SGD/momentum step of sparse vision finetuning.

    Differentiates ``vision_loss`` through every layer format in the tree —
    compressed convs backpropagate through the ``conv2d_sparse`` custom VJP
    into their packed ``values`` (``allow_int`` tolerates the int
    ``idx``/``conv_geom`` leaves, whose float0 cotangents are skipped), and
    masked layers are re-projected onto their stored masks after the update.
    Returns ``(params, mom, loss)``; jit-safe (cfg is closed over by the
    caller's jit, see :func:`train_smoke`).
    """
    loss, grads = jax.value_and_grad(vision_loss, allow_int=True)(
        params, cfg, x_cnhw, labels)

    def upd_m(m, g):
        # int/bool leaves get float0 cotangents from allow_int: skip them
        if not _trainable(m) or g.dtype == jax.dtypes.float0:
            return m
        return momentum * m + g.astype(m.dtype)

    def upd_p(p, m):
        if not _trainable(p):
            return p
        return p - lr * m.astype(p.dtype)

    mom = jax.tree_util.tree_map(upd_m, mom, grads)
    params = jax.tree_util.tree_map(upd_p, params, mom)
    params = mask_project_tree(params)
    return params, mom, loss


def synth_batch(cfg: VisionConfig, key, batch: int):
    """Learnable synthetic classification batch: per-class Gaussian mean
    images + noise.  Deterministic in ``key``; the class means are fixed by
    the config (seed 0), so train and eval batches share one task."""
    h, w = cfg.image_hw
    means = jax.random.normal(
        jax.random.PRNGKey(0), (cfg.num_classes, cfg.c_in, h, w)) * 0.5
    kl, kn = jax.random.split(key)
    labels = jax.random.randint(kl, (batch,), 0, cfg.num_classes)
    x = means[labels] + 0.3 * jax.random.normal(kn, (batch, cfg.c_in, h, w))
    # CNHW layout: [C, B, H, W]
    return x.transpose(1, 0, 2, 3).astype(jnp.dtype(cfg.dtype)), labels


def batch_for_step(cfg: VisionConfig, seed: int, step: int, batch: int):
    """Finetune batch for global step k — a pure function of (seed, k).

    This is the vision twin of ``SyntheticLM.batch_at``: the data pipeline's
    whole checkpointable state is the step counter, so a resumed run replays
    the exact batch stream and the ``SparseTrainer`` resume-determinism
    contract (kill-at-k -> restart -> bitwise-identical params) holds.
    """
    from repro import fault as _fault

    _fault.maybe_fail("data.batch", step=step)
    return synth_batch(cfg, jax.random.fold_in(jax.random.PRNGKey(seed), step),
                       batch)


def vision_accuracy(params, cfg: VisionConfig, x_cnhw, labels) -> float:
    logits = vision_apply(params, cfg, x_cnhw)
    return float((jnp.argmax(logits, axis=-1) == labels).mean())


def train_smoke(steps: int = 2, batch: int = 4, lr: float = 0.05,
                arch: str = "resnet-tiny", verbose: bool = True):
    """N-step sparse finetune smoke on resnet-tiny (compressed convs): the
    CI guard that the conv backward path stays alive end to end.  Asserts
    the loss decreases over the run (fixed batch, fixed seed —
    deterministic) and returns the per-step losses."""
    from repro.configs import get_vision_config
    from repro.core.sparse_linear import unbox_tree

    cfg = get_vision_config(arch)
    params, _ = unbox_tree(vision_init(cfg, jax.random.PRNGKey(0)))
    x, labels = synth_batch(cfg, jax.random.PRNGKey(1), batch)
    mom = sgd_init(params)
    step = jax.jit(lambda p, m, x, y: train_step(p, m, cfg, x, y, lr=lr))
    losses = []
    for _ in range(max(steps, 2)):
        params, mom, loss = step(params, mom, x, labels)
        losses.append(float(loss))
        if verbose:
            print(f"train_smoke step {len(losses)}: loss={losses[-1]:.4f}")
    assert losses[-1] < losses[0], (
        f"sparse finetune smoke did not reduce loss: {losses}")
    return losses


def conv_hints(cfg: VisionConfig, batch: int = 1) -> Dict[str, Dict[str, int]]:
    """Per-layer map-shape hints for ``dispatch.plan_params(conv_hints=...)``.

    Walks the block structure with the same stride arithmetic as
    ``vision_apply``, so every planned ``conv_key`` token matches the one the
    trace-time ``conv_apply`` call site resolves.  Keys are layer-path
    substrings (``blocks[i]/conv1`` ...) as produced by
    ``dispatch.iter_op_layers``.
    """
    h, w = cfg.image_hw
    hints: Dict[str, Dict[str, int]] = {
        "stem": {"h": h, "w": w, "batch": batch, "stride": 1, "pad": 1,
                 "v": cfg.strip_v},
    }
    for i, (_si, _bi, stride, _ci, _co) in enumerate(_block_strides(cfg)):
        hints[f"blocks[{i}]/conv1"] = {
            "h": h, "w": w, "batch": batch, "stride": stride, "pad": 1,
            "v": cfg.strip_v}
        hints[f"blocks[{i}]/proj"] = {
            "h": h, "w": w, "batch": batch, "stride": stride, "pad": 0,
            "v": cfg.strip_v}
        h = out_size(h, 3, stride, 1)
        w = out_size(w, 3, stride, 1)
        hints[f"blocks[{i}]/conv2"] = {
            "h": h, "w": w, "batch": batch, "stride": 1, "pad": 1,
            "v": cfg.strip_v}
    return hints
