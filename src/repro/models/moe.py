"""Mixture-of-Experts layer: top-k routing, capacity-clipped scatter dispatch,
expert-parallel sharding, and per-expert column-wise N:M pruning.

Dispatch is the sort-free scatter formulation: each (token, slot) assignment
computes its position-in-expert by a cumsum over one-hot expert ids, then
tokens are scatter-added into a [E, capacity, d] buffer (dropped tokens are
masked to zero before the scatter, so slot collisions add zeros).  This keeps
every shape static — a requirement for pjit — and lets GSPMD lower the
token->expert movement to an all-to-all over the expert-parallel axis.

The paper's technique applies per expert: every expert FFN matrix is a
SparseLinear; in compressed form the kept-index gather is vmapped over the
expert dimension.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import (
    Boxed,
    forward_compressed_xla,
    forward_masked,
    linear_init,
)
from repro.sharding import shd


def _stacked_linear_init(key, e: int, d_in: int, d_out: int, cfg: ModelConfig):
    """Init an expert-stacked linear [E, ...] honoring the sparsity config."""
    scfg = cfg.sparsity
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, e)
    base = [linear_init(k, d_in, d_out, scfg, dtype=dtype, in_ax="embed", out_ax="ffn")
            for k in ks[:1]]
    # init one expert to learn the structure, then batch-init all experts with
    # a single vmapped call for speed
    def init_one(k):
        p = linear_init(k, d_in, d_out, scfg, dtype=dtype, in_ax="embed", out_ax="ffn")
        return {kk: v.value for kk, v in p.items()}

    stacked = jax.vmap(init_one)(jnp.stack(ks))
    out = {}
    for kk, spec_src in base[0].items():
        out[kk] = Boxed(stacked[kk], ("expert",) + spec_src.spec)
    return out


def _stacked_linear_apply(params, x: jax.Array) -> jax.Array:
    """x: [E, C, d_in] -> [E, C, d_out] with per-expert weights."""
    if "values" in params:
        return jax.vmap(forward_compressed_xla)(x, params["values"], params["idx"])
    if "mask" in params:
        return jax.vmap(forward_masked)(x, params["w"], params["mask"])
    return jnp.einsum("ecd,edf->ecf", x, params["w"])


def moe_init(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "router": Boxed(
            jax.random.normal(ks[0], (d, e), jnp.float32) * (1.0 / math.sqrt(d)),
            ("embed", "expert"),
        )
    }
    if cfg.mlp_act == "swiglu":
        p["gate"] = _stacked_linear_init(ks[1], e, d, f, cfg)
    p["up"] = _stacked_linear_init(ks[2], e, d, f, cfg)
    p["down"] = _stacked_linear_init(ks[3], e, f, d, cfg)
    return p


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # multiple of 8 for clean tiling


def moe_apply_shard_map(params, cfg: ModelConfig, x: jax.Array,
                        router_dtype=jnp.float32):
    """Manual expert-parallel MoE via shard_map (beyond-paper, EXPERIMENTS
    §Perf cell 2 follow-up).

    Key observation: at the MoE input the activations are *replicated over
    the model axis* (they were just all-gathered for the block), so expert
    dispatch needs NO token movement at all — every device routes the full
    local-batch token set, keeps only assignments to ITS expert shard,
    computes them, and the combine is a single psum over 'model'.  This
    replaces GSPMD's f32 full-buffer dispatch all-reduces (~730 GB/chip/step
    on olmoe train_4k) with one [T_loc, d] bf16 reduction per layer.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding import get_ctx

    ctx = get_ctx()
    mesh = ctx.mesh if ctx else None
    e, k = cfg.n_experts, cfg.top_k
    if (mesh is None or "model" not in mesh.shape or mesh.shape["model"] == 1
            or e % mesh.shape["model"] != 0):
        return moe_apply(params, cfg, x, router_dtype)
    tp = mesh.shape["model"]
    b, s, d = x.shape
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    batch_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    ew_specs = jax.tree_util.tree_map(
        lambda l: P(*(("model",) + (None,) * (l.ndim - 1))),
        {kk: params[kk] for kk in params if kk != "router"},
    )
    in_specs = (P(batch_spec, None, None), P(None, None), ew_specs)
    out_specs = (P(batch_spec, None, None), P())

    def body(x_loc, router, ew):
        bl, sl, _ = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        midx = jax.lax.axis_index("model")
        e_loc = e // tp
        e_start = midx * e_loc

        logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype),
                            preferred_element_type=router_dtype)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        # aux loss: identical on every model-peer (replicated inputs) but
        # per-data-shard tokens differ -> average over the data axes
        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), router_dtype).at[top_i.reshape(-1)].add(1.0) / (t * k)
        aux = e * jnp.sum(me * ce)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)

        # keep only assignments to MY experts
        ef = top_i.reshape(-1)
        mine = (ef >= e_start) & (ef < e_start + e_loc)
        el = jnp.where(mine, ef - e_start, 0)
        cap = moe_capacity(t, cfg)
        onehot = jax.nn.one_hot(el, e_loc, dtype=jnp.int32) * mine[:, None]
        pos = (jnp.cumsum(onehot, axis=0) - onehot)
        pos = jnp.take_along_axis(pos, el[:, None], axis=1)[:, 0]
        keep = mine & (pos < cap)
        xt_rep = jnp.repeat(xt, k, axis=0)
        contrib = xt_rep * keep[:, None].astype(xt.dtype)
        buf = jnp.zeros((e_loc, cap, d), xt.dtype)
        buf = buf.at[el, jnp.minimum(pos, cap - 1)].add(contrib)

        if cfg.mlp_act == "swiglu":
            h = jax.nn.silu(_stacked_linear_apply(ew["gate"], buf)) * \
                _stacked_linear_apply(ew["up"], buf)
        else:
            h = jnp.square(jax.nn.relu(_stacked_linear_apply(ew["up"], buf)))
        out_buf = _stacked_linear_apply(ew["down"], h)

        gathered = out_buf[el, jnp.minimum(pos, cap - 1)]
        gathered = gathered * keep[:, None].astype(gathered.dtype)
        w = top_p.reshape(-1)[:, None].astype(gathered.dtype)
        y_loc = (gathered * w).reshape(t, k, d).sum(axis=1)
        y = jax.lax.psum(y_loc, "model")  # the ONLY cross-expert collective
        return y.reshape(bl, sl, d), aux

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    ew = {kk: params[kk] for kk in params if kk != "router"}
    return fn(x, params["router"], ew)


def _dispatch_group(xt, top_i, top_p, e: int, cap: int, k: int):
    """One group's scatter dispatch. xt [Tg,d]; returns (buf [E,cap,d],
    e_flat, pos, keep) — all group-local (no cross-group cumsum)."""
    tg = xt.shape[0]
    e_flat = top_i.reshape(-1)  # [Tg*K]
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_in_e, e_flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    xt_rep = jnp.repeat(xt, k, axis=0)
    contrib = xt_rep * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((e, cap, xt.shape[1]), xt.dtype)
    buf = buf.at[e_flat, jnp.minimum(pos, cap - 1)].add(contrib)
    return buf, e_flat, pos, keep


def moe_apply(params, cfg: ModelConfig, x: jax.Array, router_dtype=jnp.float32):
    """x: [B, S, d] -> [B, S, d]; returns (y, aux_loss).

    Grouped dispatch (GSPMD/Switch pattern): tokens are split into
    ``cfg.dp`` groups matching the data-parallel shards; routing, cumsum and
    scatter are group-local (no global [T*K, E] cumsum), and the group->expert
    buffer reshard [G(data), E, C, d] -> [G, E(model), C, d] lowers to an
    all-to-all over the expert-parallel axis instead of the full-buffer
    all-reduce the naive scatter produced (measured ~730 GB/chip/step on
    olmoe train_4k; see EXPERIMENTS §Perf).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = max(1, min(cfg.dp, b))
    while b % g != 0:
        g -= 1
    t = b * s
    tg = t // g
    xg = x.reshape(g, tg, d)

    # bf16 operands + f32 accumulation: an f32 *copy* of the activations here
    # costs a [T, d] f32 all-gather in the backward (measured 77 GB/chip/step)
    logits = jnp.einsum(
        "gtd,de->gte", xg, params["router"].astype(xg.dtype),
        preferred_element_type=router_dtype,
    )
    probs = jax.nn.softmax(logits, axis=-1)  # [G, Tg, E]
    top_p, top_i = jax.lax.top_k(probs, k)  # [G, Tg, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style), averaged over groups
    me = probs.mean(axis=1)  # [G, E]
    ce = jax.vmap(
        lambda ti: jnp.zeros((e,), router_dtype).at[ti.reshape(-1)].add(1.0) / (tg * k)
    )(top_i)
    aux = e * jnp.sum(me * ce, axis=-1).mean()

    cap = moe_capacity(tg, cfg)
    buf, e_flat, pos, keep = jax.vmap(
        lambda xx, ti, tp: _dispatch_group(xx, ti, tp, e, cap, k)
    )(xg, top_i, top_p)
    # group-sharded -> expert-sharded: this boundary is the all-to-all
    buf = shd(buf, None, "act_expert", None, None)

    # --- expert FFN (per-expert SparseLinear), batched over groups ---
    apply_e = lambda prm, z: jax.vmap(_stacked_linear_apply, in_axes=(None, 0))(prm, z)
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(apply_e(params["gate"], buf)) * apply_e(params["up"], buf)
    else:
        h = jnp.square(jax.nn.relu(apply_e(params["up"], buf)))
    h = shd(h, None, "act_expert", None, None)
    out_buf = apply_e(params["down"], h)  # [G, E, C, d]
    # expert-sharded -> group-sharded: the return all-to-all
    out_buf = shd(out_buf, "act_moe_group", None, None, None)

    def combine(ob, ef, ps, kp, tp):
        gathered = ob[ef, jnp.minimum(ps, cap - 1)]
        gathered = gathered * kp[:, None].astype(ob.dtype)
        w = tp.reshape(-1)[:, None].astype(ob.dtype)
        return (gathered * w).reshape(tg, k, d).sum(axis=1)

    y = jax.vmap(combine)(out_buf, e_flat, pos, keep, top_p)  # [G, Tg, d]
    return y.reshape(b, s, d), aux
