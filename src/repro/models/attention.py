"""GQA attention with tensor-parallel head padding, RoPE/M-RoPE, KV cache.

Design notes (distribution):
  - q heads are padded to a multiple of tp; the padded heads' o_proj rows are
    zero so the function is exactly the unpadded one.
  - kv heads are sharded over the model axis only when divisible (the logical
    rules drop the axis otherwise) — for small GQA archs the kv tensors are
    tiny and replication is cheaper than the reshard.
  - GQA is computed with a grouped einsum (q reshaped [B,S,KV,G,D]) so the KV
    tensors are never materialized at H width — essential for 32k/512k decode
    caches.  Only the padded-head case where H % KV != 0 falls back to an
    explicit head-mapped expansion (small archs only).
  - decode attends one query against a [B, S_max, KV, D] cache: O(S) work.
    For long_500k the cache's seq dim carries the 'act_kv_seq' logical axis so
    GSPMD shards it over the otherwise-idle data axis (distributed
    flash-decode); scores at 512k, B=1 are ~64 MB in f32.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import Boxed, linear_apply, linear_init
from repro.models.common import apply_rope, mrope_cos_sin, rope_cos_sin
from repro.sharding import shd


def attn_init(key, cfg: ModelConfig, *, cross: bool = False):
    """QKV/O projections (each a SparseLinear; o proj is reduce-oriented)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.padded_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    scfg = cfg.sparsity
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "q": linear_init(ks[0], d, h * hd, scfg, dtype=dtype, use_bias=cfg.qkv_bias,
                         in_ax="embed", out_ax="heads_flat"),
        "k": linear_init(ks[1], d, kv * hd, scfg, dtype=dtype, use_bias=cfg.qkv_bias,
                         in_ax="embed", out_ax="kv_flat"),
        "v": linear_init(ks[2], d, kv * hd, scfg, dtype=dtype, use_bias=cfg.qkv_bias,
                         in_ax="embed", out_ax="kv_flat"),
        "o": linear_init(ks[3], h * hd, d, scfg, dtype=dtype,
                         in_ax="heads_flat", out_ax="embed", mode="reduce"),
    }
    if cfg.n_heads != cfg.padded_heads and "w" in p["o"]:
        # zero the padded heads' output rows => exact numerics
        ow = p["o"]["w"]
        w = ow.value.reshape(h, hd, d)
        w = w.at[cfg.n_heads:].set(0.0)
        p["o"]["w"] = Boxed(w.reshape(h * hd, d), ow.spec)
    return p


def _qkv(params, cfg: ModelConfig, x: jax.Array, positions, mrope_positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.padded_heads, cfg.n_kv_heads
    q = linear_apply(params["q"], x).reshape(b, s, h, hd)
    k = linear_apply(params["k"], x).reshape(b, s, kv, hd)
    v = linear_apply(params["v"], x).reshape(b, s, kv, hd)
    if cfg.use_rope:
        if cfg.mrope and mrope_positions is not None:
            cos, sin = mrope_cos_sin(mrope_positions, hd, cfg.rope_theta, cfg.mrope_sections)
        else:
            cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _expand_kv(k: jax.Array, n_q_heads: int) -> jax.Array:
    """Head-mapped expansion [B,S,KV,D] -> [B,S,H,D]; fallback for H%KV!=0."""
    kvh = k.shape[2]
    if n_q_heads == kvh:
        return k
    mapping = (jnp.arange(n_q_heads) * kvh) // n_q_heads
    return jnp.take(k, mapping, axis=2)


def sdpa_gqa(q, k, v, *, causal: bool, q_offset=0, kv_len=None) -> jax.Array:
    """Scaled dot-product attention with native GQA grouping.

    q: [B, Sq, H, D]; k/v: [B, Sk, KV, D]. Returns [B, Sq, H, D].
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(d)
    if h % kvh != 0:
        k = _expand_kv(k, h)
        v = _expand_kv(v, h)
        kvh = h
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(sk)[None, :]
        scores = jnp.where(ki <= qi, scores, -1e30)
    if kv_len is not None:
        ki = jnp.arange(sk).reshape(1, 1, 1, 1, sk)
        scores = jnp.where(ki < kv_len.reshape(b, 1, 1, 1, 1), scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return o.reshape(b, sq, h, d)


def sdpa_gqa_chunked(
    q, k, v, *, causal: bool, q_offset=0, kv_len=None, chunk: int = 512
) -> jax.Array:
    """Blockwise (flash-style) attention: online softmax over KV chunks.

    The [Sq, Sk] score matrix never materializes — the dry-run showed it is
    both the dominant HBM traffic AND the source of TB-scale involuntary
    all-gathers in the backward (GSPMD cannot reshard the giant score tensor
    between the differently-sharded fwd/bwd dots).  Per chunk we expand KV to
    the full (padded) head count, so every tensor stays head-sharded over the
    model axis — no resharding, and the expansion lives only at chunk scale.

    q: [B, Sq, H, D]; k/v: [B, Sk, KV, D]. Returns [B, Sq, H, D].
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    scale = 1.0 / math.sqrt(d)
    chunk = min(chunk, sk)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    mapping = (jnp.arange(h) * kvh) // h if h % kvh else None
    kc = k.reshape(b, n_chunks, chunk, kvh, d)
    vc = v.reshape(b, n_chunks, chunk, kvh, d)
    qi = jnp.arange(sq)[:, None] + q_offset  # [Sq,1]
    f32 = jnp.float32

    def body(carry, xs):
        m, l, acc = carry  # [B,H,Sq], [B,H,Sq], [B,Sq,H,D] (f32)
        kx, vx, ci = xs  # [B,chunk,KV,D], [B,chunk,KV,D], scalar chunk idx
        if mapping is not None:
            kx = jnp.take(kx, mapping, axis=2)
            vx = jnp.take(vx, mapping, axis=2)
        elif h != kvh:
            kx = jnp.repeat(kx, h // kvh, axis=2)
            vx = jnp.repeat(vx, h // kvh, axis=2)
        kx = shd(kx, "act_batch", None, "act_heads", None)
        s = jnp.einsum("bqhd,bchd->bhqc", q, kx).astype(f32) * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]  # [1,chunk]
        valid = jnp.ones((sq, chunk), bool) if not causal else (kpos <= qi)
        valid = valid & (kpos < sk)
        if kv_len is not None:
            valid = valid[None] & (kpos[None] < kv_len[:, None, None])
            s = jnp.where(valid[:, None], s, -1e30)
        else:
            s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])  # [B,H,Sq,chunk] f32
        alpha = jnp.exp(m - m_new)  # [B,H,Sq]
        l_new = alpha * l + p.sum(axis=-1)
        pv = jnp.einsum("bhqc,bchd->bqhd", p.astype(vx.dtype), vx).astype(f32)
        acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    carry0 = (
        jnp.full((b, h, sq), -1e30, f32),
        jnp.zeros((b, h, sq), f32),
        jnp.zeros((b, sq, h, d), f32),
    )
    xs = (
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.arange(n_chunks),
    )
    # checkpoint the body: backward recomputes per-chunk scores instead of
    # stashing them (the whole point of going blockwise)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), carry0, xs)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attn_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    mrope_positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full self-attention (training / prefill without cache)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, cfg, x, positions, mrope_positions)
    q = shd(q, "act_batch", None, "act_heads", None)
    k = shd(k, "act_batch", None, "act_kv_heads", None)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attn import flash_attention

        o = flash_attention(q, k, v, causal=causal)
    elif cfg.attn_impl == "chunked" and s > cfg.attn_chunk:
        o = sdpa_gqa_chunked(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    else:
        o = sdpa_gqa(q, k, v, causal=causal)
    o = o.reshape(b, s, -1)
    return linear_apply(params["o"], o)


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_apply(params, cfg: ModelConfig, x: jax.Array, enc_kv) -> jax.Array:
    """x [B,Sq,d]; enc_kv = (k, v) precomputed from encoder output (no RoPE)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear_apply(params["q"], x).reshape(b, s, cfg.padded_heads, hd)
    k, v = enc_kv
    o = sdpa_gqa(q, k, v, causal=False).reshape(b, s, -1)
    return linear_apply(params["o"], o)


def cross_kv(params, cfg: ModelConfig, enc_out: jax.Array):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = linear_apply(params["k"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear_apply(params["v"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


def cache_init(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec_names():
    """Logical names per cache dim [L, B, S, KV, D]."""
    return (None, "act_batch", "act_kv_seq", "act_kv_heads", None)


def _cached_attention(q, k_new, v_new, kc, vc, *, limit, causal: bool):
    """softmax over (cache rows < limit[b]) ++ this step's new keys.

    q [B,C,H,D]; k_new/v_new [B,C,KV,D]; kc/vc [B,S_max,KV,D]; limit [B]
    int32.  ``causal`` masks the new keys intra-chunk (j <= i); cache rows
    >= limit may hold stale junk (a freed slot's previous occupant) and are
    always masked.  Shared by one-token decode (C=1, causal irrelevant) and
    chunked prefill.  Returns o [B,C,H,D].
    """
    b, c_len, h, d = q.shape
    kvh = kc.shape[2]
    s_max = kc.shape[1]
    scale = 1.0 / math.sqrt(d)
    f32 = jnp.float32
    qi = jnp.arange(c_len, dtype=jnp.int32)

    if h % kvh == 0:
        g = h // kvh
        qg = q.reshape(b, c_len, kvh, g, d)
        s_c = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc.astype(q.dtype)).astype(f32) * scale
        ki = jnp.arange(s_max).reshape(1, 1, 1, 1, -1)
        s_c = jnp.where(ki < limit.reshape(b, 1, 1, 1, 1), s_c, -1e30)
        s_n = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_new.astype(q.dtype)).astype(f32) * scale
        if causal and c_len > 1:
            mask = (qi[None, :] <= qi[:, None]).reshape(1, 1, 1, c_len, c_len)
            s_n = jnp.where(mask, s_n, -1e30)
        w = jax.nn.softmax(jnp.concatenate([s_c, s_n], axis=-1), axis=-1)
        w = w.astype(q.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w[..., :s_max], vc.astype(q.dtype))
        o = o + jnp.einsum("bkgqs,bskd->bqkgd", w[..., s_max:],
                           v_new.astype(q.dtype))
        return o.reshape(b, c_len, h, d)

    kx = _expand_kv(kc, h).astype(q.dtype)
    vx = _expand_kv(vc, h).astype(q.dtype)
    s_c = jnp.einsum("bqhd,bshd->bhqs", q, kx).astype(f32) * scale
    ki = jnp.arange(s_max).reshape(1, 1, 1, -1)
    s_c = jnp.where(ki < limit.reshape(b, 1, 1, 1), s_c, -1e30)
    kn = _expand_kv(k_new, h).astype(q.dtype)
    vn = _expand_kv(v_new, h).astype(q.dtype)
    s_n = jnp.einsum("bqhd,bshd->bhqs", q, kn).astype(f32) * scale
    if causal and c_len > 1:
        mask = (qi[None, :] <= qi[:, None]).reshape(1, 1, c_len, c_len)
        s_n = jnp.where(mask, s_n, -1e30)
    w = jax.nn.softmax(jnp.concatenate([s_c, s_n], axis=-1), axis=-1)
    w = w.astype(q.dtype)
    o = jnp.einsum("bhqs,bshd->bqhd", w[..., :s_max], vx)
    o = o + jnp.einsum("bhqs,bshd->bqhd", w[..., s_max:], vn)
    return o


def attn_decode(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    layer_cache: Tuple[jax.Array, jax.Array],
    *,
    pos: jax.Array,
    mrope_positions: Optional[jax.Array] = None,
):
    """One-token decode against a READ-ONLY cache slice.

    x [B, 1, d]; layer_cache (k, v): [B, S_max, KV, D]; pos: scalar int32 OR
    per-sequence [B] int32 (continuous batching: every slot sits at its own
    length).  Returns (out, (k_new [B,1,KV,D], v_new)) — the caller writes the
    new token into the stacked cache with ONE batched dynamic-update-slice
    after the layer scan.  Updating inside the scan made XLA stack a full
    cache copy per layer as scan outputs (2 x 7 TB/chip/token measured on
    qwen2-vl-72b decode_32k; EXPERIMENTS §Perf iteration J).

    Attention = online-softmax combine of (cache positions < pos) with the
    new token at pos — identical math to write-then-attend(pos+1).
    """
    b = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
    q, k_new, v_new = _qkv(params, cfg, x, pos_b[:, None], mrope_positions)
    kc, vc = layer_cache
    o = _cached_attention(q, k_new, v_new, kc, vc, limit=pos_b, causal=False)
    o = o.reshape(b, 1, -1)
    return linear_apply(params["o"], o), (k_new, v_new)


def attn_prefill_chunk(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    layer_cache: Tuple[jax.Array, jax.Array],
    *,
    start: jax.Array,
    mrope_positions: Optional[jax.Array] = None,
):
    """Chunked prefill through one layer against a preallocated cache.

    x [B, C, d] holds tokens at absolute positions [start, start+C);
    layer_cache (k, v): [B, S_max, KV, D] holds this sequence's earlier
    chunks in rows < start.  Attention = softmax over (cache rows < start)
    ++ (causal intra-chunk).  Returns (out, (k_chunk [B,C,KV,D], v_chunk));
    as with decode, the caller commits the chunk's K/V with ONE stacked
    :func:`cache_write` after the layer scan.
    """
    b, c_len = x.shape[:2]
    qi = jnp.arange(c_len, dtype=jnp.int32)
    positions = jnp.broadcast_to(start + qi[None, :], (b, c_len))
    q, k_new, v_new = _qkv(params, cfg, x, positions, mrope_positions)
    kc, vc = layer_cache
    start_b = jnp.broadcast_to(jnp.reshape(jnp.asarray(start, jnp.int32), (-1,)), (b,))
    o = _cached_attention(q, k_new, v_new, kc, vc, limit=start_b, causal=True)
    o = o.reshape(b, c_len, -1)
    return linear_apply(params["o"], o), (k_new, v_new)


def cache_write(cache_k, cache_v, k_news, v_news, pos):
    """One batched in-place write of the step's new K/V into the stacked
    cache. cache_*: [L, B, S, KV, D]; *_news: [L, B, C, KV, D] (C = 1 for
    decode, C = chunk length for chunked prefill).

    ``pos`` is the scalar row where the write starts, or a per-sequence [B]
    vector (continuous batching: every slot writes at its own length; C must
    be 1).  Starts are clamped by dynamic_update_slice semantics, so an idle
    slot parked at its last row can never write out of bounds.
    """
    pos = jnp.asarray(pos, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    if pos.ndim == 0:
        idx = (zero, zero, pos, zero, zero)
        k2 = jax.lax.dynamic_update_slice(cache_k, k_news.astype(cache_k.dtype), idx)
        v2 = jax.lax.dynamic_update_slice(cache_v, v_news.astype(cache_v.dtype), idx)
        return k2, v2

    def write1(cache, news, p):  # [L, S, KV, D], [L, C, KV, D], scalar
        return jax.lax.dynamic_update_slice(cache, news, (zero, p, zero, zero))

    k2 = jax.vmap(write1, in_axes=(1, 1, 0), out_axes=1)(
        cache_k, k_news.astype(cache_k.dtype), pos)
    v2 = jax.vmap(write1, in_axes=(1, 1, 0), out_axes=1)(
        cache_v, v_news.astype(cache_v.dtype), pos)
    return k2, v2


# ---------------------------------------------------------------------------
# Paged KV cache (repro.serve.kv_pages memory tier)
# ---------------------------------------------------------------------------


def paged_cache_init(cfg: ModelConfig, n_pages: int, page_size: int,
                     n_layers: int, dtype):
    """Physical paged cache: [L, n_pages + 1, page_size, KV, D].

    The extra page at index ``n_pages`` is the trash page — the write
    target for padded page-table entries (inactive slots, rows past a
    sequence's mapping). It may hold arbitrary junk; reads are always
    masked by the per-sequence length, so nothing ever attends to it.
    """
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, n_pages + 1, page_size, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def page_rows(tables, seq_idx, pos, page_size: int):
    """Physical flat row index for each (sequence, position) pair.

    tables [n_slots, n_max] int32; seq_idx [N] slot per token; pos [N]
    logical position. Returns [N] int32 indices into the
    ``[P * page_size]``-row flattened view of the paged cache.
    """
    pos = jnp.asarray(pos, jnp.int32)
    page_id = tables[seq_idx, pos // page_size]
    return page_id * page_size + pos % page_size


def paged_cache_write(cache_k, cache_v, k_news, v_news, rows):
    """Scatter the step's new K/V through page-table rows.

    cache_*: [L, P, page_size, KV, D]; *_news: [L, N, KV, D]; rows: [N]
    flat physical row per token (from :func:`page_rows`). Inactive slots'
    rows all alias the trash page — duplicate scatter targets there are
    fine because those rows are never read.
    """
    l, p, ps, kv, hd = cache_k.shape
    fk = cache_k.reshape(l, p * ps, kv, hd)
    fv = cache_v.reshape(l, p * ps, kv, hd)
    fk = fk.at[:, rows].set(k_news.astype(cache_k.dtype))
    fv = fv.at[:, rows].set(v_news.astype(cache_v.dtype))
    return fk.reshape(cache_k.shape), fv.reshape(cache_v.shape)


def paged_attn_decode(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    layer_cache: Tuple[jax.Array, jax.Array],
    *,
    pos: jax.Array,
    tables: jax.Array,
    page_size: int,
):
    """One-token decode against a paged READ-ONLY cache.

    x [B, 1, d]; layer_cache (k_pages, v_pages): [P, page_size, KV, D];
    pos [B] int32 per-slot lengths; tables [B, n_max] int32 page tables.
    Same no-write-in-scan contract as :func:`attn_decode` — returns
    (out, (k_new, v_new)) and the caller scatters through the page table
    once after the layer scan.
    """
    b = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,)), (b,))
    q, k_new, v_new = _qkv(params, cfg, x, pos_b[:, None], None)
    kc, vc = layer_cache
    from repro.kernels.flash_attn import paged_attention

    o = paged_attention(q, k_new, v_new, kc, vc, tables, pos_b,
                        page_size=page_size)
    o = o.reshape(b, 1, -1)
    return linear_apply(params["o"], o), (k_new, v_new)


def packed_sdpa(q, k, v, *, seq_ids) -> jax.Array:
    """Block-diagonal causal attention over one packed token stream.

    q [1, T, H, D]; k/v [1, T, KV, D]; seq_ids [T] int32 — token t may
    attend to token s iff they share a sequence and s <= t (prompts are
    stream-contiguous with increasing positions, so stream order IS causal
    order). This is the padding-free prefill: no masked-out pad columns,
    zero wasted attention FLOPs.
    """
    b, t, h, d = q.shape
    kvh = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    same = seq_ids[:, None] == seq_ids[None, :]
    causal = jnp.arange(t)[None, :] <= jnp.arange(t)[:, None]
    mask = same & causal
    if h % kvh == 0:
        g = h // kvh
        qg = q.reshape(b, t, kvh, g, d)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                       k.astype(q.dtype)).astype(jnp.float32) * scale
        s = jnp.where(mask.reshape(1, 1, 1, t, t), s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(q.dtype))
        return o.reshape(b, t, h, d)
    kx = _expand_kv(k, h).astype(q.dtype)
    vx = _expand_kv(v, h).astype(q.dtype)
    s = jnp.einsum("bqhd,bshd->bhqs", q, kx).astype(jnp.float32) * scale
    s = jnp.where(mask.reshape(1, 1, t, t), s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", w, vx)


def attn_prefill_packed(params, cfg: ModelConfig, x: jax.Array, *,
                        seq_ids: jax.Array, positions: jax.Array):
    """Packed multi-prompt prefill through one layer (no cache read).

    x [1, T, d] is the concatenated stream; seq_ids/positions [T].
    Returns (out [1, T, d'], (k [1,T,KV,D], v)) — the caller scatters all
    K/V through the page tables after the layer scan.
    """
    q, k_new, v_new = _qkv(params, cfg, x, positions[None, :], None)
    o = packed_sdpa(q, k_new, v_new, seq_ids=seq_ids)
    t = x.shape[1]
    o = o.reshape(1, t, -1)
    return linear_apply(params["o"], o), (k_new, v_new)
