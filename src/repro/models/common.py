"""Shared model components: norms, RoPE (incl. M-RoPE), embeddings."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_linear import Boxed


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(d: int, kind: str, dtype=jnp.float32):
    p = {"scale": Boxed(jnp.ones((d,), dtype), (None,))}
    if kind == "layernorm":
        p["bias"] = Boxed(jnp.zeros((d,), dtype), (None,))
    return p


def norm_apply(params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions [..., S] -> cos/sin [..., S, head_dim//2] (float32)."""
    freqs = jnp.asarray(rope_freqs(head_dim, theta))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [B, S, H, D]; cos/sin [B, S, D/2] (broadcast over heads).

    Rotates pairs (x[..., :D/2], x[..., D/2:]) — the 'NeoX' convention used by
    the Llama/Qwen family.
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(x.dtype)


def mrope_cos_sin(
    positions_3: jax.Array, head_dim: int, theta: float, sections: Tuple[int, ...]
):
    """Qwen2-VL multimodal RoPE. positions_3: [B, 3, S] (temporal, h, w).

    The head_dim/2 frequency slots are partitioned into ``sections`` (summing
    to head_dim/2); each section takes its rotation angle from the matching
    position component. Text tokens carry identical components, reducing to
    1-D RoPE exactly.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = jnp.asarray(rope_freqs(head_dim, theta))  # [D/2]
    ang = positions_3.astype(jnp.float32)[..., None] * freqs  # [B, 3, S, D/2]
    section_id = np.repeat(np.arange(len(sections)), sections)  # [D/2]
    onehot = jnp.asarray(
        np.eye(len(sections), dtype=np.float32)[section_id].T
    )  # [3, D/2]
    ang_sel = jnp.einsum("bksf,kf->bsf", ang, onehot)  # pick component per slot
    return jnp.cos(ang_sel), jnp.sin(ang_sel)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embeddings [n, d]."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = np.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    e = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return Boxed(e, ("vocab", "embed"))


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)
