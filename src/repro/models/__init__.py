from repro.models.registry import (  # noqa: F401
    abstract_cache,
    abstract_params,
    batch_specs,
    cache_init_fn,
    cache_specs,
    decode_fn,
    forward_fn,
    init_fn,
    init_params,
    input_specs,
    loss_fn,
    prefill_fn,
)
