"""MLP variants over SparseLinear: SwiGLU (llama/qwen family), squared-ReLU
(nemotron), GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import linear_apply, linear_init
from repro.sharding import shd


def mlp_init(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    scfg = cfg.sparsity
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.mlp_act == "swiglu":
        p["gate"] = linear_init(ks[0], d, f, scfg, dtype=dtype, in_ax="embed", out_ax="ffn")
        p["up"] = linear_init(ks[1], d, f, scfg, dtype=dtype, in_ax="embed", out_ax="ffn")
    else:
        p["up"] = linear_init(ks[1], d, f, scfg, dtype=dtype, in_ax="embed", out_ax="ffn")
    p["down"] = linear_init(ks[2], f, d, scfg, dtype=dtype, in_ax="ffn", out_ax="embed",
                            mode="reduce")
    return p


def mlp_apply(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_act == "swiglu":
        g = linear_apply(params["gate"], x)
        u = linear_apply(params["up"], x)
        h = jax.nn.silu(g) * u
    elif cfg.mlp_act == "sq_relu":
        h = jnp.square(jax.nn.relu(linear_apply(params["up"], x)))
    else:  # gelu
        h = jax.nn.gelu(linear_apply(params["up"], x), approximate=True)
    h = shd(h, "act_batch", None, "act_ffn")
    return linear_apply(params["down"], h)
