"""Decoder blocks assembled from attention / MLP / MoE / SSM / xLSTM parts,
plus the parameter-stacking helper used for scan-over-layers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_linear import Boxed, box_map, linear_apply, linear_init
from repro.models import attention as attn
from repro.models.common import norm_apply, norm_init
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.sharding import shd


def _is_boxed(x):
    return isinstance(x, Boxed)


def stack_init(init_fn, key, n: int):
    """Stack n copies of init_fn's params along a leading 'layers' axis."""
    ks = jax.random.split(key, n)
    proto = init_fn(ks[0])

    def values_only(k):
        return box_map(lambda b: b.value, init_fn(k))

    vals = jax.vmap(values_only)(ks)
    return jax.tree_util.tree_map(
        lambda b, v: Boxed(v, ("layers",) + b.spec), proto, vals, is_leaf=_is_boxed
    )


# ---------------------------------------------------------------------------
# Standard transformer decoder block (attn + mlp/moe)
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn.attn_init(ks[0], cfg),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def block_apply(params, cfg: ModelConfig, h, *, positions, mrope_positions=None,
                causal=True):
    """Returns (h, aux_loss).

    SP boundary note (EXPERIMENTS §Perf iteration C, refuted hypothesis):
    gathering the bf16 residual *before* the norm cut the f32 boundary
    all-gathers (6.1->5.5s collective) but doubled the memory term — the
    norm then runs on the full gathered sequence and the full-seq residual
    is rematerialized.  Norm-on-sharded-sequence (Megatron-SP order) wins.
    """
    x = norm_apply(params["ln1"], h, cfg.norm)
    x = shd(x, "act_batch", None, "act_embed")  # SP all-gather boundary
    h = h + attn.attn_apply(
        params["attn"], cfg, x, positions=positions,
        mrope_positions=mrope_positions, causal=causal,
    )
    h = shd(h, "act_batch", "act_seq_sp", None)
    x = norm_apply(params["ln2"], h, cfg.norm)
    x = shd(x, "act_batch", None, "act_embed")
    if cfg.is_moe:
        if cfg.moe_impl == "shard_map":
            from repro.models.moe import moe_apply_shard_map

            y, aux = moe_apply_shard_map(params["moe"], cfg, x)
        else:
            y, aux = moe_apply(params["moe"], cfg, x)
    else:
        y, aux = mlp_apply(params["mlp"], cfg, x), jnp.zeros((), jnp.float32)
    h = h + y
    h = shd(h, "act_batch", "act_seq_sp", None)
    return h, aux


def block_decode(params, cfg: ModelConfig, h, layer_cache, *, pos,
                 mrope_positions=None):
    """One-token decode through a transformer block. Returns (h, new_cache)."""
    x = norm_apply(params["ln1"], h, cfg.norm)
    a, new_cache = attn.attn_decode(
        params["attn"], cfg, x, layer_cache, pos=pos, mrope_positions=mrope_positions
    )
    h = h + a
    x = norm_apply(params["ln2"], h, cfg.norm)
    if cfg.is_moe:
        if cfg.moe_impl == "shard_map":
            from repro.models.moe import moe_apply_shard_map

            y, _ = moe_apply_shard_map(params["moe"], cfg, x)
        else:
            y, _ = moe_apply(params["moe"], cfg, x)
    else:
        y = mlp_apply(params["mlp"], cfg, x)
    return h + y, new_cache


def block_prefill_chunk(params, cfg: ModelConfig, h, layer_cache, *, start,
                        mrope_positions=None):
    """Chunked prefill through a transformer block: h [B, C, d] at absolute
    positions [start, start+C) against a preallocated layer cache.
    Returns (h, (k_chunk, v_chunk))."""
    x = norm_apply(params["ln1"], h, cfg.norm)
    a, kv_new = attn.attn_prefill_chunk(
        params["attn"], cfg, x, layer_cache, start=start,
        mrope_positions=mrope_positions,
    )
    h = h + a
    x = norm_apply(params["ln2"], h, cfg.norm)
    if cfg.is_moe:
        if cfg.moe_impl == "shard_map":
            from repro.models.moe import moe_apply_shard_map

            y, _ = moe_apply_shard_map(params["moe"], cfg, x)
        else:
            y, _ = moe_apply(params["moe"], cfg, x)
    else:
        y = mlp_apply(params["mlp"], cfg, x)
    return h + y, kv_new


# ---------------------------------------------------------------------------
# Zamba2 shared attention block (one set of weights reused across the stack)
# ---------------------------------------------------------------------------


def shared_block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        # Zamba concatenates the current hidden with the original embedding;
        # we fuse [2d -> d] before the shared transformer block (see DESIGN).
        "fuse": linear_init(ks[0], 2 * cfg.d_model, cfg.d_model, cfg.sparsity,
                            dtype=dtype, in_ax="embed", out_ax="embed2"),
        "block": block_init(ks[1], cfg),
    }


def shared_block_apply(params, cfg: ModelConfig, h, h0, *, positions):
    x = jnp.concatenate([h, h0], axis=-1)
    x = linear_apply(params["fuse"], x)
    out, _ = block_apply(params["block"], cfg, x, positions=positions)
    return h + out


def shared_block_decode(params, cfg: ModelConfig, h, h0, layer_cache, *, pos):
    x = jnp.concatenate([h, h0], axis=-1)
    x = linear_apply(params["fuse"], x)
    out, new_cache = block_decode(params["block"], cfg, x, layer_cache, pos=pos)
    return h + out, new_cache


def block_paged_decode(params, cfg: ModelConfig, h, layer_cache, *, pos,
                       tables, page_size: int):
    """One-token decode through a transformer block against a paged cache.

    layer_cache (k_pages, v_pages): [P, page_size, KV, D]; pos [B]; tables
    [B, n_max].  Returns (h, (k_new, v_new)) — the caller scatters through
    the page table after the layer scan (same contract as block_decode).
    """
    x = norm_apply(params["ln1"], h, cfg.norm)
    a, new_kv = attn.paged_attn_decode(
        params["attn"], cfg, x, layer_cache, pos=pos, tables=tables,
        page_size=page_size,
    )
    h = h + a
    x = norm_apply(params["ln2"], h, cfg.norm)
    if cfg.is_moe:
        if cfg.moe_impl == "shard_map":
            from repro.models.moe import moe_apply_shard_map

            y, _ = moe_apply_shard_map(params["moe"], cfg, x)
        else:
            y, _ = moe_apply(params["moe"], cfg, x)
    else:
        y = mlp_apply(params["mlp"], cfg, x)
    return h + y, new_kv


def block_prefill_packed(params, cfg: ModelConfig, h, *, seq_ids, positions):
    """Packed multi-prompt prefill through a transformer block.

    h [1, T, d] is the concatenated padding-free stream; seq_ids/positions
    [T].  Returns (h, (k [1,T,KV,D], v)); the caller scatters the stream's
    K/V through the page tables after the layer scan.
    """
    x = norm_apply(params["ln1"], h, cfg.norm)
    a, kv_new = attn.attn_prefill_packed(
        params["attn"], cfg, x, seq_ids=seq_ids, positions=positions,
    )
    h = h + a
    x = norm_apply(params["ln2"], h, cfg.norm)
    if cfg.is_moe:
        if cfg.moe_impl == "shard_map":
            from repro.models.moe import moe_apply_shard_map

            y, _ = moe_apply_shard_map(params["moe"], cfg, x)
        else:
            y, _ = moe_apply(params["moe"], cfg, x)
    else:
        y = mlp_apply(params["mlp"], cfg, x)
    return h + y, kv_new
