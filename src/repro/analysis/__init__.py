"""Cross-layer contract checker: AST lints for the repo's hardware-facing
conventions (Pallas DMA protocol, dispatch VMEM predicates, fault-site /
obs-name / env-knob registries).  ``python -m repro.analysis src`` is the
CI gate; see ``docs/static-analysis.md`` for the rule catalog."""
from repro.analysis.engine import (Context, Finding, Report, Rule, all_rules,
                                   find_root, iter_py_files, load_baseline,
                                   render_json, render_text, run)

__all__ = ["Context", "Finding", "Report", "Rule", "all_rules", "find_root",
           "iter_py_files", "load_baseline", "render_json", "render_text",
           "run"]
