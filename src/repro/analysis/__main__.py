"""CLI: ``python -m repro.analysis [paths...] [--json] [--baseline F]``.

Exit codes: 0 clean, 1 findings (or stale baseline waivers), 2 bad usage.
The default baseline is the committed ``src/repro/analysis/baseline.json``;
``--no-baseline`` audits the raw findings.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import engine

_DEFAULT_BASELINE = Path(__file__).parent / "baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="cross-layer contract checker (see docs/static-analysis.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to scan (default: the repo's src/)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report (deterministic bytes)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"waiver file (default {_DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the committed baseline")
    ap.add_argument("--only", default=None,
                    help="comma-separated rule ids to run (e.g. PK101,RC203)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in engine.all_rules():
            print(f"{rule.id}  {rule.title}")
        return 0

    paths = [Path(p) for p in args.paths] if args.paths else None
    if paths is None:
        root = engine.find_root(Path.cwd())
        if root is None:
            print("error: no paths given and no repo root found "
                  "(run from the repo or pass paths)", file=sys.stderr)
            return 2
        paths = [root / "src"]
    for p in paths:
        if not p.exists():
            print(f"error: no such path {p}", file=sys.stderr)
            return 2

    baseline = {} if args.no_baseline else engine.load_baseline(
        args.baseline if args.baseline is not None else _DEFAULT_BASELINE)
    only = args.only.split(",") if args.only else None
    report = engine.run(paths, only=only, baseline=baseline)
    print(engine.render_json(report) if args.as_json
          else engine.render_text(report))
    return 1 if (report.findings or report.unused_waivers) else 0


if __name__ == "__main__":
    sys.exit(main())
