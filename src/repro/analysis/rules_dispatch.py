"""Dispatch-predicate consistency (DP3xx): VMEM predicates vs. kernels.

PR 3 shipped the canonical bug this family exists for: a VMEM-feasibility
predicate that assumed bf16 operands under-counted the resident footprint
2x for f32 keys, so the dispatcher admitted a megakernel whose whole-map
scratch could not fit.  These rules recompute each registered pallas
candidate's footprint **independently** — straight from the kernel modules'
analytic ``*_vmem_bytes`` functions (which are derived from the literal
``scratch_shapes``/``BlockSpec`` the kernels allocate), with the byte width
taken from the probe key's dtype and both halves of every double buffer
counted — and compare against what the registry's ``vmem_bytes``/
``feasible`` claim, over a grid of representative OpKeys x dtypes.

These are *project* rules: they import the live registry, so they only run
when the analyzed tree contains the real ``src/repro`` package.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

from repro.analysis.engine import Context, Rule, register

_REGISTRY_PATH = "src/repro/dispatch/registry.py"


def _itemsize(key) -> int:
    # the independent statement of the dtype law; if the registry's
    # _key_itemsize ever regresses to a constant, this disagrees and fires
    return 4 if key.dtype == "f32" else 2


def probe_keys(R) -> List:
    """Representative OpKeys per op: small/large x f32/bf16, plus one
    deliberately over-budget shape per family so the feasible() rejection
    boundary is exercised too."""
    keys = []
    for dt in ("float32", "bfloat16"):
        keys.append(R.linear_key(8, 512, 512, 128, 128, dt))
        keys.append(R.linear_key(256, 2048, 1024, 256, 128, dt))
        keys.append(R.conv_key(16, 28, 28, 128, 3, 3, 1, 1, 72, 128,
                               v=128, dtype=dt, batch=1))
        keys.append(R.conv_key(32, 56, 56, 256, 3, 3, 1, 1, 144, 128,
                               v=128, dtype=dt, batch=4))
        keys.append(R.paged_attn_key(8, 8, 2, 64, 256, page_size=0, dtype=dt))
        keys.append(R.paged_attn_key(8, 8, 2, 64, 256, page_size=16,
                                     dtype=dt))
    # over-budget probes: the whole-map megakernel cannot hold a stem-scale
    # f32 map, and no block geometry holds a 2M-wide reduction
    keys.append(R.linear_key(512, 1 << 21, 512, 128, 128, "float32"))
    keys.append(R.conv_key(64, 224, 224, 128, 7, 7, 2, 3, 288, 128,
                           v=128, dtype="float32", batch=8))
    return keys


def recompute_vmem(spec, key) -> Optional[int]:
    """The kernel-side footprint for ``spec`` at ``key``: the analytic
    byte-count colocated with each kernel's scratch allocation, evaluated
    with a locally derived (dtype-aware) element size.  None for families
    with no VMEM-resident kernel (xla) or unknown families."""
    from repro.kernels.colwise_nm import kernel as ck
    from repro.kernels.conv_gemm import kernel as gk
    from repro.kernels.flash_attn import paged as pk
    from repro.kernels.im2col_pack.ref import out_size

    family = spec.name.split("@")[0]
    geom = dict(spec.geometry)
    ib = _itemsize(key)
    tile = min(key.tile, 512)
    if family == "compressed_pallas":
        return ck.vmem_bytes(min(geom.get("bb", 128), key.batch),
                             min(geom.get("bk", 128), key.k_kept),
                             key.d_in, tile, in_bytes=ib)
    if family == "im2col_sparse_pallas":
        return ck.strips_vmem_bytes(key.d_in, key.get("v", 128),
                                    min(128, key.k_kept), tile, in_bytes=ib)
    if family == "fused_sparse_pallas":
        return gk.fused_vmem_bytes(
            key.get("c"), max(key.get("b", 1), 1), key.get("h"),
            key.get("w", key.get("h")), geom["v"],
            min(geom["bk"], key.k_kept), tile, in_bytes=ib)
    if family == "fused_banded_pallas":
        c, h = key.get("c"), key.get("h")
        w = key.get("w", h)
        b = max(key.get("b", 1), 1)
        ho = out_size(h, key.get("kh"), key.get("s", 1), key.get("p", 0))
        wo = out_size(w, key.get("kw"), key.get("s", 1), key.get("p", 0))
        _, band_rows = band_rows_for(gk, b, h, key, ho, wo, geom)
        return gk.banded_vmem_bytes(c, w, band_rows, geom["v"],
                                    min(geom["bk"], key.k_kept), tile,
                                    in_bytes=ib)
    if family == "two_kernel_pipelined":
        return ck.pipelined_strips_vmem_bytes(
            key.d_in, geom["v"], geom["hb"], min(geom["bk"], key.k_kept),
            tile, in_bytes=ib)
    if family == "paged_attn_pallas":
        hd = key.get("hd", key.d_in)
        kv = max(key.k_kept, 1)
        h = key.d_out // max(hd, 1)
        return pk.paged_vmem_bytes(geom["ps"], kv, hd, geom["bq"], h,
                                   sn=geom["bq"], in_bytes=ib)
    return None


def band_rows_for(gk, b, h, key, ho, wo, geom) -> Tuple[int, int]:
    return gk.band_plan(b=b, h=h, kh=key.get("kh"), stride=key.get("s", 1),
                        pad=key.get("p", 0), ho=ho, wo=wo, v=geom["v"],
                        hb=geom["hb"])


def _audit_pairs(ctx: Context):
    if ctx.root is None or not (ctx.root / _REGISTRY_PATH).is_file():
        return None, ()
    from repro.dispatch import registry as R

    pairs = []
    for key in probe_keys(R):
        for spec in R.REGISTRY.candidates(key.op):
            if spec.backend != "pallas":
                continue
            expected = recompute_vmem(spec, key)
            if expected is None:
                continue
            pairs.append((spec, key, expected))
    return R, pairs


@register
class VmemPredicateUnderCount(Rule):
    """DP301: a registered candidate's ``vmem_bytes(key)`` claims less than
    the kernel-side analytic footprint for that key — the PR 3 bug class
    (dtype-unaware or single-halved accounting) as a CI failure."""

    id = "DP301"
    title = "VMEM predicate under-counts the kernel's footprint"

    def check_project(self, ctx: Context) -> Iterable:
        R, pairs = _audit_pairs(ctx)
        if R is None:
            return
        for spec, key, expected in pairs:
            declared = spec.vmem_bytes(key)
            if declared < expected:
                yield self.finding(
                    _REGISTRY_PATH, 1,
                    f"{spec.op}:{spec.name} vmem_bytes({key.token}) = "
                    f"{declared} under-counts the kernel footprint "
                    f"{expected} (dtype {key.dtype}; check per-operand byte "
                    f"width and both double-buffer halves)",
                    anchor=f"{spec.op}:{spec.name}:{key.dtype}")


@register
class FeasibleAdmitsOverBudget(Rule):
    """DP302: ``feasible(key)`` accepts a key whose kernel-side footprint
    exceeds the VMEM budget — the dispatcher would admit a kernel that
    cannot fit, failing at compile/run time instead of falling down the
    plan ladder."""

    id = "DP302"
    title = "feasibility predicate admits an over-budget kernel"

    def check_project(self, ctx: Context) -> Iterable:
        R, pairs = _audit_pairs(ctx)
        if R is None:
            return
        for spec, key, expected in pairs:
            if expected > R.VMEM_BYTES and spec.feasible(key)[0]:
                yield self.finding(
                    _REGISTRY_PATH, 1,
                    f"{spec.op}:{spec.name} feasible({key.token}) admits a "
                    f"kernel footprint of {expected} bytes against a "
                    f"{R.VMEM_BYTES}-byte budget",
                    anchor=f"{spec.op}:{spec.name}:{key.dtype}:budget")
