"""Pallas kernel lints (PK1xx): the manual-DMA and VMEM conventions.

The kernels under ``src/repro/kernels/`` share a hand-rolled protocol
(``pltpu_compat``): async copies are created by ``make_async_copy`` and
driven by the two-slot ``double_buffer_rotate`` helper, HBM-resident
operands are declared ``BlockSpec(memory_space=ANY)`` and touched only
through windowed ``ref.at[...]`` DMA descriptors, and MXU contractions go
through ``dot_f32`` so interpret mode (XLA:CPU, no bf16 dot) keeps working.
These rules pin the protocol with pure AST checks — a kernel that starts a
DMA it never waits on, or indexes an ANY operand as if it were in VMEM,
fails CI instead of failing on hardware.

All rules key off names imported from ``pltpu_compat``, so the compat shim
itself (which *defines* the helpers and legitimately calls ``.start()`` /
``.wait()`` inside ``double_buffer_rotate``) is exempt by construction.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.engine import Context, Rule, register

_COMPAT_SUFFIX = "pltpu_compat"


def compat_aliases(tree: ast.Module) -> Dict[str, str]:
    """Local names bound by ``from ...pltpu_compat import X [as Y]``."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.endswith(_COMPAT_SUFFIX):
            for a in node.names:
                out[a.asname or a.name] = a.name
    return out


def _call_name(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _const_or(node: Optional[ast.expr], default: int) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return default


@dataclasses.dataclass
class PallasModel:
    """One ``pallas_call`` invocation resolved against its kernel function."""

    call: ast.Call
    kernel: Optional[ast.FunctionDef]
    in_specs: List[ast.expr]
    n_out: int
    scratch: List[ast.expr]
    n_prefetch: int

    def params(self) -> List[str]:
        if self.kernel is None:
            return []
        args = self.kernel.args
        return [a.arg for a in (*args.posonlyargs, *args.args)]

    def any_operand_params(self) -> List[str]:
        """Kernel param names bound to ``BlockSpec(memory_space=...)``
        (un-blocked, HBM/ANY-resident) inputs."""
        params = self.params()
        out = []
        for i, spec in enumerate(self.in_specs):
            if isinstance(spec, ast.Call) and _call_name(spec) == "BlockSpec" \
                    and _kwarg(spec, "memory_space") is not None:
                j = self.n_prefetch + i
                if j < len(params):
                    out.append(params[j])
        return out

    def scratch_expr_for(self, name: str) -> Optional[ast.expr]:
        """The scratch_shapes entry backing kernel param ``name``."""
        params = self.params()
        if name not in params:
            return None
        idx = params.index(name) - (self.n_prefetch + len(self.in_specs)
                                    + self.n_out)
        if 0 <= idx < len(self.scratch):
            return self.scratch[idx]
        return None


def _resolve_kernel_fn(tree: ast.Module, arg: ast.expr) \
        -> Optional[ast.FunctionDef]:
    name = None
    if isinstance(arg, ast.Name):
        name = arg.id
    elif isinstance(arg, ast.Call) and _call_name(arg) == "partial" \
            and arg.args and isinstance(arg.args[0], ast.Name):
        name = arg.args[0].id
    if name is None:
        return None
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def pallas_models(tree: ast.Module) -> List[PallasModel]:
    models = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _call_name(node) == "pallas_call"):
            continue
        spec_src: ast.Call = node
        n_prefetch = 0
        grid_spec = _kwarg(node, "grid_spec")
        if isinstance(grid_spec, ast.Call):
            # prefetch_grid_spec(num_scalar_prefetch=K, in_specs=..., ...):
            # scalar-prefetch operands shift every kernel param right by K
            spec_src = grid_spec
            n_prefetch = _const_or(_kwarg(grid_spec, "num_scalar_prefetch"), 0)
        in_specs = _kwarg(spec_src, "in_specs")
        out_specs = _kwarg(spec_src, "out_specs")
        scratch = _kwarg(spec_src, "scratch_shapes")
        models.append(PallasModel(
            call=node,
            kernel=_resolve_kernel_fn(tree, node.args[0]) if node.args
            else None,
            in_specs=list(in_specs.elts)
            if isinstance(in_specs, (ast.List, ast.Tuple)) else [],
            n_out=len(out_specs.elts)
            if isinstance(out_specs, (ast.List, ast.Tuple))
            else (1 if out_specs is not None else 1),
            scratch=list(scratch.elts)
            if isinstance(scratch, (ast.List, ast.Tuple)) else [],
            n_prefetch=n_prefetch,
        ))
    return models


def _top_level_functions(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in tree.body if isinstance(n, ast.FunctionDef)]


def _calls_to(fn: ast.AST, names: Iterable[str]) -> List[ast.Call]:
    wanted = set(names)
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in wanted:
            out.append(node)
    return out


def _method_calls(fn: ast.AST, attr: str) -> List[ast.Call]:
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr == attr:
            out.append(node)
    return out


def _aliases_of(compat: Dict[str, str], original: str) -> List[str]:
    return [local for local, orig in compat.items() if orig == original]


@register
class UnpairedAsyncCopy(Rule):
    """PK101: a ``make_async_copy`` descriptor must be driven to completion —
    either a direct ``.start()``/``.wait()`` pair or (preferred) the shared
    ``double_buffer_rotate`` protocol.  A start with no wait deadlocks or
    races on hardware; a descriptor that is never started is dead code."""

    id = "PK101"
    title = "make_async_copy without a matching wait"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        compat = compat_aliases(tree)
        mac = _aliases_of(compat, "make_async_copy")
        rot = _aliases_of(compat, "double_buffer_rotate")
        if not mac:
            return
        for fn in _top_level_functions(tree):
            mac_calls = _calls_to(fn, mac)
            if not mac_calls:
                continue
            starts = _method_calls(fn, "start")
            waits = _method_calls(fn, "wait")
            rotates = _calls_to(fn, rot) if rot else []
            if rotates and not starts and not waits:
                continue  # the shared rotation protocol drives the DMA
            if starts and waits:
                continue  # manually paired; PK102 judges the style
            line = mac_calls[0].lineno
            what = "started but never waited" if starts else (
                "waited but never started" if waits
                else "neither started nor handed to double_buffer_rotate")
            yield self.finding(
                path, line,
                f"async copy in {fn.name}() is {what}; drive it with "
                f"pltpu_compat.double_buffer_rotate or a .start()/.wait() "
                f"pair on every path",
                anchor=fn.name)


@register
class RawSlotRotation(Rule):
    """PK102: double-buffer slot sequencing belongs to the one shared
    ``double_buffer_rotate`` helper.  Hand-rolled ``.start()``/``.wait()``
    arithmetic re-implements the warmup/prefetch/drain protocol per kernel,
    which is exactly how slot-index bugs (wait on the buffer being filled)
    get written."""

    id = "PK102"
    title = "manual DMA slot rotation instead of double_buffer_rotate"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        compat = compat_aliases(tree)
        mac = _aliases_of(compat, "make_async_copy")
        if not mac:
            return
        for fn in _top_level_functions(tree):
            if not _calls_to(fn, mac):
                continue
            starts = _method_calls(fn, "start")
            waits = _method_calls(fn, "wait")
            if starts and waits:
                yield self.finding(
                    path, starts[0].lineno,
                    f"{fn.name}() sequences DMA slots with raw "
                    f".start()/.wait() calls; use "
                    f"pltpu_compat.double_buffer_rotate so warmup/prefetch/"
                    f"drain share one audited protocol",
                    anchor=fn.name)


@register
class AnyOperandDirectIndex(Rule):
    """PK103: a ``BlockSpec(memory_space=ANY)`` operand is HBM-resident —
    the kernel body may only carve DMA windows with ``ref.at[...]``, never
    read it with a direct subscript (which compiles to a per-element HBM
    access or fails late on hardware)."""

    id = "PK103"
    title = "ANY-memory operand indexed without an explicit copy"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        for model in pallas_models(tree):
            if model.kernel is None:
                continue
            any_params = set(model.any_operand_params())
            if not any_params:
                continue
            for node in ast.walk(model.kernel):
                if isinstance(node, ast.Subscript) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id in any_params:
                    yield self.finding(
                        path, node.lineno,
                        f"{model.kernel.name}() indexes ANY-memory operand "
                        f"{node.value.id!r} directly; copy a window into "
                        f"VMEM scratch first ({node.value.id}.at[...] + "
                        f"make_async_copy)",
                        anchor=f"{model.kernel.name}.{node.value.id}")


@register
class BareDotInKernel(Rule):
    """PK104: kernel-body contractions must go through
    ``pltpu_compat.dot_f32`` (which casts to f32 under interpret mode —
    XLA:CPU has no bf16 dot), not bare ``jnp.dot``.  A bare dot works on
    TPU and then breaks every CPU test/profile run in interpret mode."""

    id = "PK104"
    title = "bare jnp.dot in a pallas kernel body"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        for model in pallas_models(tree):
            if model.kernel is None:
                continue
            for node in ast.walk(model.kernel):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in ("dot", "dot_general"):
                    yield self.finding(
                        path, node.lineno,
                        f"{model.kernel.name}() calls a bare "
                        f"{ast.unparse(node.func)}; route the contraction "
                        f"through pltpu_compat.dot_f32 so interpret mode "
                        f"(CPU tests, profiling) keeps working",
                        anchor=model.kernel.name)


def _leading_dim_doubled(shape: ast.expr) -> bool:
    """True when a VMEM scratch shape's leading dim carries two DMA halves:
    a literal ``2`` or a ``2 * x`` / ``x * 2`` product."""
    if not isinstance(shape, (ast.Tuple, ast.List)) or not shape.elts:
        return False
    lead = shape.elts[0]
    if isinstance(lead, ast.Constant):
        return lead.value == 2
    if isinstance(lead, ast.BinOp) and isinstance(lead.op, ast.Mult):
        for side in (lead.left, lead.right):
            if isinstance(side, ast.Constant) and side.value == 2:
                return True
    return False


@register
class SingleBufferedDmaScratch(Rule):
    """PK105: the VMEM scratch a ``make_async_copy`` lands in must hold BOTH
    double-buffer halves (leading dim ``2`` or ``2*hb``).  A single-slot
    scratch silently serializes the pipeline — or worse, the prefetch
    overwrites the half still being consumed."""

    id = "PK105"
    title = "DMA destination scratch is not double-buffered"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        compat = compat_aliases(tree)
        mac = set(_aliases_of(compat, "make_async_copy"))
        if not mac:
            return
        for model in pallas_models(tree):
            if model.kernel is None:
                continue
            for call in _calls_to(model.kernel, mac):
                if len(call.args) < 2:
                    continue
                dst = _base_ref_name(call.args[1])
                if dst is None:
                    continue
                scratch = model.scratch_expr_for(dst)
                if scratch is None or not (
                        isinstance(scratch, ast.Call)
                        and _call_name(scratch) == "VMEM"):
                    continue
                shape = scratch.args[0] if scratch.args else None
                if shape is not None and not _leading_dim_doubled(shape):
                    yield self.finding(
                        path, call.lineno,
                        f"{model.kernel.name}() DMAs into scratch "
                        f"{dst!r} whose leading dim is not a 2x double "
                        f"buffer; allocate (2, ...) or (2*hb, ...) so "
                        f"prefetch can overlap compute",
                        anchor=f"{model.kernel.name}.{dst}")


def _base_ref_name(node: ast.expr) -> Optional[str]:
    """``buf.at[i]`` / ``buf.at[...]`` / ``buf`` -> ``"buf"``."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Attribute) and node.attr == "at":
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None
