"""Registry coherence (RC2xx): cross-layer name discipline.

Three registries anchor runtime names — ``fault.SITES`` for fault-injection
sites, the ``docs/observability.md`` schema tables for obs event/metric
names, and ``repro.env.KNOBS`` for ``REPRO_*`` env vars.  Code that invents
a name outside its registry "works" (all three layers tolerate unknown
names at runtime) and silently falls out of every tool built on the
registry: an unregistered fault site never fires under a chaos spec typo, an
undocumented trace event is invisible to schema-driven consumers, an
undeclared env knob dodges the central default/type discipline.  These
rules close the loop: every literal must be registered, and since the obs
names are parsed from the docs themselves, letting the docs drift behind
the code is the same failure.

All registry facts are parsed from source/docs via AST/regex (no imports),
so these rules run on fixture trees too.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Optional, Set

from repro.analysis.engine import Context, Rule, register

_FAULT_REGISTRY = "src/repro/fault.py"
_ENV_REGISTRY = "src/repro/env.py"

# the obs emit surface whose first (literal) argument is a schema name
_OBS_FNS = {"span", "instant", "counter", "gauge", "histogram"}


def _literal_first_arg(call: ast.Call) -> Optional[str]:
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


def _dotted_parts(node: ast.expr):
    """``a.b.c`` -> ["a", "b", "c"]; None for non-name chains."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return None


def spec_sites(spec: str) -> Iterable[str]:
    """Site names referenced by a fault-plan grammar string
    (``site[@match]:kind=value`` entries, comma-separated)."""
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        site = entry.partition(":")[0].partition("@")[0].strip()
        if site:
            yield site


@register
class UnknownFaultSite(Rule):
    """RC201: ``maybe_fail``/``fault_scope`` site literals must be members
    of ``fault.SITES``.  The runtime tolerates unknown sites (a probe that
    never runs never fires), which is exactly why a typo'd site in a chaos
    spec or a new probe missing from the registry stays invisible."""

    id = "RC201"
    title = "fault-site literal not registered in fault.SITES"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        if path == _FAULT_REGISTRY:
            return  # the registry itself (docstrings, grammar parser)
        sites = ctx.fault_sites()
        if sites is None:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None)
            if name == "maybe_fail":
                site = _literal_first_arg(node)
                if site is not None and site not in sites:
                    yield self.finding(
                        path, node.lineno,
                        f"maybe_fail site {site!r} is not in fault.SITES; "
                        f"register it in {_FAULT_REGISTRY} (and "
                        f"docs/robustness.md)",
                        anchor=site)
            elif name == "fault_scope":
                spec = _literal_first_arg(node)
                for site in spec_sites(spec or ""):
                    if site not in sites:
                        yield self.finding(
                            path, node.lineno,
                            f"fault_scope spec names unknown site {site!r}; "
                            f"register it in {_FAULT_REGISTRY}",
                            anchor=site)


def _obs_aliases(tree: ast.Module) -> Dict[str, Set[str]]:
    """Local bindings of the obs emit surface.

    Returns ``{"modules": {...}, "functions": {...}}`` — names bound to the
    ``repro.obs``/``repro.obs.trace``/``repro.obs.metrics`` modules, and
    emit functions imported directly (``from repro.obs.trace import span``).
    """
    modules: Set[str] = set()
    functions: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            if node.module == "repro" or node.module.endswith(".obs"):
                for a in node.names:
                    if a.name in ("obs", "trace", "metrics"):
                        modules.add(a.asname or a.name)
            if node.module.endswith("obs.trace") \
                    or node.module.endswith("obs.metrics"):
                for a in node.names:
                    if a.name in _OBS_FNS:
                        functions.add(a.asname or a.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name in ("repro.obs", "repro.obs.trace",
                              "repro.obs.metrics"):
                    modules.add(a.asname or a.name.split(".")[0])
    return {"modules": modules, "functions": functions}


@register
class UndocumentedObsName(Rule):
    """RC202: span/instant/counter/gauge/histogram name literals emitted on
    the global obs surface must appear in the ``docs/observability.md``
    schema tables.  Names are parsed from the docs, so shipping code without
    updating the docs fails the same way as inventing a name."""

    id = "RC202"
    title = "obs event/metric name missing from docs/observability.md"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        documented = ctx.documented_obs_names()
        if documented is None:
            return
        aliases = _obs_aliases(tree)
        if not aliases["modules"] and not aliases["functions"]:
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            emit = None
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _OBS_FNS:
                parts = _dotted_parts(node.func)
                # _ot.span(...) / obs.trace.span(...): the receiver chain
                # must root in an obs-module alias (a method on a private
                # Registry instance is internal, not schema-bearing)
                if parts is not None and parts[0] in aliases["modules"]:
                    emit = node.func.attr
            elif isinstance(node.func, ast.Name) \
                    and node.func.id in aliases["functions"]:
                emit = node.func.id
            if emit is None:
                continue
            name = _literal_first_arg(node)
            if name is not None and name not in documented:
                yield self.finding(
                    path, node.lineno,
                    f"obs {emit} name {name!r} is not documented in "
                    f"docs/observability.md; add it to the schema tables",
                    anchor=name)


def _is_environ_get(node: ast.Call) -> bool:
    """``os.environ.get(...)`` or ``os.getenv(...)``."""
    parts = _dotted_parts(node.func)
    return parts in (["os", "environ", "get"], ["os", "getenv"])


def _env_aliases(tree: ast.Module) -> Set[str]:
    """Local names bound to the ``repro.env`` module."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "repro":
            for a in node.names:
                if a.name == "env":
                    out.add(a.asname or a.name)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.env" and a.asname:
                    out.add(a.asname)
    return out


@register
class StrayEnvRead(Rule):
    """RC203: every ``REPRO_*`` read goes through ``repro.env`` — a direct
    ``os.environ`` read elsewhere re-invents the knob's parse/default
    inline and dodges the declared registry; an ``env.get`` of an
    undeclared name bypasses it entirely."""

    id = "RC203"
    title = "REPRO_* env read outside the repro.env registry"

    def check_module(self, ctx: Context, path: str, tree: ast.Module):
        if path == _ENV_REGISTRY:
            return  # the one sanctioned os.environ reader
        declared = ctx.declared_env_names()
        env_aliases = _env_aliases(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _is_environ_get(node):
                name = _literal_first_arg(node)
                if name is not None and name.startswith("REPRO_"):
                    yield self.finding(
                        path, node.lineno,
                        f"direct os.environ read of {name!r}; use "
                        f"repro.env.get({name!r}) so the knob's "
                        f"type/default live in one registry",
                        anchor=name)
            elif isinstance(node, ast.Subscript) \
                    and isinstance(node.ctx, ast.Load) \
                    and _dotted_parts(node.value) == ["os", "environ"] \
                    and isinstance(node.slice, ast.Constant) \
                    and isinstance(node.slice.value, str) \
                    and node.slice.value.startswith("REPRO_"):
                yield self.finding(
                    path, node.lineno,
                    f"direct os.environ[{node.slice.value!r}] read; use "
                    f"repro.env.get({node.slice.value!r})",
                    anchor=node.slice.value)
            elif isinstance(node, ast.Call) and declared is not None \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("get", "raw", "spec"):
                parts = _dotted_parts(node.func)
                if parts is not None and parts[0] in env_aliases:
                    name = _literal_first_arg(node)
                    if name is not None and name not in declared:
                        yield self.finding(
                            path, node.lineno,
                            f"repro.env.{node.func.attr}({name!r}) reads an "
                            f"undeclared knob; declare it in "
                            f"repro.env.KNOBS first",
                            anchor=name)
