"""Rule engine for the cross-layer contract checker (``repro.analysis``).

The repo's correctness rests on conventions spanning layers — VMEM
predicates must agree with the scratch their kernels allocate, fault-site
literals must exist in ``fault.SITES``, obs event names must match the
``docs/observability.md`` schema, env reads must go through ``repro.env`` —
and none of them are enforced by the type system.  This engine makes them
CI gates: stdlib-``ast`` rules (no new deps) walk every ``*.py`` once,
return :class:`Finding` records, and ``python -m repro.analysis src`` exits
non-zero on any finding not waived by the committed baseline.

Design points:

  * **Deterministic output.**  Files are visited in sorted order, findings
    are sorted on ``(path, line, rule, msg)``, paths are root-relative
    POSIX, and the JSON reporter sorts keys and carries no timestamps — two
    runs over the same tree are byte-identical (pinned by a test).
  * **Stable waiver keys.**  A finding's ``waiver_key`` is
    ``rule:path:anchor`` where the anchor is a rule-chosen symbol (function
    name, site literal), never a line number, so a committed waiver
    survives unrelated edits to the file.
  * **Two rule scopes.**  ``check_module(ctx, path, tree)`` rules see one
    parsed file at a time; ``check_project(ctx)`` rules run once per
    invocation (the dispatch-predicate audit imports the live registry).
    Project rules only fire when the analyzed tree contains the real
    ``src/repro`` package — running the engine over a test fixture
    directory exercises the AST rules without importing jax.

See ``docs/static-analysis.md`` for the rule catalog and waiver policy.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Finding", "Rule", "Context", "all_rules", "register",
           "iter_py_files", "load_baseline", "run", "render_text",
           "render_json"]

JSON_SCHEMA_VERSION = 1

# Directory names never descended into (caches, VCS metadata, envs).
_SKIP_DIRS = {"__pycache__", ".git", ".hg", ".cache", ".venv", "node_modules"}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one location."""

    path: str  # root-relative POSIX path
    line: int  # 1-indexed
    rule: str  # e.g. "PK101"
    msg: str
    waiver_key: str  # "rule:path:anchor" — line-free, baseline-stable

    def as_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "msg": self.msg, "waiver_key": self.waiver_key}


class Rule:
    """Base class: subclass, set ``id``/``title``, implement one hook."""

    id: str = ""
    title: str = ""

    def finding(self, path: str, line: int, msg: str,
                anchor: Optional[str] = None) -> Finding:
        key = f"{self.id}:{path}:{anchor if anchor is not None else 'module'}"
        return Finding(path=path, line=line, rule=self.id, msg=msg,
                       waiver_key=key)

    def check_module(self, ctx: "Context", path: str,
                     tree: ast.Module) -> Iterable[Finding]:
        return ()

    def check_project(self, ctx: "Context") -> Iterable[Finding]:
        return ()


_RULES: List[Rule] = []


def register(rule_cls):
    """Class decorator adding a rule (one shared instance) to the engine."""
    _RULES.append(rule_cls())
    return rule_cls


def all_rules() -> List[Rule]:
    # rule modules register at import; import them lazily so engine.py has
    # no import cycle with the rule files
    from repro.analysis import rules_dispatch  # noqa: F401
    from repro.analysis import rules_kernels  # noqa: F401
    from repro.analysis import rules_registry  # noqa: F401

    return sorted(_RULES, key=lambda r: r.id)


def find_root(start: Path) -> Optional[Path]:
    """Walk up from ``start`` to the repo root (the dir holding both
    ``src/repro`` and ``docs/observability.md``)."""
    p = start.resolve()
    if p.is_file():
        p = p.parent
    for cand in (p, *p.parents):
        if (cand / "src" / "repro").is_dir() and \
                (cand / "docs" / "observability.md").is_file():
            return cand
    return None


class Context:
    """Shared state for one engine run: the repo root (when found) and
    lazily parsed cross-file facts (fault sites, documented obs names,
    declared env knobs)."""

    def __init__(self, root: Optional[Path], files: Sequence[Path]):
        self.root = root
        self.files = list(files)
        self._fault_sites: Optional[frozenset] = None
        self._obs_names: Optional[frozenset] = None
        self._env_names: Optional[frozenset] = None
        # project rules audit the live registry; only meaningful when the
        # analyzed tree includes the real package
        self.has_repo_src = root is not None and any(
            _is_under(f, root / "src" / "repro") for f in self.files)

    def relpath(self, path: Path) -> str:
        if self.root is not None:
            try:
                return path.resolve().relative_to(self.root).as_posix()
            except ValueError:
                pass
        return path.as_posix()

    # -- cross-file facts ---------------------------------------------------

    def fault_sites(self) -> Optional[frozenset]:
        """``fault.SITES`` literals, parsed from the AST (no import)."""
        if self._fault_sites is None:
            self._fault_sites = _parse_fault_sites(self.root)
        return self._fault_sites or None

    def documented_obs_names(self) -> Optional[frozenset]:
        """Dotted event/metric names backticked in docs/observability.md."""
        if self._obs_names is None:
            self._obs_names = _parse_documented_names(self.root)
        return self._obs_names or None

    def declared_env_names(self) -> Optional[frozenset]:
        """Knob names declared in ``repro.env.KNOBS`` (AST, no import)."""
        if self._env_names is None:
            self._env_names = _parse_env_names(self.root)
        return self._env_names or None


def _is_under(path: Path, parent: Path) -> bool:
    try:
        path.resolve().relative_to(parent)
        return True
    except ValueError:
        return False


def _parse_fault_sites(root: Optional[Path]) -> frozenset:
    if root is None:
        return frozenset()
    src = root / "src" / "repro" / "fault.py"
    if not src.is_file():
        return frozenset()
    tree = ast.parse(src.read_text(), filename=str(src))
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "SITES":
                value = node.value
                if isinstance(value, (ast.Tuple, ast.List)):
                    return frozenset(
                        e.value for e in value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str))
    return frozenset()


# dotted lowercase identifiers like `dispatch.resolve` or `bench.<name>.us`
_DOC_NAME_RE = re.compile(
    r"`([a-z][a-z0-9_]*(?:\.(?:[a-z0-9_]+|<[a-z0-9_]+>))+)`")


def _parse_documented_names(root: Optional[Path]) -> frozenset:
    if root is None:
        return frozenset()
    doc = root / "docs" / "observability.md"
    if not doc.is_file():
        return frozenset()
    return frozenset(_DOC_NAME_RE.findall(doc.read_text()))


def _parse_env_names(root: Optional[Path]) -> frozenset:
    if root is None:
        return frozenset()
    src = root / "src" / "repro" / "env.py"
    if not src.is_file():
        return frozenset()
    tree = ast.parse(src.read_text(), filename=str(src))
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "EnvVar" and node.args \
                and isinstance(node.args[0], ast.Constant):
            names.add(node.args[0].value)
    return frozenset(names)


# ---------------------------------------------------------------------------
# File discovery, baseline, run
# ---------------------------------------------------------------------------


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    out = []
    for p in paths:
        p = Path(p)
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    out.append(f)
    return sorted(set(out))


def load_baseline(path: Optional[Path]) -> Dict[str, str]:
    """Committed waivers: ``{"waivers": [{"key": ..., "reason": ...}]}`` ->
    ``{key: reason}``.  A missing file is an empty baseline."""
    if path is None or not Path(path).is_file():
        return {}
    data = json.loads(Path(path).read_text())
    waivers = data.get("waivers", []) if isinstance(data, dict) else []
    out = {}
    for w in waivers:
        if isinstance(w, dict) and "key" in w:
            out[str(w["key"])] = str(w.get("reason", ""))
    return out


@dataclasses.dataclass
class Report:
    findings: List[Finding]          # non-waived, sorted
    waived: List[Finding]            # matched a baseline key
    unused_waivers: List[str]        # baseline keys that matched nothing
    files: int


def run(paths: Sequence[Path], *, root: Optional[Path] = None,
        only: Optional[Sequence[str]] = None,
        baseline: Optional[Dict[str, str]] = None) -> Report:
    """Run the rules over ``paths`` and split findings against ``baseline``."""
    files = iter_py_files([Path(p) for p in paths])
    if root is None and files:
        root = find_root(files[0])
    ctx = Context(root, files)
    rules = all_rules()
    if only is not None:
        wanted = set(only)
        rules = [r for r in rules if r.id in wanted]
    findings: List[Finding] = []
    for f in files:
        rel = ctx.relpath(f)
        try:
            tree = ast.parse(f.read_text(), filename=str(f))
        except SyntaxError as e:
            findings.append(Finding(
                path=rel, line=e.lineno or 1, rule="E000",
                msg=f"syntax error: {e.msg}", waiver_key=f"E000:{rel}:module"))
            continue
        for rule in rules:
            findings.extend(rule.check_module(ctx, rel, tree))
    if ctx.has_repo_src:
        for rule in rules:
            findings.extend(rule.check_project(ctx))
    findings.sort()
    baseline = dict(baseline or {})
    live, waived = [], []
    matched = set()
    for f in findings:
        if f.waiver_key in baseline:
            matched.add(f.waiver_key)
            waived.append(f)
        else:
            live.append(f)
    unused = sorted(set(baseline) - matched)
    return Report(findings=live, waived=waived, unused_waivers=unused,
                  files=len(files))


# ---------------------------------------------------------------------------
# Reporters
# ---------------------------------------------------------------------------


def render_text(report: Report) -> str:
    lines = []
    for f in report.findings:
        lines.append(f"{f.path}:{f.line}: {f.rule} {f.msg}")
    for key in report.unused_waivers:
        lines.append(f"baseline: unused waiver {key}")
    n = len(report.findings)
    lines.append(
        f"{n} finding{'s' if n != 1 else ''} "
        f"({len(report.waived)} waived) in {report.files} files")
    return "\n".join(lines)


def render_json(report: Report) -> str:
    payload = {
        "version": JSON_SCHEMA_VERSION,
        "files": report.files,
        "findings": [f.as_dict() for f in report.findings],
        "waived": [f.as_dict() for f in report.waived],
        "unused_waivers": list(report.unused_waivers),
    }
    return json.dumps(payload, indent=1, sort_keys=True)
