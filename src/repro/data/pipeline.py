"""Deterministic, resumable, topology-independent data pipeline.

The batch for global step k is a pure function of (seed, k) — restarting on a
different mesh (elastic scaling) or resuming from a checkpoint reproduces the
exact token stream with no iterator state beyond the step counter.

The synthetic stream is drawn from a fixed random bigram (Markov) table, so
models actually have structure to learn — the accuracy benchmarks
(paper Table 1 proxy) rely on a learnable distribution, not uniform noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 503
    batch: int = 8
    seq_len: int = 64
    seed: int = 1234
    kind: str = "bigram"  # bigram | uniform


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse-ish bigram table: each token has ~8 likely successors
        logits = rng.normal(size=(v, v)).astype(np.float32)
        top = np.argsort(-logits, axis=1)[:, :8]
        boost = np.zeros_like(logits)
        np.put_along_axis(boost, top, 4.0, axis=1)
        p = np.exp(logits * 0.1 + boost)
        self.table = p / p.sum(axis=1, keepdims=True)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        from repro import fault as _fault

        _fault.maybe_fail("data.batch", step=step)
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        if cfg.kind == "uniform":
            toks = rng.integers(0, cfg.vocab_size, (cfg.batch, cfg.seq_len))
            return {"tokens": toks.astype(np.int32)}
        toks = np.empty((cfg.batch, cfg.seq_len), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, cfg.batch)
        # vectorized Markov sampling via inverse-CDF per column
        u = rng.random((cfg.batch, cfg.seq_len))
        cdf = np.cumsum(self.table, axis=1)
        for t in range(1, cfg.seq_len):
            rows = cdf[toks[:, t - 1]]
            toks[:, t] = (rows < u[:, t : t + 1]).sum(axis=1)
        return {"tokens": toks.astype(np.int32)}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    # -- checkpointable state ------------------------------------------------
    def state_dict(self, step: int) -> Dict:
        return {"seed": self.cfg.seed, "step": step}

    @staticmethod
    def resume_step(state: Dict) -> int:
        return int(state["step"])
