from repro.serve.engine import Engine, ServeConfig  # noqa: F401
from repro.serve.kv_pages import (  # noqa: F401
    PackedPrefill,
    PageError,
    PagePool,
    PageTable,
    pack_prompts,
)
from repro.serve.kv_slots import Slot, SlotError, SlotPool  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    STATUSES,
    Completion,
    Request,
    RequestQueue,
    Scheduler,
    latency_percentiles,
    synthetic_trace,
)
