"""Serving engine: batched prefill + decode with per-sequence completion,
greedy/temperature sampling, and padded-vocab masking.

The engine owns the jitted step primitives — ``prefill_step``,
``prefill_chunk_step``, ``decode_step``, ``sample`` — and two consumers share
them: the static-batch :meth:`Engine.generate` below (pads every request to
the slowest sequence) and the continuous-batching
:class:`repro.serve.scheduler.Scheduler` (slot-based, in-flight admission).

Each step function is traced under a :func:`repro.dispatch.phase_scope`, so
every sparse-operator lookup inside resolves a phase-tagged OpKey: prefill
([B*S]-row operands) and decode ([B]-row operands) get separately profiled,
separately pinned implementations (TensorRT-LLM-style per-phase operator
specialization).  The same decode_step the multi-pod dry-run compiles for 512
chips drives this engine; on CPU it serves the reduced configs for
tests/examples.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry as reg
from repro.obs import trace as _ot


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: Optional[int] = None
    seed: int = 0
    # profile sparse-operator candidates at engine build (otherwise the plan
    # is resolved from the existing profile DB / platform heuristic; also
    # switchable via REPRO_DISPATCH_PROFILE=1)
    profile_dispatch: Optional[bool] = None
    dispatch_batch_hint: int = 8
    # expected prompt length for the prefill-phase row bucket
    # (prefill rows ~= batch * seq; decode rows ~= batch)
    dispatch_seq_hint: int = 128


def _phased(fn, phase: str):
    """Wrap a step fn so its jit trace runs inside a dispatch phase scope."""

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        from repro import dispatch as _dispatch

        with _dispatch.phase_scope(phase):
            return fn(*args, **kwargs)

    return wrapped


class Engine:
    def __init__(self, cfg: ModelConfig, params,
                 serve_cfg: Optional[ServeConfig] = None):
        self.cfg = cfg
        self.params = params
        # None => fresh per-instance config (a dataclass default instance
        # would be shared mutable state across every Engine)
        self.scfg = serve_cfg if serve_cfg is not None else ServeConfig()
        # Build-time operator dispatch: resolve (and optionally profile) the
        # implementation for every compressed layer shape before tracing, so
        # the phase-tagged lookups inside the traced steps hit a warm profile
        # DB and every process serving this model pins identical per-phase
        # backends.
        from repro import dispatch as _dispatch

        scfg = self.scfg
        with _ot.span("engine.build", arch=cfg.name):
            self.dispatch_plan = _dispatch.plan_params(
                params, batch_hint=scfg.dispatch_batch_hint,
                phase_hints={
                    "prefill": scfg.dispatch_batch_hint * scfg.dispatch_seq_hint,
                    "decode": scfg.dispatch_batch_hint,
                },
                profile=scfg.profile_dispatch)
        self._decode = jax.jit(_phased(reg.decode_fn(cfg), "decode"),
                               donate_argnums=(1,))
        self._prefill = jax.jit(_phased(reg.prefill_fn(cfg), "prefill"))
        self._prefill_chunk = None  # built lazily (attention families only)
        # paged-cache steps, built lazily per page size (serve.kv_pages tier)
        self._paged_decode = None
        self._prefill_packed = None
        self._paged_page_size = None

    # ------------------------------------------------------------------
    # Step primitives (shared by generate() and the continuous Scheduler)
    # ------------------------------------------------------------------

    def sample(self, logits: jax.Array, key) -> jax.Array:
        """Sample next tokens from [B, S, V] logits (last position)."""
        logits = logits[:, -1].astype(jnp.float32)
        v = self.cfg.vocab_size
        if self.cfg.padded_vocab != v:
            logits = logits.at[:, v:].set(-1e30)
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)

    # kept as an alias: pre-refactor callers used the private name
    _sample = sample

    def prefill_step(self, prompts: np.ndarray, max_len: int,
                     extras: Optional[Dict] = None):
        """Run the prompt through the model; returns (last-token logits,
        decode-ready cache sized for max_len)."""
        b, s = prompts.shape
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, cache = self._prefill(self.params, batch)
        if cache is None:
            # recurrent/hybrid families: prefill == run the recurrence over
            # the prompt (state cache, not KV)
            cache = reg.cache_init_fn(self.cfg, b, max_len)()
            for t in range(s):
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(prompts[:, t : t + 1]),
                    jnp.asarray(t, jnp.int32),
                )
        else:
            # grow the KV cache to max_len for attention families
            cache = self._grow_cache(cache, b, max_len, s)
        return logits, cache

    def prefill_chunk_step(self, cache, tokens, start, with_logits=True):
        """Prefill one fixed-shape chunk of a prompt into a preallocated
        cache (scheduler admission path; attention families only).
        ``with_logits=False`` skips the unembed matmul — only the chunk
        holding the last prompt token needs logits."""
        if self._prefill_chunk is None:
            # no cache donation here: the scheduler feeds slot *views* of its
            # pool cache, and a full-extent slice (n_slots == 1) can alias
            # the pool's own buffer — donating it would delete the pool
            self._prefill_chunk = jax.jit(
                _phased(reg.prefill_chunk_fn(self.cfg), "prefill"),
                static_argnums=(4,))
        return self._prefill_chunk(self.params, cache, jnp.asarray(tokens),
                                   jnp.asarray(start, jnp.int32),
                                   bool(with_logits))

    def decode_step(self, cache, tokens, pos):
        """One decode step. tokens [B,1]; pos scalar or per-sequence [B]
        int32.  Returns (logits [B,1,V], cache).  The cache argument is
        donated — callers must rebind to the returned cache."""
        return self._decode(self.params, cache, tokens, pos)

    def _build_paged(self, page_size: int):
        """(Re)build the paged step pair for one physical page size.  The
        paged cache is owned exclusively by the scheduler (no slot views),
        so BOTH steps donate it and scatter in place."""
        if self._paged_page_size == page_size:
            return
        self._paged_decode = jax.jit(
            _phased(reg.paged_decode_fn(self.cfg, page_size), "decode"),
            donate_argnums=(1,))
        self._prefill_packed = jax.jit(
            _phased(reg.prefill_packed_fn(self.cfg, page_size), "prefill"),
            donate_argnums=(1,))
        self._paged_page_size = page_size

    def paged_decode_step(self, cache, tokens, pos, tables, *, page_size):
        """One decode step against a paged cache. tokens [B,1]; pos [B];
        tables [B, n_max] int32.  The cache argument is donated — callers
        must rebind to the returned cache."""
        self._build_paged(page_size)
        return self._paged_decode(self.params, cache, jnp.asarray(tokens),
                                  jnp.asarray(pos, jnp.int32),
                                  jnp.asarray(tables, jnp.int32))

    def packed_prefill_step(self, cache, packed, tables, *, page_size):
        """Prefill a packed multi-prompt stream (kv_pages.PackedPrefill)
        into a paged cache in ONE exact-shape call — zero padded tokens.
        Returns (logits [n_new, 1, V] — one row per admitted prompt — and
        the cache with all K/V scattered through the page tables).  Donates
        the cache; retraces per distinct stream length."""
        self._build_paged(page_size)
        return self._prefill_packed(
            self.params, cache, jnp.asarray(packed.tokens),
            jnp.asarray(packed.slot_ids), jnp.asarray(packed.positions),
            jnp.asarray(tables, jnp.int32), jnp.asarray(packed.last_idx))

    # ------------------------------------------------------------------
    # Static-batch generation
    # ------------------------------------------------------------------

    def generate(self, prompts: np.ndarray, extras: Optional[Dict] = None) -> Dict:
        """prompts: [B, S_prompt] int32. Returns dict with tokens + timings.

        With ``eos_id`` set, positions after a sequence's EOS are masked to
        ``eos_id`` (never the live tokens the batch keeps sampling for the
        still-running sequences) and ``gen_lens[b]`` reports how many tokens
        sequence b actually generated (its EOS included).
        """
        cfg, scfg = self.cfg, self.scfg
        b, s = prompts.shape
        max_len = s + scfg.max_new_tokens
        key = jax.random.PRNGKey(scfg.seed)

        t0 = time.perf_counter()
        with _ot.span("engine.prefill", batch=b, seq=s):
            logits, cache = self.prefill_step(prompts, max_len, extras)
        t_prefill = time.perf_counter() - t0

        out = []
        done = np.zeros((b,), bool)
        gen_len = np.zeros((b,), np.int32)

        def record(tok: jax.Array) -> jax.Array:
            """Mask post-EOS samples, track done/lengths; returns the token
            that is both emitted and fed back to the next decode step."""
            t = np.asarray(tok)
            if scfg.eos_id is not None:
                t = np.where(done, scfg.eos_id, t)
            gen_len[:] += (~done)
            out.append(t)
            if scfg.eos_id is not None:
                done[:] |= t == scfg.eos_id
            return jnp.asarray(t)

        key, k0 = jax.random.split(key)
        tok = record(self.sample(logits, k0))
        t1 = time.perf_counter()
        with _ot.span("engine.decode_loop", batch=b,
                      budget=scfg.max_new_tokens) as dsp:
            steps = 0
            for i in range(scfg.max_new_tokens - 1):
                if done.all():
                    break
                pos = jnp.asarray(s + i, jnp.int32)
                logits, cache = self.decode_step(cache, tok[:, None], pos)
                key, kk = jax.random.split(key)
                tok = record(self.sample(logits, kk))
                steps += 1
            dsp.set(steps=steps)
        t_decode = time.perf_counter() - t1
        gen = np.stack(out, axis=1)
        return {
            "tokens": gen,
            "gen_lens": gen_len.copy(),
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_s": gen.shape[1] * b / max(t_decode, 1e-9),
        }

    def _grow_cache(self, cache, b, max_len, cur_len):
        if cache is None:  # recurrent families need no growth
            full = reg.cache_init_fn(self.cfg, b, max_len)()
            return full
        if "k" in cache and cache["k"].ndim == 5 and cache["k"].shape[2] < max_len:
            full = reg.cache_init_fn(self.cfg, b, max_len)()
            for key in ("k", "v"):
                full[key] = full[key].at[:, :, :cur_len].set(cache[key])
            for key in ("xk", "xv"):
                if key in cache:
                    full[key] = cache[key]
            return full
        return cache
