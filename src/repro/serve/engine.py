"""Serving engine: batched prefill + decode with per-sequence completion,
greedy/temperature sampling, and padded-vocab masking.

The same decode_step the multi-pod dry-run compiles for 512 chips drives this
engine; on CPU it serves the reduced configs for tests/examples.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry as reg


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: Optional[int] = None
    seed: int = 0
    # profile sparse-operator candidates at engine build (otherwise the plan
    # is resolved from the existing profile DB / platform heuristic; also
    # switchable via REPRO_DISPATCH_PROFILE=1)
    profile_dispatch: Optional[bool] = None
    dispatch_batch_hint: int = 8


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        # Build-time operator dispatch: resolve (and optionally profile) the
        # implementation for every compressed layer shape before tracing, so
        # decode-shaped lookups hit a warm profile DB and every process
        # serving this model picks identical backends.  Prefill rows bucket
        # by batch*prompt_len and fall back to the heuristic until profiled
        # (per-phase dispatch is a ROADMAP open item).
        from repro import dispatch as _dispatch

        self.dispatch_plan = _dispatch.plan_params(
            params, batch_hint=serve_cfg.dispatch_batch_hint,
            profile=serve_cfg.profile_dispatch)
        self._decode = jax.jit(reg.decode_fn(cfg), donate_argnums=(1,))
        self._prefill = jax.jit(reg.prefill_fn(cfg))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        logits = logits[:, -1].astype(jnp.float32)
        v = self.cfg.vocab_size
        if self.cfg.padded_vocab != v:
            logits = logits.at[:, v:].set(-1e30)
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, extras: Optional[Dict] = None) -> Dict:
        """prompts: [B, S_prompt] int32. Returns dict with tokens + timings."""
        cfg, scfg = self.cfg, self.scfg
        b, s = prompts.shape
        max_len = s + scfg.max_new_tokens
        key = jax.random.PRNGKey(scfg.seed)

        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, cache = self._prefill(self.params, batch)
        if cache is None:
            # recurrent/hybrid families: prefill == run the recurrence over
            # the prompt (state cache, not KV)
            cache = reg.cache_init_fn(self.cfg, b, max_len)()
            for t in range(s):
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(prompts[:, t : t + 1]),
                    jnp.asarray(t, jnp.int32),
                )
        else:
            # grow the KV cache to max_len for attention families
            cache = self._grow_cache(cache, b, max_len, s)
        t_prefill = time.perf_counter() - t0

        key, k0 = jax.random.split(key)
        tok = self._sample(logits, k0)
        out = [tok]
        done = np.zeros((b,), bool)
        t1 = time.perf_counter()
        for i in range(scfg.max_new_tokens - 1):
            pos = jnp.asarray(s + i, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            key, kk = jax.random.split(key)
            tok = self._sample(logits, kk)
            out.append(tok)
            if scfg.eos_id is not None:
                done |= np.asarray(tok) == scfg.eos_id
                if done.all():
                    break
        t_decode = time.perf_counter() - t1
        gen = np.stack([np.asarray(t) for t in out], axis=1)
        return {
            "tokens": gen,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_s": gen.shape[1] * b / max(t_decode, 1e-9),
        }

    def _grow_cache(self, cache, b, max_len, cur_len):
        if cache is None:  # recurrent families need no growth
            full = reg.cache_init_fn(self.cfg, b, max_len)()
            return full
        if "k" in cache and cache["k"].ndim == 5 and cache["k"].shape[2] < max_len:
            full = reg.cache_init_fn(self.cfg, b, max_len)()
            for key in ("k", "v"):
                full[key] = full[key].at[:, :, :cur_len].set(cache[key])
            for key in ("xk", "xv"):
                if key in cache:
                    full[key] = cache[key]
            return full
        return cache
